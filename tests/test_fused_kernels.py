"""Fused select+pack encode kernels (DESIGN.md §8 "fused encode kernels").

Four contracts:

1. **Threshold equivalence** — the bit-pattern binary search that now
   drives ``ref.topk_mask`` / ``topk_mask_dynamic`` equals the naive
   ``lax.top_k`` threshold (the pre-fusion implementation), and the Pallas
   radix walk (``topk_compress.threshold_bits``) returns the same bit
   pattern in interpret mode.
2. **Kernel/oracle parity** — ``select_slots`` and ``qr_pack`` kernels in
   interpret mode are bitwise equal to their ``ref.py`` oracles at the
   edges the codec meets: k=0, k=n, cap±1 tie overflow, r=1, r=MAX_R,
   bf16 leaves, odd/non-block-multiple sizes.
3. **Dispatch parity** — ``ops.topk_slots`` / ``quantize_pack`` /
   ``topk_qr_slots`` agree between the ``ref`` and ``interpret`` backends,
   including under ``vmap`` (the client axis).
4. **Wire integration** — ``wire.encode`` payloads are identical across
   backends, and ``decode(encode(x))`` still equals the transform output.

Everything runs on CPU (interpret mode executes the kernel bodies with
jnp semantics); the CI matrix runs this file on both the single-device
and the 8-host-device legs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import Compose, QuantQr, TopK, wire
from repro.kernels import ops
from repro.kernels import qr_pack
from repro.kernels import ref
from repro.kernels import select_slots as sel
from repro.kernels import topk_compress as tc

SIZES = [33, 67, 128, 1024, 1030, 5000]


@pytest.fixture(autouse=True)
def _ref_backend():
    ops.set_backend("ref")
    yield
    ops.set_backend("auto")


def _vec(n, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=n).astype(np.float32)).astype(dtype)


def _uni(n, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(size=n).astype(np.float32))


def _naive_topk_mask(x, k):
    """The pre-fusion oracle: lax.top_k threshold semantics."""
    if k >= x.size:
        return x
    mag = jnp.abs(x)
    kth = jax.lax.top_k(mag, k)[0][k - 1]
    return jnp.where(mag >= kth, x, jnp.zeros_like(x))


# --------------------------------------------------------------------------- #
# 1. threshold equivalence
# --------------------------------------------------------------------------- #

class TestThreshold:
    @pytest.mark.parametrize("n", SIZES)
    def test_mask_equals_naive_topk(self, n):
        x = _vec(n, seed=n)
        for k in (1, 2, max(1, n // 10), n // 2, n - 1, n):
            assert (ref.topk_mask(x, k) == _naive_topk_mask(x, k)).all(), k

    def test_mask_bf16(self):
        x = _vec(1030, seed=3, dtype=jnp.bfloat16)
        out = ref.topk_mask(x, 100)
        ref_out = _naive_topk_mask(x, 100)
        assert out.dtype == jnp.bfloat16
        assert (out == ref_out).all()

    def test_dynamic_matches_static(self):
        x = _vec(515, seed=5)
        for k in (1, 50, 514, 515, 600):
            got = ref.topk_mask_dynamic(x, jnp.asarray(k, jnp.int32))
            assert (got == ref.topk_mask(x, min(k, x.size))).all(), k

    def test_dynamic_vmap(self):
        xs = jnp.stack([_vec(256, seed=s) for s in range(4)])
        ks = jnp.asarray([1, 16, 128, 256], jnp.int32)
        got = jax.vmap(ref.topk_mask_dynamic)(xs, ks)
        for i in range(4):
            assert (got[i] == ref.topk_mask(xs[i], int(ks[i]))).all()

    @pytest.mark.parametrize("n", [33, 1030])
    def test_radix_kernel_same_bits(self, n):
        x = _vec(n, seed=n + 1)
        for k in (1, n // 3, n - 1):
            t_ref = ref.topk_threshold_bits(x, k)
            t_pal = tc.threshold_bits(x, k, interpret=True)
            assert int(t_ref) == int(t_pal), (n, k)

    def test_k_edges(self):
        x = _vec(100, seed=9)
        # k = 0: all-ones pattern, empty support
        assert int(ref.topk_threshold_bits(x, 0)) == 0xFFFFFFFF
        assert int(tc.threshold_bits(x, 0, interpret=True)) == 0xFFFFFFFF
        # k >= n: every entry kept (bits >= t for all) on both paths
        bits = jax.lax.bitcast_convert_type(jnp.abs(x), jnp.uint32)
        for t in (ref.topk_threshold_bits(x, 100),
                  tc.threshold_bits(x, 100, interpret=True)):
            assert bool(jnp.all(bits >= t))

    def test_all_zero_input(self):
        x = jnp.zeros(64, jnp.float32)
        assert (ref.topk_mask(x, 7) == x).all()
        _, _, support = ref.topk_slots(x, 7, 7)
        assert int(support.sum()) == 0


# --------------------------------------------------------------------------- #
# 2. kernel/oracle parity (interpret mode)
# --------------------------------------------------------------------------- #

class TestCompactSlots:
    @pytest.mark.parametrize("n", SIZES)
    def test_parity(self, n):
        x = _vec(n, seed=n + 2)
        for k in (1, max(1, n // 10), n // 2):
            idx_r, vals_r, _ = ref.topk_slots(x, k, k)
            t = tc.threshold_bits(x, k, interpret=True)
            idx_p, vals_p = sel.compact_slots(x, t, k, interpret=True)
            assert (idx_r == idx_p.astype(jnp.uint32)).all(), (n, k)
            assert (vals_r == vals_p).all(), (n, k)

    @pytest.mark.parametrize("cap_delta", [-1, 0, 1])
    def test_tie_overflow_keeps_lowest_cap(self, cap_delta):
        x = jnp.ones(50, jnp.float32)            # 50-way tie at the threshold
        k, cap = 10, 10 + cap_delta
        idx_r, vals_r, support = ref.topk_slots(x, k, cap)
        t = tc.threshold_bits(x, k, interpret=True)
        idx_p, vals_p = sel.compact_slots(x, t, cap, interpret=True)
        assert (idx_r == jnp.arange(cap, dtype=jnp.uint32)).all()
        assert (idx_r == idx_p.astype(jnp.uint32)).all()
        assert (vals_r == vals_p).all()
        assert int(support.sum()) == 50          # accounting sees every tie

    def test_underfull_support_sentinels(self):
        x = jnp.zeros(100, jnp.float32).at[7].set(3.0).at[42].set(-1.5)
        idx_r, vals_r, _ = ref.topk_slots(x, 10, 10)
        t = tc.threshold_bits(x, 10, interpret=True)
        idx_p, vals_p = sel.compact_slots(x, t, 10, interpret=True)
        assert (idx_r == idx_p.astype(jnp.uint32)).all()
        assert (vals_r == vals_p).all()
        assert idx_r[0] == 7 and idx_r[1] == 42
        assert (idx_r[2:] == 100).all() and (vals_r[2:] == 0).all()

    def test_cap_beyond_block_boundary(self):
        # cap > one (1, 128) output tile: exercises the padded slot axis
        n = 4000
        x = _vec(n, seed=11)
        k = 300
        idx_r, vals_r, _ = ref.topk_slots(x, k, k)
        t = tc.threshold_bits(x, k, interpret=True)
        idx_p, vals_p = sel.compact_slots(x, t, k, interpret=True)
        assert (idx_r == idx_p.astype(jnp.uint32)).all()
        assert (vals_r == vals_p).all()


class TestQrPack:
    @pytest.mark.parametrize("n", [33, 1024, 1030, 5000])
    @pytest.mark.parametrize("r", [1, 4, wire.MAX_R])
    def test_parity(self, n, r):
        x, u = _vec(n, seed=n + 3), _uni(n, seed=n + 4)
        norm = jnp.sqrt(jnp.sum(x * x))
        w_ref = ref.quantize_pack_with_uniforms(x, r, u, norm)
        w_pal = qr_pack.quantize_pack_with_uniforms(
            x, r, u, norm, interpret=True)
        assert w_ref.shape == (-(-n // 32) * (1 + r),)
        assert (w_ref == w_pal).all()

    def test_matches_unfused_codes(self):
        x, u = _vec(1030, seed=7), _uni(1030, seed=8)
        norm = jnp.sqrt(jnp.sum(x * x))
        codes = ref.qr_codes_with_uniforms(x, 4, u, norm)
        assert (ref.quantize_pack_with_uniforms(x, 4, u, norm)
                == ref.pack_codes(codes, 5)).all()

    def test_saturation(self):
        # one dominant coordinate reaches the top level 2**r -> clamps
        x = jnp.zeros(64, jnp.float32).at[5].set(10.0)
        u = jnp.zeros(64, jnp.float32)
        norm = jnp.sqrt(jnp.sum(x * x))
        for r in (1, 4):
            w = qr_pack.quantize_pack_with_uniforms(x, r, u, norm,
                                                    interpret=True)
            codes = ref.unpack_codes(w, 1 + r, 64)
            assert int(codes[5]) == 2 ** r - 1
            assert (ref.quantize_pack_with_uniforms(x, r, u, norm) == w).all()

    def test_zero_norm(self):
        x = jnp.zeros(40, jnp.float32)
        u = _uni(40, seed=12)
        w = qr_pack.quantize_pack_with_uniforms(x, 4, u, jnp.float32(0.0),
                                                interpret=True)
        assert (w == 0).all()


class TestCompactCodeSlots:
    @pytest.mark.parametrize("n", [67, 1030, 3000])
    @pytest.mark.parametrize("r", [1, 4, wire.MAX_R])
    def test_parity(self, n, r):
        x, u = _vec(n, seed=n + 5), _uni(n, seed=n + 6)
        k = cap = max(1, n // 10)
        idx_r, words_r, norm_r, _ = ref.topk_qr_slots(x, k, cap, r, u)
        t = tc.threshold_bits(x, k, interpret=True)
        bits = jax.lax.bitcast_convert_type(jnp.abs(x), jnp.uint32)
        masked = jnp.where(bits >= t, x, 0.0)
        norm = jnp.sqrt(jnp.sum(masked * masked))
        idx_p, codes_p = sel.compact_code_slots(x, u, norm, t, r, cap,
                                                interpret=True)
        assert (idx_r == idx_p.astype(jnp.uint32)).all()
        assert (words_r == ref.pack_codes(codes_p, 1 + r)).all()


# --------------------------------------------------------------------------- #
# 3. dispatch parity: ref vs interpret backends, incl. vmap
# --------------------------------------------------------------------------- #

class TestOpsParity:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_topk_slots(self, dtype):
        x = _vec(1030, seed=21, dtype=dtype)
        ops.set_backend("interpret")
        i1, v1, s1 = ops.topk_slots(x, 100, 100)
        ops.set_backend("ref")
        i2, v2, s2 = ops.topk_slots(x, 100, 100)
        assert v1.dtype == v2.dtype == dtype
        assert (i1 == i2).all() and (v1 == v2).all() and (s1 == s2).all()

    def test_quantize_pack(self):
        x = _vec(1030, seed=22)
        key = jax.random.PRNGKey(5)
        ops.set_backend("interpret")
        w1, n1 = ops.quantize_pack(x, 4, key)
        ops.set_backend("ref")
        w2, n2 = ops.quantize_pack(x, 4, key)
        # the norms come from differently-ordered reductions; codes agree
        # whenever the norms do
        np.testing.assert_allclose(float(n1), float(n2), rtol=1e-6)
        if float(n1) == float(n2):
            assert (w1 == w2).all()

    def test_topk_qr_slots(self):
        x = _vec(2050, seed=23)
        key = jax.random.PRNGKey(6)
        ops.set_backend("interpret")
        i1, w1, n1, s1 = ops.topk_qr_slots(x, 200, 200, 4, key)
        ops.set_backend("ref")
        i2, w2, n2, s2 = ops.topk_qr_slots(x, 200, 200, 4, key)
        assert (i1 == i2).all() and (s1 == s2).all()
        np.testing.assert_allclose(float(n1), float(n2), rtol=1e-6)
        if float(n1) == float(n2):
            assert (w1 == w2).all()

    def test_topk_slots_vmap(self):
        xs = jnp.stack([_vec(515, seed=30 + s) for s in range(4)])
        out = {}
        for backend in ("interpret", "ref"):
            ops.set_backend(backend)
            out[backend] = jax.vmap(lambda x: ops.topk_slots(x, 50, 50))(xs)
        for a, b in zip(out["interpret"], out["ref"]):
            assert (a == b).all()

    def test_traced_k_routes_to_ref(self):
        # per-client densities: traced k must not hit the static kernels
        ops.set_backend("interpret")
        xs = jnp.stack([_vec(256, seed=40 + s) for s in range(3)])
        ks = jnp.asarray([8, 64, 256], jnp.int32)
        iv, vv, sv = jax.vmap(
            lambda x, k: ops.topk_slots(x, k, 256))(xs, ks)
        ops.set_backend("ref")
        for i in range(3):
            ir, vr, sr = ops.topk_slots(xs[i], int(ks[i]), 256)
            assert (iv[i] == ir).all() and (vv[i] == vr).all()


# --------------------------------------------------------------------------- #
# 4. wire integration: payload parity across backends, decode bit-identity
# --------------------------------------------------------------------------- #

WIRE_COMPS = [
    TopK(density=0.1),
    TopK(density=0.1, scope="global"),
    Compose(TopK(0.1), QuantQr(4)),
]


def _tree():
    km = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(km, (33,)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (8, 8)),
        "v": jax.random.normal(jax.random.PRNGKey(2), (67,)),
    }


class TestWireBackendParity:
    @pytest.mark.parametrize("comp", WIRE_COMPS,
                             ids=lambda c: type(c).__name__ + getattr(
                                 c, "scope", getattr(
                                     getattr(c, "first", None), "scope", "")))
    def test_payload_bitwise_equal(self, comp):
        tree, key = _tree(), jax.random.PRNGKey(7)
        ops.set_backend("ref")
        p_ref, rep_ref = jax.jit(
            lambda t, k: wire.encode(comp, t, k))(tree, key)
        ops.set_backend("interpret")
        p_int, rep_int = jax.jit(
            lambda t, k: wire.encode(comp, t, k))(tree, key)
        for unit_r, unit_i in zip(p_ref.data, p_int.data):
            for buf_r, buf_i in zip(unit_r, unit_i):
                if buf_r.dtype == jnp.float32 and buf_r.ndim == 0:
                    np.testing.assert_allclose(       # the per-unit norm
                        float(buf_r), float(buf_i), rtol=1e-6)
                else:
                    assert (buf_r == buf_i).all()
        assert float(rep_ref.total_bits) == float(rep_int.total_bits)

    def test_decode_roundtrip_interpret(self):
        tree, key = _tree(), jax.random.PRNGKey(8)
        comp = TopK(density=0.1)
        ops.set_backend("interpret")
        payload, _ = jax.jit(lambda t, k: wire.encode(comp, t, k))(tree, key)
        out = wire.decode(payload)
        expect, _ = comp.compress(tree)
        for a, b in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(expect)):
            assert (a == b).all()


class TestPayloadNbytesMemo:
    def test_cached_and_correct(self):
        tree = _tree()
        comp = TopK(density=0.1)
        wire._NBYTES_CACHE.clear()
        n1 = wire.payload_nbytes(comp, tree)
        assert len(wire._NBYTES_CACHE) == 1
        payload, _ = wire.encode(comp, tree, jax.random.PRNGKey(0))
        assert n1 == payload.nbytes
        # second call: pure dict hit (no new entries, same answer)
        assert wire.payload_nbytes(comp, tree) == n1
        assert len(wire._NBYTES_CACHE) == 1
        # a different static config gets its own entry
        wire.payload_nbytes(TopK(density=0.2), tree)
        assert len(wire._NBYTES_CACHE) == 2

    def test_key_separates_dtypes(self):
        tree32 = {"w": jnp.ones((64,), jnp.float32)}
        tree16 = {"w": jnp.ones((64,), jnp.bfloat16)}
        comp = TopK(density=0.5)
        assert (wire.payload_nbytes(comp, tree32)
                != wire.payload_nbytes(comp, tree16))
