"""Exact bit accounting + fused multi-round engine (hypothesis-free).

Two contracts from DESIGN.md §3:

1. ``BitsReport`` totals equal the hand-computed paper formulas —
   (32+32)*nnz for TopK (nnz from the actual mask), (1+r)*n + 32/tensor for
   Q_r, (32+1+r)*nnz + 32 for TopK->Q_r — across many shapes/seeds;
2. ``run_rounds`` (one jit for R rounds) is *bit-identical* to calling
   ``round`` R times on the same key chain, for all four FedComLoc
   variants, and its accumulated meter bits match the summed per-round
   accounting.  EF-mode uplink bits reflect the transmitted innovation,
   not the dense model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import (
    Compose, Identity, QuantQr, TopK, dense_bits, make_compressor)
from repro.core import fed_data, server
from repro.core.baselines import FedAvg, FedConfig
from repro.core.comm import CommMeter
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------- #
# 1. BitsReport == hand-computed formulas
# --------------------------------------------------------------------------- #

SHAPES = [[(17,)], [(64,), (8, 8)], [(5, 3), (31,), (2, 2, 2)]]


def tree_of(seed, shapes):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(keys, shapes))}


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("shapes", SHAPES)
@pytest.mark.parametrize("density", [0.1, 0.33, 0.8])
def test_topk_bits_formula(seed, shapes, density):
    x = tree_of(seed, shapes)
    out, rep = TopK(density=density).compress(x)
    nnz = sum(int((v != 0).sum()) for v in out.values())
    assert float(rep.total_bits) == nnz * (32 + 32)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("shapes", SHAPES)
@pytest.mark.parametrize("r", [1, 4, 8])
def test_quant_bits_formula(seed, shapes, r):
    x = tree_of(seed, shapes)
    n = sum(v.size for v in x.values())
    _, rep = QuantQr(r=r).compress(x, jax.random.PRNGKey(seed + 100))
    assert float(rep.total_bits) == (1 + r) * n + len(x) * 32


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("density,r", [(0.25, 4), (0.5, 2)])
def test_double_compression_bits_formula(seed, density, r):
    x = tree_of(seed, [(64,), (16, 4)])
    comp = Compose(TopK(density), QuantQr(r))
    out, rep = comp.compress(x, jax.random.PRNGKey(seed + 7))
    mid = TopK(density).apply(x)
    nnz = sum(int((v != 0).sum()) for v in mid.values())
    assert float(rep.total_bits) == nnz * (32 + 1 + r) + len(x) * 32


def test_identity_and_int8_formulas():
    x = tree_of(0, [(40,), (6, 6)])
    n = 40 + 36
    _, rep = Identity().compress(x)
    assert float(rep.total_bits) == n * 32
    _, rep8 = make_compressor("int8").compress(x, jax.random.PRNGKey(0))
    assert float(rep8.total_bits) == n * 8 + len(x) * 32


def test_value_bits_follow_leaf_dtype():
    """Dense/TopK value bits derive from the leaf dtype (DESIGN.md §3.2 /
    §8 satellite): bf16 leaves ship 16-bit values, fp32 stays at the
    FLOAT_BITS default — and mixed trees account each leaf at its own
    width.  Q_r/int8 level codes are dtype-independent."""
    from repro.compress import QuantQr, dense_report

    bf = tree_of(1, [(40,), (6, 6)])
    bf = {k: v.astype(jnp.bfloat16) for k, v in bf.items()}
    assert float(dense_report(bf).total_bits) == 76 * 16
    out, rep = TopK(density=0.25).compress(bf)
    nnz = sum(int((v != 0).sum()) for v in out.values())
    assert float(rep.value_bits) == nnz * 16
    assert float(rep.index_bits) == nnz * 32
    assert TopK(density=0.25).expected_bits(bf) == (10 + 9) * (16 + 32)
    mixed = {"a": jnp.ones((8,), jnp.bfloat16), "b": jnp.ones((8,))}
    assert float(dense_report(mixed).total_bits) == 8 * 16 + 8 * 32
    _, repq = QuantQr(r=4).compress(bf, jax.random.PRNGKey(2))
    assert float(repq.total_bits) == 76 * 5 + 2 * 32


# --------------------------------------------------------------------------- #
# 2. run_rounds == per-round loop, exactly
# --------------------------------------------------------------------------- #

def quadratic_setup(n_clients=5, d=6, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, d))
    b = rng.normal(size=(n_clients,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(n_clients)]
    return fed_data.from_numpy_partition(x, y, parts)


def sq_loss(params, xb, yb):
    pred = xb @ params["w"]
    return 0.5 * jnp.mean((pred - yb) ** 2)


def make_alg(variant, comp, n=5, d=6, **cfg_kw):
    data = quadratic_setup(n, d)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=3, batch_size=4,
                          variant=variant, **cfg_kw)
    return FedComLoc(sq_loss, data, cfg, comp), d


VARIANT_COMPRESSORS = [
    ("none", Identity(), {}),
    ("com", TopK(density=0.4), {}),
    ("local", TopK(density=0.5), {}),
    ("global", QuantQr(r=6), {}),
    ("com", TopK(density=0.4), {"error_feedback": True}),
]


@pytest.mark.parametrize("variant,comp,extra", VARIANT_COMPRESSORS)
def test_run_rounds_matches_per_round_loop(variant, comp, extra):
    R = 7
    alg_a, d = make_alg(variant, comp, **extra)
    alg_b, _ = make_alg(variant, comp, **extra)
    key = jax.random.PRNGKey(42)
    state_a = alg_a.init({"w": jnp.zeros((d,), jnp.float32)})
    state_b = alg_b.init({"w": jnp.zeros((d,), jnp.float32)})

    k = key
    per_round = []
    for _ in range(R):
        k, sub = jax.random.split(k)
        state_a, m = alg_a.round(state_a, sub)
        per_round.append(m)

    state_b, metrics = alg_b.run_rounds(state_b, key, R)

    # bit-identical trajectory (same key chain, one jit for R rounds)
    np.testing.assert_array_equal(np.asarray(state_a.x["w"]),
                                  np.asarray(state_b.x["w"]))
    np.testing.assert_array_equal(np.asarray(state_a.h["w"]),
                                  np.asarray(state_b.h["w"]))
    # identical per-round metrics and bits
    for i, m in enumerate(per_round):
        for key_ in ("train_loss", "uplink_bits", "downlink_bits"):
            assert m[key_] == pytest.approx(float(metrics[key_][i]), abs=0.0)
    # meters agree after R rounds
    assert alg_a.meter.rounds == alg_b.meter.rounds == R
    assert alg_a.meter.uplink_bits == alg_b.meter.uplink_bits
    assert alg_a.meter.downlink_bits == alg_b.meter.downlink_bits


def test_run_rounds_single_jit_call():
    """The fused engine compiles once and issues ONE call for R rounds."""
    alg, d = make_alg("com", TopK(density=0.4))
    state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
    calls = {"n": 0}
    orig = alg._fused

    def counting(num_rounds):
        fn = orig(num_rounds)

        def wrapper(*a):
            calls["n"] += 1
            return fn(*a)
        return wrapper

    alg._fused = counting
    alg.run_rounds(state, jax.random.PRNGKey(0), 12)
    assert calls["n"] == 1
    assert alg.meter.rounds == 12


def test_ef_uplink_bits_are_innovation_bits():
    """EF mode transmits C(innovation): at round 1 from x0 = 0 the
    innovation is the local iterate (small support), and reported uplink
    bits must be far below the dense model — the old dense-model
    accounting would report s * d * 32-bit value+index pairs."""
    n, d = 5, 40
    data = quadratic_setup(n, d)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=n, batch_size=4,
                          variant="com", error_feedback=True)
    alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.1))
    state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
    _, m = alg.round(state, jax.random.PRNGKey(0))
    k = max(1, round(0.1 * d))
    assert m["uplink_bits"] == n * k * 64          # nnz of the innovation
    assert m["uplink_bits"] < n * dense_bits(state.x)


def test_meter_jnp_mode_lazy_accumulation():
    meter = CommMeter(mode="jnp")
    meter.record_round(uplink_bits=jnp.asarray(100.0),
                       downlink_bits=jnp.asarray(50.0))
    meter.record_rounds(uplink_bits=jnp.asarray([1.0, 2.0]),
                        downlink_bits=jnp.asarray([3.0, 4.0]),
                        num_rounds=2)
    assert isinstance(meter._uplink, jax.Array)    # stayed on device
    assert meter.snapshot() == {"rounds": 3, "uplink_bits": 103.0,
                                "downlink_bits": 57.0, "total_bits": 160.0}


def test_server_fused_matches_unfused():
    """run_federated(fuse=True) records the same history + meter as the
    per-round driver."""
    data = quadratic_setup(4, 5)
    hists, meters = {}, {}
    for fuse in (False, True):
        cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=4,
                              clients_per_round=2, batch_size=4,
                              variant="com")
        alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.4))
        hist = server.run_federated(
            alg, {"w": jnp.zeros((5,), jnp.float32)}, num_rounds=9,
            key=jax.random.PRNGKey(5),
            eval_fn=lambda p: (jnp.zeros(()), jnp.zeros(())), eval_every=4)
        hists[fuse] = hist
        meters[fuse] = alg.meter.snapshot()
    assert meters[False] == meters[True]
    assert hists[False].rounds == hists[True].rounds
    np.testing.assert_array_equal(hists[False].train_loss,
                                  hists[True].train_loss)
    np.testing.assert_array_equal(
        np.asarray(hists[False].final_params["w"]),
        np.asarray(hists[True].final_params["w"]))


def test_fedavg_run_rounds_matches_loop():
    data = quadratic_setup(4, 5)
    cfg = FedConfig(gamma=0.05, local_steps=3, n_clients=4,
                    clients_per_round=2, batch_size=4)
    a = FedAvg(sq_loss, data, cfg, TopK(density=0.5))
    b = FedAvg(sq_loss, data, cfg, TopK(density=0.5))
    key = jax.random.PRNGKey(9)
    sa = a.init({"w": jnp.zeros((5,), jnp.float32)})
    sb = b.init({"w": jnp.zeros((5,), jnp.float32)})
    k = key
    for _ in range(5):
        k, sub = jax.random.split(k)
        sa, _ = a.round(sa, sub)
    sb, _ = b.run_rounds(sb, key, 5)
    np.testing.assert_array_equal(np.asarray(sa.x["w"]),
                                  np.asarray(sb.x["w"]))
    assert a.meter.snapshot() == b.meter.snapshot()
