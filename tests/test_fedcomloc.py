"""Algorithmic correctness of FedComLoc (paper Algorithm 1).

Key invariants:
* with C = Identity, full participation and deterministic gradients,
  FedComLoc is exactly Scaffnew/ProxSkip — verified against an independent
  numpy implementation;
* ProxSkip converges to the exact optimum of the average objective under
  heterogeneity (unlike FedAvg, which has a fixed-point bias);
* control variates sum to ~0 across clients (conservation);
* the Com/Local/Global variants and both step modes run and converge.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed_data
from repro.compress import Identity, QuantQr, TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

jax.config.update("jax_platform_name", "cpu")


def quadratic_setup(n_clients=5, d=3, seed=0):
    """Client i holds one repeated sample (a_i, b_i):
    f_i(w) = 0.5 (a_i . w - b_i)^2  (deterministic minibatch gradients)."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, d))
    b = rng.normal(size=(n_clients,))
    # dataset: each client's shard is its sample repeated
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(n_clients)]
    data = fed_data.from_numpy_partition(x, y, parts)
    w_star = np.linalg.solve(A.T @ A / n_clients + 1e-12 * np.eye(d),
                             A.T @ b / n_clients)
    return data, A, b, w_star


def sq_loss(params, xb, yb):
    pred = xb @ params["w"]
    return 0.5 * jnp.mean((pred - yb) ** 2)


def numpy_scaffnew(A, b, gamma, p, rounds, L, seed_unused=0):
    """Independent Scaffnew reference: full participation, fixed L local
    steps per round (the deterministic schedule FedComLoc uses)."""
    n, d = A.shape
    x = np.zeros((n, d))
    h = np.zeros((n, d))
    for _ in range(rounds):
        for _ in range(L):
            g = (A @ x.T).diagonal()[:, None] * A - b[:, None] * A
            x = x - gamma * (g - h)
        xbar = x.mean(axis=0)
        h = h + (p / gamma) * (xbar[None] - x)
        x = np.tile(xbar, (n, 1))
    return xbar


class TestScaffnewEquivalence:
    def test_matches_numpy_reference(self):
        n, d = 5, 3
        data, A, b, w_star = quadratic_setup(n, d)
        gamma, p, rounds = 0.05, 0.2, 40
        cfg = FedComLocConfig(gamma=gamma, p=p, n_clients=n,
                              clients_per_round=n, batch_size=4,
                              variant="none", local_steps="fixed")
        alg = FedComLoc(sq_loss, data, cfg, Identity())
        state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
        key = jax.random.PRNGKey(0)
        for _ in range(rounds):
            key, sub = jax.random.split(key)
            state, _ = alg.round(state, sub)
        ref = numpy_scaffnew(A, b, gamma, p, rounds, L=round(1 / p))
        np.testing.assert_allclose(np.asarray(state.x["w"]), ref,
                                   rtol=5e-4, atol=1e-5)

    def test_converges_to_exact_optimum(self):
        """ProxSkip's defining property: exact convergence under
        heterogeneity."""
        n, d = 5, 3
        data, A, b, w_star = quadratic_setup(n, d)
        cfg = FedComLocConfig(gamma=0.15, p=0.2, n_clients=n,
                              clients_per_round=n, batch_size=4,
                              variant="none")
        alg = FedComLoc(sq_loss, data, cfg, Identity())
        state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
        key = jax.random.PRNGKey(1)
        for _ in range(600):
            key, sub = jax.random.split(key)
            state, _ = alg.round(state, sub)
        err = np.linalg.norm(np.asarray(state.x["w"]) - w_star)
        assert err < 1e-3, err

    def test_control_variates_conserved(self):
        """Full participation keeps sum_i h_i = 0 (paper line 16 + init)."""
        n, d = 4, 3
        data, A, b, _ = quadratic_setup(n, d)
        cfg = FedComLocConfig(gamma=0.05, p=0.5, n_clients=n,
                              clients_per_round=n, batch_size=4,
                              variant="none")
        alg = FedComLoc(sq_loss, data, cfg, Identity())
        state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
        key = jax.random.PRNGKey(2)
        for _ in range(20):
            key, sub = jax.random.split(key)
            state, _ = alg.round(state, sub)
        hsum = np.asarray(state.h["w"]).sum(axis=0)
        np.testing.assert_allclose(hsum, 0.0, atol=1e-4)


class TestVariants:
    @pytest.mark.parametrize("variant,comp,tol", [
        # biased TopK: substantial decrease (exact convergence is not
        # guaranteed for biased compressors — the paper's own caveat)
        ("com", TopK(density=0.5), 0.3),
        ("local", TopK(density=0.75), 0.3),
        ("global", TopK(density=0.75), 0.3),
        # unbiased Q_r: converges near the optimum
        ("com", QuantQr(r=8), 0.01),
    ])
    def test_variant_converges(self, variant, comp, tol):
        n, d = 5, 8
        data, A, b, w_star = quadratic_setup(n, d)
        cfg = FedComLocConfig(gamma=0.05, p=0.2, n_clients=n,
                              clients_per_round=n, batch_size=4,
                              variant=variant)
        alg = FedComLoc(sq_loss, data, cfg, comp)
        state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
        key = jax.random.PRNGKey(3)
        losses = []
        for r in range(300):
            key, sub = jax.random.split(key)
            state, m = alg.round(state, sub)
            losses.append(m["train_loss"])
        assert np.mean(losses[-20:]) < tol * np.mean(losses[:3]), \
            (np.mean(losses[:3]), np.mean(losses[-20:]))

    def test_com_density1_equals_none(self):
        n, d = 4, 3
        data, *_ = quadratic_setup(n, d)
        runs = {}
        for variant, comp in [("none", Identity()),
                              ("com", TopK(density=1.0))]:
            cfg = FedComLocConfig(gamma=0.05, p=0.2, n_clients=n,
                                  clients_per_round=2, batch_size=4,
                                  variant=variant)
            alg = FedComLoc(sq_loss, data, cfg, comp)
            state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
            key = jax.random.PRNGKey(4)
            for _ in range(10):
                key, sub = jax.random.split(key)
                state, _ = alg.round(state, sub)
            runs[variant] = np.asarray(state.x["w"])
        np.testing.assert_allclose(runs["none"], runs["com"], rtol=1e-6)

    def test_rejects_bad_config(self):
        with pytest.raises(ValueError):
            FedComLocConfig(variant="huh")
        with pytest.raises(ValueError):
            FedComLocConfig(p=0.0)
        data, *_ = quadratic_setup(3, 2)
        with pytest.raises(ValueError):
            FedComLoc(sq_loss, data,
                      FedComLocConfig(variant="none", n_clients=3,
                                      clients_per_round=2),
                      TopK(density=0.5))


class TestBitsAccounting:
    def test_com_compresses_uplink_only(self):
        n, d = 4, 8
        data, *_ = quadratic_setup(n, d)
        cfg = FedComLocConfig(gamma=0.05, p=0.5, n_clients=n,
                              clients_per_round=2, batch_size=4,
                              variant="com")
        alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.25))
        state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
        state, _ = alg.round(state, jax.random.PRNGKey(0))
        snap = alg.meter.snapshot()
        dense_down = 2 * d * 32          # 2 clients x d floats
        assert snap["downlink_bits"] == dense_down
        assert snap["uplink_bits"] == 2 * 2 * 64    # k=2 coords x 64b x 2 cl

    def test_geometric_steps(self):
        n, d = 4, 3
        data, *_ = quadratic_setup(n, d)
        cfg = FedComLocConfig(gamma=0.05, p=0.3, n_clients=n,
                              clients_per_round=2, batch_size=4,
                              variant="none", local_steps="geometric")
        alg = FedComLoc(sq_loss, data, cfg, Identity())
        state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
        key = jax.random.PRNGKey(5)
        steps = []
        for _ in range(50):
            key, sub = jax.random.split(key)
            state, m = alg.round(state, sub)
            steps.append(m["num_local_steps"])
        mean = np.mean(steps)
        # truncated Geometric(0.3) mean ~ 2.8; allow slack
        assert 1.5 < mean < 5.0, mean
        assert max(steps) <= cfg.steps_cap


class TestBeyondPaper:
    """Beyond-paper extensions: EF14 error feedback + server momentum."""

    def test_error_feedback_requires_com(self):
        with pytest.raises(ValueError):
            FedComLocConfig(variant="local", error_feedback=True)

    def test_error_feedback_improves_biased_topk(self):
        """EF should tighten convergence at aggressive sparsity."""
        n, d = 5, 8
        data, A, b, w_star = quadratic_setup(n, d)
        errs = {}
        for ef in (False, True):
            cfg = FedComLocConfig(gamma=0.05, p=0.2, n_clients=n,
                                  clients_per_round=n, batch_size=4,
                                  variant="com", error_feedback=ef)
            alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.25))
            state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
            key = jax.random.PRNGKey(7)
            for _ in range(400):
                key, sub = jax.random.split(key)
                state, _ = alg.round(state, sub)
            errs[ef] = float(np.linalg.norm(
                np.asarray(state.x["w"]) - w_star))
        assert errs[True] < errs[False], errs

    def test_server_momentum_runs_and_converges(self):
        n, d = 5, 8
        data, A, b, w_star = quadratic_setup(n, d)
        cfg = FedComLocConfig(gamma=0.05, p=0.2, n_clients=n,
                              clients_per_round=n, batch_size=4,
                              variant="com", server_momentum=0.5)
        alg = FedComLoc(sq_loss, data, cfg, QuantQr(r=8))
        state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
        key = jax.random.PRNGKey(8)
        losses = []
        for _ in range(200):
            key, sub = jax.random.split(key)
            state, m = alg.round(state, sub)
            losses.append(m["train_loss"])
        assert np.mean(losses[-10:]) < 0.05 * np.mean(losses[:3])
