"""Client-axis sharding (DESIGN.md §6): a `shard_map` round must reproduce
the unsharded round — bit-identically on the metrics (uplink/downlink bits,
client_steps, client_uplink_bits, sim_time) and allclose on params — for
FedComLoc and all three baselines, at every realisable device count.

Run single-device (the default tier-1 env) this exercises the shard_map
path on a 1-device mesh; under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI matrix's
second leg) the same tests sweep 1/2/4/8-way sharding.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import TopK
from repro.core import fed_data
from repro.core.baselines import FedAvg, FedConfig, FedDyn, Scaffold
from repro.core.clients import ClientProfile, ClientSchedule
from repro.core.distributed import (
    shard_round, usable_shard_counts, validate_client_mesh)
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.launch.mesh import make_client_mesh

jax.config.update("jax_platform_name", "cpu")

N_CLIENTS, DIM, S, ROUNDS = 16, 6, 8, 4

EXACT_METRICS = ("uplink_bits", "downlink_bits", "client_steps",
                 "client_uplink_bits", "sim_time")


def quadratic_data(n_clients=N_CLIENTS, d=DIM, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, d))
    b = rng.normal(size=(n_clients,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(n_clients)]
    return fed_data.from_numpy_partition(x, y, parts)


def sq_loss(params, xb, yb):
    return 0.5 * jnp.mean((xb @ params["w"] - yb) ** 2)


DATA = quadratic_data()
P0 = {"w": jnp.zeros((DIM,), jnp.float32)}


def straggler_schedule():
    return ClientSchedule(
        profile=ClientProfile.lognormal(N_CLIENTS, speed_sigma=1.5, seed=3),
        deadline=3.0, drop_stragglers=True, bit_cost=1e-6)


def build(name):
    """Fresh algorithm instance (meters and jit caches are per-instance)."""
    if name == "fedcomloc_com":
        cfg = FedComLocConfig(gamma=0.05, p=0.2, n_clients=N_CLIENTS,
                              clients_per_round=S, batch_size=4,
                              variant="com")
        return FedComLoc(sq_loss, DATA, cfg, TopK(density=0.5))
    if name == "fedcomloc_ef":
        cfg = FedComLocConfig(gamma=0.05, p=0.2, n_clients=N_CLIENTS,
                              clients_per_round=S, batch_size=4,
                              variant="com", error_feedback=True)
        return FedComLoc(sq_loss, DATA, cfg, TopK(density=0.25))
    if name == "fedcomloc_drop":
        cfg = FedComLocConfig(gamma=0.05, p=0.2, n_clients=N_CLIENTS,
                              clients_per_round=S, batch_size=4,
                              variant="com")
        return FedComLoc(sq_loss, DATA, cfg, TopK(density=0.5),
                         schedule=straggler_schedule())
    fed = FedConfig(n_clients=N_CLIENTS, clients_per_round=S, batch_size=4,
                    local_steps=5)
    if name == "fedavg":
        return FedAvg(sq_loss, DATA, fed, TopK(density=0.5))
    if name == "fedavg_drop":
        return FedAvg(sq_loss, DATA, fed, TopK(density=0.5),
                      schedule=straggler_schedule())
    if name == "scaffold":
        return Scaffold(sq_loss, DATA, fed)
    if name == "feddyn":
        return FedDyn(sq_loss, DATA, fed)
    raise ValueError(name)


ALGORITHMS = ["fedcomloc_com", "fedcomloc_ef", "fedcomloc_drop",
              "fedavg", "fedavg_drop", "scaffold", "feddyn"]


@pytest.fixture(scope="module")
def references():
    """Unsharded run_rounds trajectories, one per algorithm."""
    out = {}
    for name in ALGORITHMS:
        alg = build(name)
        state, metrics = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(9),
                                        ROUNDS)
        out[name] = (state, metrics)
    return out


@pytest.mark.parametrize("name", ALGORITHMS)
def test_sharded_rounds_match_unsharded(name, references):
    """Fused scan-of-shard_map == unsharded scan at every device count."""
    st_ref, m_ref = references[name]
    for n_shards in usable_shard_counts(S):
        alg = build(name).use_mesh(make_client_mesh(n_shards))
        st, m = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(9), ROUNDS)
        for k in EXACT_METRICS:
            np.testing.assert_array_equal(
                m_ref[k], m[k], err_msg=f"{name} D={n_shards} metric {k}")
        np.testing.assert_allclose(
            np.asarray(st.x["w"]), np.asarray(st_ref.x["w"]),
            rtol=1e-5, atol=1e-6, err_msg=f"{name} D={n_shards} params")
        np.testing.assert_allclose(
            m["train_loss"], m_ref["train_loss"], rtol=1e-5, atol=1e-7)
        # the meter saw identical wire totals whichever mesh ran the rounds
        assert np.isclose(alg.meter.uplink_bits,
                          float(m_ref["uplink_bits"].sum()))


def test_single_device_mesh_is_bit_identical(references):
    """On a 1-device mesh even the *params* must match bit-for-bit: the
    shard_map program is the same computation in the same order."""
    st_ref, m_ref = references["fedcomloc_com"]
    alg = build("fedcomloc_com").use_mesh(make_client_mesh(1))
    st, m = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(9), ROUNDS)
    np.testing.assert_array_equal(np.asarray(st_ref.x["w"]),
                                  np.asarray(st.x["w"]))
    np.testing.assert_array_equal(np.asarray(st_ref.h["w"]),
                                  np.asarray(st.h["w"]))
    for k in m_ref:
        np.testing.assert_array_equal(m_ref[k], m[k], err_msg=k)


def test_per_round_driver_matches_on_mesh(references):
    """The one-jit-per-round driver agrees with the fused sharded engine."""
    _, m_ref = references["scaffold"]
    alg = build("scaffold").use_mesh(make_client_mesh())
    state = alg.init(P0)
    key = jax.random.PRNGKey(9)
    for r in range(ROUNDS):
        key, sub = jax.random.split(key)
        state, m = alg.round(state, sub)
        assert m["uplink_bits"] == float(m_ref["uplink_bits"][r])
        np.testing.assert_array_equal(m["client_steps"],
                                      m_ref["client_steps"][r])


def test_unbind_mesh_restores_unsharded_path(references):
    st_ref, m_ref = references["fedavg"]
    alg = build("fedavg").use_mesh(make_client_mesh(1)).use_mesh(None)
    assert alg._mesh is None
    st, m = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(9), ROUNDS)
    np.testing.assert_array_equal(np.asarray(st_ref.x["w"]),
                                  np.asarray(st.x["w"]))
    np.testing.assert_array_equal(m_ref["uplink_bits"], m["uplink_bits"])


class TestValidation:
    def test_mesh_must_have_clients_axis(self):
        from repro.launch.mesh import make_host_mesh
        with pytest.raises(ValueError, match="clients"):
            validate_client_mesh(make_host_mesh(), S)

    def test_sample_must_divide_over_shards(self):
        if len(jax.devices()) < 2:
            pytest.skip("needs >= 2 devices to make division fail")
        mesh = make_client_mesh(2)
        with pytest.raises(ValueError, match="divide"):
            validate_client_mesh(mesh, 7)
        with pytest.raises(ValueError, match="divide"):
            shard_round(lambda st, k, ctx: (st, {}), mesh, 7)

    def test_use_mesh_rejects_undividable_sample(self):
        if len(jax.devices()) < 2:
            pytest.skip("needs >= 2 devices to make division fail")
        cfg = FedComLocConfig(n_clients=N_CLIENTS, clients_per_round=3,
                              batch_size=4, variant="none")
        from repro.compress import Identity
        alg = FedComLoc(sq_loss, DATA, cfg, Identity())
        with pytest.raises(ValueError, match="divide"):
            alg.use_mesh(make_client_mesh(2))

    def test_usable_shard_counts(self):
        counts = usable_shard_counts(8, max_devices=8)
        assert counts == [1, 2, 4, 8]
        assert usable_shard_counts(8, max_devices=3) == [1, 2]
        assert usable_shard_counts(6, max_devices=8) == [1, 2, 3, 6]


def test_make_client_mesh_shapes():
    mesh = make_client_mesh(1)
    assert mesh.axis_names == ("clients",)
    assert mesh.shape["clients"] == 1
    composed = make_client_mesh(1, data=1, model=1)
    assert composed.axis_names == ("clients",)
    if len(jax.devices()) >= 2:
        full = make_client_mesh()
        assert full.shape["clients"] == len(jax.devices())
        two_axis = make_client_mesh(len(jax.devices()) // 2, data=2)
        assert two_axis.axis_names == ("clients", "data", "model")


def test_run_federated_accepts_mesh():
    from repro.core import server
    alg = build("fedcomloc_com")
    hist = server.run_federated(alg, P0, num_rounds=3,
                                key=jax.random.PRNGKey(2),
                                mesh=make_client_mesh())
    assert alg._mesh is not None
    assert alg.meter.rounds == 3
    assert hist.final_params is not None
