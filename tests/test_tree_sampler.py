"""TreeSampler (DESIGN.md §12): O(s log n) host-side cohort sampling.

Contracts:

* **Neutral path untouched** — ``sampler="tree"`` without an availability
  process is byte-identical to ``jax.random.choice`` (the tree only
  engages on weighted draws);
* **Distribution equivalence** — the tree draw is weighted sampling
  without replacement proportional to ``availability.weights(t)``:
  chi-square on the first-pick marginal at small n, and inclusion
  frequencies matching the Gumbel-top-k sampler's;
* **Cohort validity** — no duplicates, ``online`` mask mirrors positive
  weights at the picks, offline padding takes the lowest-indexed
  unselected clients (the Gumbel path's ``lax.top_k`` tie-break);
* **Incremental gate maintenance** — arc-search updates equal a full
  rebuild at every round, including multi-step advances and the
  rebuild-threshold jump;
* **Determinism** — draws are pure functions of ``(key, t, s)``, memoised
  so planner and in-graph callback share one cohort; fused and stepped
  engine runs agree.
"""

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.core.clients import (
    ClientAvailability, ClientProfile, ClientSchedule)
from repro.core.sampling import TreeSampler

jax.config.update("jax_platform_name", "cpu")


def make_avail(n, *, period=7.0, amp=0.6, churn_rate=0.0, online_frac=1.0,
               seed=0):
    return ClientAvailability.diurnal(
        n, period=period, amp=amp, churn_rate=churn_rate,
        online_frac=online_frac, seed=seed)


def make_sched(avail, sampler="tree"):
    return ClientSchedule(
        profile=ClientProfile.homogeneous(avail.n_clients),
        availability=avail, sampler=sampler)


def key_data(i):
    return np.asarray(jax.random.key_data(jax.random.PRNGKey(i)))


# --------------------------------------------------------------------------- #
# 1. neutral path: byte-identical to jax.random.choice
# --------------------------------------------------------------------------- #

def test_neutral_path_byte_identical_to_choice():
    n, s = 40, 8
    sched = ClientSchedule(profile=ClientProfile.homogeneous(n),
                           sampler="tree")
    for i in range(20):
        key = jax.random.PRNGKey(i)
        got, online = sched.sample_cohort(key, s, round_idx=i)
        ref = jax.random.choice(key, n, (s,), replace=False)
        assert online is None
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_unknown_sampler_rejected():
    with pytest.raises(ValueError, match="unknown sampler"):
        ClientSchedule(profile=ClientProfile.homogeneous(4),
                       sampler="reservoir")


# --------------------------------------------------------------------------- #
# 2. distribution equivalence
# --------------------------------------------------------------------------- #

def test_first_pick_marginal_chi_square():
    """s=1 draws hit client i with probability w_i / sum(w): chi-square
    over n=8 bins, ~20k draws, threshold far above the df=7 0.999
    quantile (24.3) so the test only fires on a real distribution bug."""
    n, trials = 8, 20000
    avail = make_avail(n, amp=0.6, seed=3)
    sampler = TreeSampler(avail)
    t = 2
    w = np.asarray(avail.weights(t), np.float64)
    p = w / w.sum()
    counts = np.zeros(n)
    for i in range(trials):
        clients, online = sampler.draw(key_data(i), t, 1)
        assert online[0]
        counts[clients[0]] += 1
    expected = trials * p
    chi2 = ((counts - expected) ** 2 / expected).sum()
    assert chi2 < 35.0, f"chi2={chi2:.1f}, counts={counts}, exp={expected}"


def test_inclusion_frequency_matches_gumbel():
    """Without-replacement cohorts: per-client inclusion frequencies of
    the tree sampler match the Gumbel-top-k reference within sampling
    noise (5000 trials, tolerance 0.04)."""
    n, s, trials = 10, 3, 5000
    avail = make_avail(n, amp=0.7, seed=5)
    t = 4

    tree = TreeSampler(avail)
    inc_tree = np.zeros(n)
    for i in range(trials):
        clients, _ = tree.draw(key_data(i), t, s)
        inc_tree[clients] += 1

    sched = make_sched(avail, sampler="gumbel")

    @jax.jit
    def gumbel_draw(key):
        clients, online = sched.sample_cohort(key, s, round_idx=t)
        return clients

    inc_g = np.zeros(n)
    for i in range(trials):
        inc_g[np.asarray(gumbel_draw(jax.random.PRNGKey(i)))] += 1

    np.testing.assert_allclose(inc_tree / trials, inc_g / trials,
                               atol=0.04)


# --------------------------------------------------------------------------- #
# 3. cohort validity
# --------------------------------------------------------------------------- #

def test_no_duplicates_and_online_mask():
    n, s = 64, 12
    avail = make_avail(n, amp=0.9, churn_rate=0.23, online_frac=0.5,
                       seed=7)
    sampler = TreeSampler(avail)
    for t in range(30):
        clients, online = sampler.draw(key_data(t), t, s)
        assert clients.shape == (s,) and online.shape == (s,)
        assert len(np.unique(clients)) == s, "duplicate client in cohort"
        w = np.asarray(avail.weights(t))
        # every client flagged online has positive weight
        assert (w[clients[online]] > 0).all()


def test_offline_padding_is_lowest_index_unselected():
    """When fewer than s clients are online, the cohort is padded with
    the lowest-indexed unselected clients — matching lax.top_k's
    tie-break on the Gumbel path's -inf scores."""
    n, s = 12, 6
    avail = make_avail(n, amp=0.5, churn_rate=0.31, online_frac=0.2,
                       seed=11)
    sampler = TreeSampler(avail)
    saw_pad = False
    for t in range(40):
        clients, online = sampler.draw(key_data(t), t, s)
        k = int(online.sum())
        if k == s:
            continue
        saw_pad = True
        # online picks first, then offline pads
        assert online[:k].all() and not online[k:].any()
        pads = clients[k:]
        unselected = np.setdiff1d(np.arange(n), clients[:k])
        np.testing.assert_array_equal(np.sort(pads), unselected[:s - k])
    assert saw_pad, "thin schedule never padded — tighten online_frac"


def test_rejection_cap_falls_back_to_exact(monkeypatch):
    """A zeroed proposal budget forces the exact Gumbel fallback — the
    draw must still be a valid, duplicate-free weighted cohort."""
    import repro.core.sampling as sampling
    monkeypatch.setattr(sampling, "_REJECTION_CAP_PER_PICK", 0)
    n, s = 32, 5
    avail = make_avail(n, amp=0.8, seed=2)
    sampler = TreeSampler(avail)
    clients, online = sampler.draw(key_data(0), 3, s)
    assert sampler.fallback_draws > 0
    assert len(np.unique(clients)) == s
    assert online.all()
    w = np.asarray(avail.weights(3))
    assert (w[clients] > 0).all()


# --------------------------------------------------------------------------- #
# 4. incremental gate maintenance == full rebuild
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("churn_rate,online_frac", [
    (0.37, 0.34), (0.05, 0.8), (0.49, 0.1)])
def test_incremental_gate_equals_rebuild(churn_rate, online_frac):
    n = 257                       # off power-of-two: exercises tree padding
    avail = make_avail(n, churn_rate=churn_rate, online_frac=online_frac,
                       seed=13)
    inc = TreeSampler(avail)
    ref = TreeSampler(avail)
    inc._rebuild(0)
    for t in range(1, 120):
        inc._advance_to(t)        # arc-search incremental path
        ref._rebuild(t)           # exact recompute
        np.testing.assert_array_equal(inc._gate, ref._gate,
                                      err_msg=f"gate diverged at t={t}")
        np.testing.assert_array_equal(inc._tree, ref._tree,
                                      err_msg=f"tree diverged at t={t}")
    assert inc.incremental_updates > 0


def test_jumps_and_backward_rebuild():
    n = 64
    avail = make_avail(n, churn_rate=0.37, online_frac=0.34, seed=17)
    s = TreeSampler(avail)
    ref = TreeSampler(avail)
    # forward jump past the rebuild threshold (dt * churn > 0.5) and a
    # backward jump both trigger a rebuild; small jumps stay incremental
    for t in [0, 1, 3, 500, 501, 2, 50]:
        s._advance_to(t)
        ref._rebuild(t)
        np.testing.assert_array_equal(s._gate, ref._gate,
                                      err_msg=f"gate diverged at t={t}")
    assert s.full_rebuilds >= 3   # t=0, t=500 (jump), t=2 (backward)
    assert s.incremental_updates > 0


def test_gate_matches_weights_support():
    """The tree's churn gate equals the support of ``weights(t)``'s gate
    factor (same f32 formula) round for round."""
    n = 128
    avail = make_avail(n, amp=0.0, churn_rate=0.29, online_frac=0.4,
                       seed=23)
    sampler = TreeSampler(avail)
    for t in range(60):
        sampler._advance_to(t)
        w = np.asarray(avail.weights(t))
        np.testing.assert_array_equal(sampler._gate, w > 0.0,
                                      err_msg=f"gate != weights support "
                                              f"at t={t}")


# --------------------------------------------------------------------------- #
# 5. determinism & memoisation
# --------------------------------------------------------------------------- #

def test_draw_is_memoised_and_deterministic():
    n, s = 50, 8
    avail = make_avail(n, amp=0.6, churn_rate=0.2, online_frac=0.6,
                       seed=29)
    a = TreeSampler(avail)
    kd = key_data(9)
    c1, o1 = a.draw(kd, 5, s)
    c2, o2 = a.draw(kd, 5, s)      # memo hit: identical objects
    assert c1 is c2 and o1 is o2
    b = TreeSampler(avail)         # fresh instance: same result
    b._advance_to(3)               # ...even from a different gate state
    c3, o3 = b.draw(kd, 5, s)
    np.testing.assert_array_equal(c1, c3)
    np.testing.assert_array_equal(o1, o3)


def test_engine_fused_equals_stepped_with_tree_sampler():
    """The in-graph tree callback and the host planner agree: a fused
    multi-round scan and the same rounds stepped one by one produce the
    same trajectory (InMemoryStore — the sampler is store-independent)."""
    from tests.test_client_store import build, run_fused, run_stepped
    import dataclasses as dc
    from tests.test_client_store import churny_schedule
    sched = dc.replace(churny_schedule(), sampler="tree")
    st_f, m_f = run_fused(build("fedcomloc_ef", None, sched))
    st_s, m_s = run_stepped(build("fedcomloc_ef", None, sched))
    np.testing.assert_allclose(np.asarray(st_f.x["w"]),
                               np.asarray(st_s.x["w"]), rtol=1e-6)
    for r, ms in enumerate(m_s):
        np.testing.assert_allclose(
            np.asarray(m_f["clients_aggregated"])[r],
            np.asarray(ms["clients_aggregated"]),
            err_msg=f"round {r} cohort size diverged")
