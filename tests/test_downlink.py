"""Downlink codec path (DESIGN.md §10): broadcast compression + LoCoDL.

Five contracts, mirroring tests/test_wire.py for the reverse direction:

1. mode validation — a non-dense downlink requires a compressor; packed
   additionally requires a wire-supported one; FedComLoc's Global variant
   and server momentum (which extrapolate past the value clients adopt)
   are rejected with a compressed downlink;
2. ``downlink="account"`` and ``downlink="packed"`` are bit-identical on
   one device — params exactly equal, ``downlink_bits`` exactly equal —
   for every algorithm, because decode(encode(delta)) IS the transform
   output (the §8 wire contract applied to the broadcast);
3. measured broadcast bytes reconcile in-graph with the accounted bits:
   ``downlink_payload_bytes * 8 - downlink_bits == s * padding`` with the
   same closed-form word-padding slack TestReconcile pins per codec;
4. LoCoDL: collapses to Scaffnew's cohort mean under Identity/lam=1/sync;
   its unconditional key chain keeps the sampling/uplink trajectory
   identical across downlink modes; fused rounds == one-jit-per-round;
   excluded stragglers keep their pre-round iterate and control variate;
5. the broadcast decodes under real meshes: a >1-shard client mesh
   reproduces the single-device packed-downlink round, and
   ``ModelShardCtx.encode_broadcast``/``decode_broadcast`` on a composed
   clients x model mesh match the unsharded wire bit-for-bit (the §9
   shard-local layout, one buffer per model shard).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import Compose, Identity, Int8Sync, QuantQr, TopK, wire
from repro.core import aggregation, fed_data
from repro.core.aggregation import AggregationPolicy
from repro.core.baselines import FedAvg, FedConfig, FedDyn, Scaffold
from repro.core.clients import ClientProfile, ClientSchedule
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.core.locodl import LoCoDL, LoCoDLConfig
from repro.launch.mesh import make_client_mesh

jax.config.update("jax_platform_name", "cpu")

N_DEV = len(jax.devices())
N, D, S, R = 6, 10, 4, 3


def quadratic_setup(seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(N, D))
    b = rng.normal(size=(N,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(N)]
    return fed_data.from_numpy_partition(x, y, parts)


def sq_loss(params, xb, yb):
    return 0.5 * jnp.mean((xb @ params["w"] - yb) ** 2)


DATA = quadratic_setup()
P0 = {"w": jnp.zeros((D,), jnp.float32)}
DROP_SCHED = ClientSchedule(
    profile=ClientProfile.lognormal(N, speed_sigma=1.0, seed=3),
    deadline=3.0, drop_stragglers=True)

# (name, downlink compressor) — every wire-supported codec family x scope
DOWN_CODECS = [
    ("identity", Identity()),
    ("topk", TopK(density=0.3)),
    ("topk-global", TopK(density=0.3, scope="global")),
    ("qr-r4", QuantQr(r=4)),
    ("qr-global", QuantQr(r=4, scope="global")),
    ("compose", Compose(TopK(0.3), QuantQr(4))),
    ("int8", Int8Sync()),
]


def build(alg_name, downlink="dense", down_comp=None, policy=None,
          schedule=None, **kw):
    if alg_name == "fedcomloc":
        cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=N,
                              clients_per_round=S, batch_size=4,
                              variant="com")
        return FedComLoc(sq_loss, DATA, cfg, TopK(0.5), schedule=schedule,
                         policy=policy, downlink=downlink,
                         downlink_compressor=down_comp, **kw)
    if alg_name == "locodl":
        cfg = LoCoDLConfig(gamma=0.05, p=0.25, lam=0.5, n_clients=N,
                           clients_per_round=S, batch_size=4)
        return LoCoDL(sq_loss, DATA, cfg, TopK(0.5), schedule=schedule,
                      policy=policy, downlink=downlink,
                      downlink_compressor=down_comp, **kw)
    cfg = FedConfig(gamma=0.05, local_steps=4, n_clients=N,
                    clients_per_round=S, batch_size=4)
    cls = {"fedavg": FedAvg, "scaffold": Scaffold, "feddyn": FedDyn}[alg_name]
    ckw = {"compressor": TopK(0.5)} if alg_name == "fedavg" else {}
    return cls(sq_loss, DATA, cfg, schedule=schedule, policy=policy,
               downlink=downlink, downlink_compressor=down_comp,
               **ckw, **kw)


ALGS = ("fedcomloc", "locodl", "fedavg", "scaffold", "feddyn")


def run(alg):
    state, metrics = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(7), R)
    return np.asarray(state.x["w"]), metrics


# --------------------------------------------------------------------------- #
# 1. validation
# --------------------------------------------------------------------------- #

class TestValidation:
    def test_non_dense_requires_compressor(self):
        with pytest.raises(ValueError, match="compressor"):
            build("fedavg", downlink="account")

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="downlink"):
            build("fedavg", downlink="sparse", down_comp=TopK(0.5))

    def test_packed_requires_wire_supported(self):
        class Opaque:
            def compress(self, tree, key=None):
                return tree, None

        with pytest.raises((ValueError, TypeError)):
            build("fedavg", downlink="packed", down_comp=Opaque())

    def test_fedcomloc_global_variant_rejected(self):
        cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=N,
                              clients_per_round=S, batch_size=4,
                              variant="global")
        with pytest.raises(ValueError, match="lobal"):
            FedComLoc(sq_loss, DATA, cfg, TopK(0.5), downlink="account",
                      downlink_compressor=TopK(0.5))

    def test_fedcomloc_momentum_rejected(self):
        cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=N,
                              clients_per_round=S, batch_size=4,
                              variant="com", server_momentum=0.5)
        with pytest.raises(ValueError, match="momentum"):
            FedComLoc(sq_loss, DATA, cfg, TopK(0.5), downlink="account",
                      downlink_compressor=TopK(0.5))

    def test_set_downlink_rebinds(self):
        alg = build("fedavg")
        alg.set_downlink("account", TopK(0.5))
        assert alg.downlink == "account"
        w1, m1 = run(alg)
        w2, m2 = run(build("fedavg", downlink="account",
                           down_comp=TopK(0.5)))
        np.testing.assert_array_equal(w1, w2)
        np.testing.assert_array_equal(m1["downlink_bits"],
                                      m2["downlink_bits"])

    def test_locodl_lam_validated(self):
        with pytest.raises(ValueError, match="lam"):
            LoCoDLConfig(lam=0.0)
        with pytest.raises(ValueError, match="lam"):
            LoCoDLConfig(lam=1.5)


# --------------------------------------------------------------------------- #
# 2. account == packed, bit-identical, every algorithm
# --------------------------------------------------------------------------- #

class TestAccountPackedParity:
    @pytest.mark.parametrize("alg_name", ALGS)
    def test_bit_identical(self, alg_name):
        wa, ma = run(build(alg_name, downlink="account",
                           down_comp=QuantQr(r=4)))
        wp, mp = run(build(alg_name, downlink="packed",
                           down_comp=QuantQr(r=4)))
        np.testing.assert_array_equal(wa, wp)
        for k in ("downlink_bits", "uplink_bits", "client_uplink_bits"):
            np.testing.assert_array_equal(ma[k], mp[k], err_msg=k)
        assert "downlink_payload_bytes" not in ma
        pad = mp["downlink_payload_bytes"] * 8 - mp["downlink_bits"]
        assert (pad >= 0).all()

    @pytest.mark.parametrize("alg_name", ALGS)
    def test_compressed_downlink_cheaper_than_dense(self, alg_name):
        _, md = run(build(alg_name))
        _, mc = run(build(alg_name, downlink="account",
                          down_comp=QuantQr(r=4)))
        assert float(np.sum(mc["downlink_bits"])) < \
            float(np.sum(md["downlink_bits"]))

    def test_dense_metrics_carry_no_payload_keys(self):
        _, m = run(build("fedcomloc"))
        assert "downlink_payload_bytes" not in m


# --------------------------------------------------------------------------- #
# 3. in-graph reconcile: closed-form padding per codec, scaled by cohort
# --------------------------------------------------------------------------- #

def expected_pad_bits(comp, tree):
    """Word-padding slack of one broadcast payload, from the wire spec —
    the same closed forms TestReconcile pins (uplink direction)."""
    spec = jax.eval_shape(
        lambda t: wire.encode(comp, t, jax.random.PRNGKey(0))[0],
        tree).spec
    shapes = [np.asarray(leaf).shape if hasattr(leaf, "shape")
              else leaf.shape
              for leaf in jax.tree_util.tree_leaves(tree)]
    b = 1 + spec.r
    if spec.codec in ("dense", "topk", "int8"):
        return 0.0
    if spec.codec == "qr":
        sizes = ([sum(int(np.prod(s)) for s in shapes)]
                 if spec.scope == "global"
                 else [int(np.prod(s)) for s in shapes])
        return float(sum((32 * -(-n // 32) - n) * b for n in sizes))
    return float(sum((32 * -(-c // 32) - c) * b for c in spec.caps))


class TestDownlinkReconcile:
    @pytest.mark.parametrize("name,comp", DOWN_CODECS)
    @pytest.mark.parametrize("alg_name", ("fedcomloc", "locodl"))
    def test_bytes_reconcile_with_bits(self, alg_name, name, comp):
        """packed broadcast: bytes*8 - bits == s * closed-form padding,
        every round.  TopK deltas are dense-support here (continuous
        random data never produces exact zeros), so topk slack is 0."""
        _, m = run(build(alg_name, downlink="packed", down_comp=comp))
        slack = np.asarray(m["downlink_payload_bytes"]) * 8 \
            - np.asarray(m["downlink_bits"])
        np.testing.assert_allclose(slack, S * expected_pad_bits(comp, P0))

    def test_downlink_meter_accumulates_payload(self):
        alg = build("fedavg", downlink="packed", down_comp=QuantQr(r=4))
        _, m = run(alg)
        assert alg.meter.downlink_bits == pytest.approx(
            float(np.sum(m["downlink_bits"])))


# --------------------------------------------------------------------------- #
# 4. LoCoDL semantics
# --------------------------------------------------------------------------- #

class TestLoCoDL:
    def test_collapses_to_scaffnew_mean(self):
        """Identity links + lam=1 + full participation: the communication
        step IS Scaffnew's averaging — every client lands on y, and y is
        the cohort mean of the local iterates."""
        cfg = LoCoDLConfig(gamma=0.05, p=0.25, lam=1.0, n_clients=N,
                           clients_per_round=N, batch_size=4)
        alg = LoCoDL(sq_loss, DATA, cfg, Identity())
        st, _ = alg.round(alg.init(P0), jax.random.PRNGKey(3))
        np.testing.assert_allclose(np.asarray(st.xs["w"]),
                                   np.asarray(st.x["w"])[None].repeat(N, 0),
                                   rtol=1e-6, atol=1e-7)

    def test_uplink_chain_invariant_across_downlink_modes(self):
        """One unconditional key split: switching the downlink codec moves
        the broadcast, never the sampling/local/uplink randomness."""
        runs = {dl: run(build("locodl", downlink=dl,
                              down_comp=None if dl == "dense"
                              else QuantQr(r=4)))
                for dl in ("dense", "account", "packed")}
        for dl in ("account", "packed"):
            np.testing.assert_array_equal(
                runs["dense"][1]["client_uplink_bits"],
                runs[dl][1]["client_uplink_bits"])
            np.testing.assert_array_equal(
                runs["dense"][1]["client_steps"],
                runs[dl][1]["client_steps"])

    def test_dense_equals_identity_account(self):
        """C_dn = Identity under "account" is a no-op on values: the
        trajectory equals dense mode exactly (same key chain), only the
        accounting path differs — and Identity accounts dense bits."""
        wd, md = run(build("locodl"))
        wi, mi = run(build("locodl", downlink="account",
                           down_comp=Identity()))
        np.testing.assert_array_equal(wd, wi)
        np.testing.assert_array_equal(md["downlink_bits"],
                                      mi["downlink_bits"])

    def test_fused_matches_per_round(self):
        for policy, sched in ((None, None),
                              (AggregationPolicy.semi_sync(2), DROP_SCHED),
                              (AggregationPolicy.async_buffered(2, 0.5),
                               DROP_SCHED)):
            alg = build("locodl", downlink="packed", down_comp=QuantQr(4),
                        policy=policy, schedule=sched)
            st_f, _ = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(7), R)
            st_l, key = alg.init(P0), jax.random.PRNGKey(7)
            for _ in range(R):
                key, sub = jax.random.split(key)
                st_l, _ = alg.round(st_l, sub)
            np.testing.assert_allclose(np.asarray(st_f.x["w"]),
                                       np.asarray(st_l.x["w"]),
                                       rtol=1e-6, atol=1e-7)

    def test_excluded_clients_keep_state(self):
        """semi_sync(1) with a straggler schedule: an excluded client's
        iterate and control variate rows are exactly its pre-round rows."""
        alg = build("locodl", policy=AggregationPolicy.semi_sync(1),
                    schedule=DROP_SCHED)
        st0 = alg.init(P0)
        st1, m = alg.round(st0, jax.random.PRNGKey(11))
        agg = float(np.asarray(m["clients_aggregated"]))
        assert agg <= S
        # only aggregated clients may move: excluded + never-sampled rows
        # stay exactly at their pre-round values (x AND h)
        changed_x = np.any(
            np.asarray(st1.xs["w"]) != np.asarray(st0.xs["w"]), axis=1)
        changed_h = np.any(
            np.asarray(st1.h["w"]) != np.asarray(st0.h["w"]), axis=1)
        assert changed_x.sum() <= agg
        assert changed_h.sum() <= agg

    def test_loss_decreases(self):
        alg = build("locodl", downlink="account", down_comp=QuantQr(r=8))
        st, key = alg.init(P0), jax.random.PRNGKey(0)
        losses = []
        for _ in range(30):
            key, sub = jax.random.split(key)
            st, m = alg.round(st, sub)
            losses.append(float(m["train_loss"]))
        assert np.mean(losses[-5:]) < 0.5 * np.mean(losses[:5])


# --------------------------------------------------------------------------- #
# 5. meshes: >1-shard client decode + model-sharded broadcast (§9)
# --------------------------------------------------------------------------- #

@pytest.mark.skipif(N_DEV < 2, reason="needs >= 2 devices")
class TestShardedDownlink:
    @pytest.mark.parametrize("alg_name", ("fedcomloc", "locodl"))
    def test_client_mesh_matches_single_device(self, alg_name):
        w1, m1 = run(build(alg_name, downlink="packed",
                           down_comp=QuantQr(r=4)))
        alg = build(alg_name, downlink="packed", down_comp=QuantQr(r=4))
        alg.use_mesh(make_client_mesh(2))
        ws, ms = run(alg)
        np.testing.assert_allclose(w1, ws, rtol=1e-6, atol=1e-7)
        for k in ("downlink_bits", "downlink_payload_bytes"):
            np.testing.assert_array_equal(m1[k], ms[k], err_msg=k)

    @pytest.mark.parametrize("comp", [TopK(0.3), QuantQr(r=4), Identity()],
                             ids=["topk", "qr", "dense"])
    def test_model_sharded_broadcast_roundtrip(self, comp):
        """ModelShardCtx.encode_broadcast/decode_broadcast on a composed
        clients x model mesh: shard-local buffers, bit-identical to the
        unsharded wire (tie-free leaves force identical TopK support).
        qr dither keys are shard-folded (the documented §9 contract), so
        its VALUES compare by quantization-error magnitude while the bit
        accounting still matches exactly."""
        from repro.core.distributed import ModelShardCtx

        rng = np.random.default_rng(5)
        shapes = {"embed": {"embedding": (64, 16)},
                  "mlp": {"wi": {"kernel": (16, 96)}},
                  "q": {"bias": (40,)},
                  "norm": {"scale": (33,)}}

        def leaf(shape):
            n = int(np.prod(shape))
            mags = rng.permutation(n).astype(np.float32) + 1.0
            signs = rng.choice(np.asarray([-1.0, 1.0], np.float32), n)
            return jnp.asarray((signs * mags).reshape(shape))

        tree = jax.tree_util.tree_map(
            leaf, shapes, is_leaf=lambda x: isinstance(x, tuple))
        ctx = ModelShardCtx(make_client_mesh(1, model=2))
        key = jax.random.PRNGKey(2)
        payload, rep = ctx.encode_broadcast(comp, tree, key)
        dec = ctx.decode_broadcast(payload)
        ref_payload, ref_rep = wire.encode(comp, tree, key)
        ref = wire.decode(ref_payload)
        qr_dither = isinstance(comp, QuantQr)
        for (kp, x), a, b in zip(
                jax.tree_util.tree_leaves_with_path(tree),
                jax.tree_util.tree_leaves(ref),
                jax.tree_util.tree_leaves(dec)):
            if qr_dither:
                e_ref = float(jnp.linalg.norm(x - a))
                e_got = float(jnp.linalg.norm(x - b))
                assert e_got <= 1.5 * e_ref + 1e-6, \
                    (jax.tree_util.keystr(kp), e_ref, e_got)
            else:
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=jax.tree_util.keystr(kp))
        for f in ("value_bits", "index_bits", "meta_bits"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref_rep, f)),
                np.asarray(getattr(rep, f)), err_msg=f)
