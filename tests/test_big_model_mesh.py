"""Composed clients x model meshes (DESIGN.md §9): shard-local wire path.

Four contracts:

1. ``ModelShardCtx.encode_payload`` -> ``gather_decoded_payload`` on a
   composed mesh reproduces the unsharded wire round bit-for-bit on
   tie-free trees — decoded trees and ``BitsReport`` identical for topk
   and dense at every model-shard count, qr identical bits + comparable
   quantization error (its dither keys are shard-folded by design);
2. the static byte accounting conserves wire bytes: a model shard ships
   ``per_device_payload_nbytes`` (~1/m of the payload), replicated units
   ride along whole, and ``m * per_dev - nbytes`` is exactly the
   replicated overhang;
3. every committed config's ``param_shardings`` agrees with
   ``model_dim_index`` leaf-by-leaf on 1- and 8-device model axes — the
   wire layout and the GSPMD placement can never disagree — and
   ``_sanitize`` never *silently* drops the model axis: whenever it would,
   ``validate_model_axis`` raises up front (seamless' 256206 vocab);
4. a federated round end-to-end on a composed mesh matches the flat-mesh
   round: losses and accounted bits equal up to threshold-tie noise.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import Compose, Identity, Int8Sync, QuantQr, TopK, wire
from repro.configs import ARCH_IDS, get_spec
from repro.core import fed_data
from repro.core.baselines import FedAvg, FedConfig
from repro.core.clients import RoundPlan
from repro.core.distributed import ModelShardCtx, validate_model_axis
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_client_mesh
from repro.models import transformer as tfm
from repro.sharding import specs as sspecs

jax.config.update("jax_platform_name", "cpu")

N_DEV = len(jax.devices())
C = 4  # stacked client dim in the wire tests

# Leaf paths chosen to hit the param_spec rules: embedding (model on dim
# 0), mlp kernel (model on dim 1), qkv bias (model on dim 0), and a norm
# scale the rules replicate (odd size: never divisible anyway).  All
# sharded dims divide 8.
WIRE_SHAPES = {
    "embed": {"embedding": (64, 16)},
    "mlp": {"wi": {"kernel": (16, 96)}},
    "q": {"bias": (40,)},
    "norm": {"scale": (33,)},
}


def tie_free_stacked(seed=0):
    """(C, ...) client-stacked tree with pairwise-distinct magnitudes per
    client leaf, so the TopK threshold has no ties and sharded vs
    unsharded support is forced identical."""
    rng = np.random.default_rng(seed)

    def leaf(shape):
        n = int(np.prod(shape))
        rows = []
        for _ in range(C):
            mags = rng.permutation(n).astype(np.float32) + 1.0
            signs = rng.choice(np.asarray([-1.0, 1.0], np.float32), n)
            rows.append((signs * mags).reshape(shape))
        return jnp.asarray(np.stack(rows))

    return jax.tree_util.tree_map(
        leaf, WIRE_SHAPES, is_leaf=lambda x: isinstance(x, tuple))


def unsharded_roundtrip(comp, stacked, keys=None):
    decs, reps = [], []
    for c in range(C):
        tree_c = jax.tree_util.tree_map(lambda a: a[c], stacked)
        k = None if keys is None else keys[c]
        payload, rep = wire.encode(comp, tree_c, k)
        decs.append(wire.decode(payload))
        reps.append(rep)
    dec = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *decs)
    return dec, reps


def full_plan():
    return RoundPlan(steps=jnp.ones((C,), jnp.int32),
                     participating=jnp.ones((C,), bool),
                     speed=jnp.ones((C,)), bandwidth=jnp.ones((C,)),
                     comp_overrides={})


def sharded_roundtrip(comp, stacked, m, keys=None, partf=None):
    mesh = make_client_mesh(max(1, min(N_DEV // m, C)), model=m)
    ctx = ModelShardCtx(mesh)
    payload, report = ctx.encode_payload(comp, full_plan(), stacked, keys)
    if partf is None:
        partf = jnp.ones((C,), jnp.float32)
    dec = ctx.gather_decoded_payload(payload, partf)
    return payload, report, dec


def leaf_model_dims(tree, m):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return tuple(sspecs.model_dim_index(path, leaf.shape, m)
                 for path, leaf in flat)


# --------------------------------------------------------------------------- #
# 1. shard-local encode/decode == unsharded wire, bit-for-bit
# --------------------------------------------------------------------------- #

@pytest.mark.skipif(N_DEV < 8, reason="needs 8 devices for clients x model")
class TestShardedRoundtrip:
    @pytest.mark.parametrize("m", [2, 4, 8])
    @pytest.mark.parametrize("comp", [TopK(0.1), TopK(0.4), Identity()],
                             ids=["topk10", "topk40", "dense"])
    def test_exact_match(self, comp, m):
        stacked = tie_free_stacked()
        dec_ref, reps = unsharded_roundtrip(comp, stacked)
        _, report, dec = sharded_roundtrip(comp, stacked, m)
        for (kp_r, ref), (kp_s, got) in zip(
                jax.tree_util.tree_leaves_with_path(dec_ref),
                jax.tree_util.tree_leaves_with_path(dec)):
            np.testing.assert_array_equal(
                np.asarray(ref), np.asarray(got),
                err_msg=f"m={m} {jax.tree_util.keystr(kp_r)}")
        for f in ("value_bits", "index_bits", "meta_bits"):
            ref = np.asarray([float(getattr(r, f)) for r in reps])
            np.testing.assert_array_equal(
                ref, np.asarray(getattr(report, f), np.float64),
                err_msg=f"m={m} {f}")

    @pytest.mark.parametrize("m", [2, 4])
    def test_qr_bits_and_error(self, m):
        """qr dither keys are shard-folded (documented), so decoded values
        differ from the unsharded run draw-by-draw — but the bits are
        width-static identical and the quantization error is the same
        magnitude (global norm via one psum)."""
        comp = QuantQr(r=4)
        stacked = tie_free_stacked(seed=3)
        keys = jax.random.split(jax.random.PRNGKey(5), C)
        dec_ref, reps = unsharded_roundtrip(comp, stacked, keys)
        _, report, dec = sharded_roundtrip(comp, stacked, m, keys=keys)
        for f in ("value_bits", "index_bits", "meta_bits"):
            ref = np.asarray([float(getattr(r, f)) for r in reps])
            np.testing.assert_array_equal(
                ref, np.asarray(getattr(report, f), np.float64),
                err_msg=f"m={m} {f}")
        for (kp, x), ref, got in zip(
                jax.tree_util.tree_leaves_with_path(stacked),
                jax.tree_util.tree_leaves(dec_ref),
                jax.tree_util.tree_leaves(dec)):
            e_ref = float(jnp.linalg.norm(x - ref))
            e_got = float(jnp.linalg.norm(x - got))
            assert e_got <= 1.5 * e_ref + 1e-6, \
                (jax.tree_util.keystr(kp), e_ref, e_got)

    @pytest.mark.parametrize("m", [2, 4])
    def test_masked_clients_decode_to_zero(self, m):
        comp = TopK(0.2)
        stacked = tie_free_stacked(seed=1)
        partf = jnp.asarray([1.0, 0.0, 1.0, 1.0])
        dec_ref, _ = unsharded_roundtrip(comp, stacked)
        _, _, dec = sharded_roundtrip(comp, stacked, m, partf=partf)
        for ref, got in zip(jax.tree_util.tree_leaves(dec_ref),
                            jax.tree_util.tree_leaves(dec)):
            got = np.asarray(got)
            assert not got[1].any()
            for c in (0, 2, 3):
                np.testing.assert_array_equal(np.asarray(ref)[c], got[c])

    def test_shard_is_fail_soft(self):
        mesh = make_client_mesh(2, model=2)
        ctx = ModelShardCtx(mesh)
        scalar = jnp.float32(3.0)
        odd = jnp.ones((3, 5))
        assert ctx.shard(scalar) is scalar        # rank-0: untouched
        np.testing.assert_array_equal(np.asarray(ctx.shard(odd)),
                                      np.ones((3, 5)))  # indivisible: no-op


# --------------------------------------------------------------------------- #
# 2. static capacity / byte accounting
# --------------------------------------------------------------------------- #

class TestByteAccounting:
    def sharded_spec(self, comp, m):
        structs = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s, jnp.float32), WIRE_SHAPES,
            is_leaf=lambda x: isinstance(x, tuple))
        mdims = leaf_model_dims(structs, m)
        return wire.sharded_wire_spec(comp, structs, mdims, m), mdims

    @pytest.mark.parametrize("comp", [TopK(0.1), QuantQr(r=4), Identity()],
                             ids=["topk", "qr", "dense"])
    def test_per_device_bytes_shrink_and_conserve(self, comp):
        spec1, _ = self.sharded_spec(comp, 1)
        assert wire.per_device_payload_nbytes(spec1) == spec1.nbytes
        prev = None
        for m in (2, 4, 8):
            spec, mdims = self.sharded_spec(comp, m)
            assert any(d is not None for d in mdims)
            per_dev = wire.per_device_payload_nbytes(spec)
            # nbytes = m * sharded + replicated; per_dev = sharded + repl
            assert per_dev < spec.nbytes
            overhang = m * per_dev - spec.nbytes        # = (m-1) * repl
            assert overhang >= 0 and overhang % (m - 1) == 0
            if prev is not None:
                assert per_dev < prev                   # shrinks with m
            prev = per_dev

    def test_dense_bytes_exact(self):
        spec, _ = self.sharded_spec(Identity(), 4)
        n_sharded = 64 * 16 + 16 * 96 + 40
        n_repl = 33
        assert spec.nbytes == (n_sharded + n_repl) * 4
        assert wire.per_device_payload_nbytes(spec) == \
            (n_sharded // 4 + n_repl) * 4

    def test_shard_cap_properties(self):
        for k in (1, 5, 64, 1000, 4096):
            for m in (1, 2, 4, 8, 16):
                cap = wire.shard_cap(k, m, 10**6)
                assert cap >= -(-k // m)            # >= expected k/m slots
                assert m * cap >= k                 # capacity conservation
            assert wire.shard_cap(k, 4, 7) <= 7     # never exceeds local n


# --------------------------------------------------------------------------- #
# 3. codec / mesh validation
# --------------------------------------------------------------------------- #

class _FakeMesh:
    axis_names = ("clients", "data", "model")

    def __init__(self, m):
        self.shape = {"clients": 1, "data": 1, "model": m}


class TestValidation:
    @pytest.mark.parametrize("comp", [
        Compose(TopK(0.25), QuantQr(4)),
        Int8Sync(),
        TopK(0.3, scope="global"),
        QuantQr(4, scope="global"),
    ], ids=["compose", "int8", "topk-global", "qr-global"])
    def test_sharded_codec_rejections(self, comp):
        with pytest.raises(ValueError):
            wire.check_sharded_supported(comp, 2)
        wire.check_sharded_supported(comp, 1)       # fine off the model axis

    def test_sharded_codec_accepts(self):
        assert wire.check_sharded_supported(TopK(0.3), 4) == "topk"
        assert wire.check_sharded_supported(QuantQr(4), 4) == "qr"
        assert wire.check_sharded_supported(Identity(), 4) == "dense"

    @pytest.mark.skipif(N_DEV < 4, reason="needs a composed mesh")
    def test_overrides_rejected_on_model_axis(self):
        ctx = ModelShardCtx(make_client_mesh(2, model=2))
        plan = full_plan()._replace(comp_overrides={1: TopK(0.5)})
        with pytest.raises(ValueError, match="overrides"):
            ctx.encode_payload(TopK(0.1), plan, tie_free_stacked())

    def test_validate_model_axis(self):
        qwen = get_spec("qwen2-0.5b")
        assert validate_model_axis(_FakeMesh(8), qwen) == 8
        assert validate_model_axis(_FakeMesh(1), qwen) == 1

        seamless = get_spec("seamless-m4t-large-v2")
        assert validate_model_axis(_FakeMesh(2), seamless) == 2
        with pytest.raises(ValueError) as ei:                # 256206 % 4
            validate_model_axis(_FakeMesh(4), seamless)
        msg = str(ei.value)
        assert "vocab" in msg and "[1, 2]" in msg            # usable sizes

        class NoModel:
            axis_names = ("clients",)
            shape = {"clients": 4}

        assert validate_model_axis(NoModel(), qwen) == 1

    @pytest.mark.skipif(N_DEV < 2, reason="needs a composed mesh")
    def test_make_client_mesh_validates_config(self):
        qwen = get_spec("qwen2-0.5b")
        bad = dataclasses.replace(qwen, model=dataclasses.replace(
            qwen.model, vocab=151_935))                      # odd vocab
        with pytest.raises(ValueError, match="vocab"):
            make_client_mesh(1, model=2, config=bad)
        make_client_mesh(1, model=2, config=qwen)            # divides fine


# --------------------------------------------------------------------------- #
# 4. param_shardings <-> model_dim_index agreement, every committed config
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_shardings_agree_with_wire_rules(arch):
    """The placement (``param_shardings`` after ``_sanitize``) and the wire
    layout (``model_dim_index``) must name the same model dim on every
    leaf; any dim ``_sanitize`` drops must be caught loudly by
    ``validate_model_axis`` rather than silently replicated."""
    from jax.sharding import Mesh

    spec = get_spec(arch)
    pstruct = steps_mod._params_struct(spec)
    n_exp = steps_mod._n_experts(spec)
    sizes = [1] + ([8] if N_DEV >= 8 else [])
    for m in sizes:
        mesh = Mesh(np.array(jax.devices()[:m]).reshape(1, m),
                    ("data", "model"))
        shardings = sspecs.param_shardings(pstruct, mesh, n_experts=n_exp)
        eom = bool(n_exp) and n_exp % m == 0
        try:
            validate_model_axis(mesh, spec)
            valid = True
        except ValueError:
            valid = False
        dropped = []
        for (path, leaf), ns in zip(
                jax.tree_util.tree_leaves_with_path(pstruct),
                jax.tree_util.tree_leaves(shardings)):
            placed = [i for i, e in enumerate(ns.spec)
                      if e == "model"
                      or (isinstance(e, tuple) and "model" in e)]
            mdi = sspecs.model_dim_index(path, leaf.shape, m,
                                         expert_over_model=eom)
            want = [] if mdi is None else [mdi]
            assert placed == want, \
                (arch, m, jax.tree_util.keystr(path), ns.spec, mdi)
            rule = sspecs.param_spec(sspecs._path_str(path), leaf.shape,
                                     mesh, eom)
            if any(e == "model" for e in rule) and not placed:
                dropped.append(jax.tree_util.keystr(path))
        if valid:
            assert not dropped, (arch, m, dropped)
        elif m > 1:
            assert dropped, (arch, m)        # the validator flagged these


# --------------------------------------------------------------------------- #
# 5. federated round end-to-end on a composed mesh
# --------------------------------------------------------------------------- #

TINY = tfm.ModelConfig(name="tiny", n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
                       qkv_bias=True)


@pytest.mark.skipif(N_DEV < 8, reason="needs 8 devices for clients x model")
def test_fed_round_composed_mesh_matches_flat():
    rng = np.random.default_rng(0)
    per, seq = 4, 8
    x = rng.integers(0, TINY.vocab, (4 * per, seq)).astype(np.int32)
    y = np.zeros((4 * per,), np.float32)
    data = fed_data.from_numpy_partition(
        x, y, [np.arange(i * per, (i + 1) * per) for i in range(4)])
    loss_fn = lambda p, xb, yb: tfm.loss(p, TINY, xb, loss_chunk=seq)
    fcfg = FedConfig(gamma=0.05, local_steps=2, n_clients=4,
                     clients_per_round=4, batch_size=2)
    params0 = tfm.init_params(jax.random.PRNGKey(0), TINY)

    runs = {}
    for m in (1, 2):
        mesh = (make_client_mesh(4) if m == 1 else
                make_client_mesh(4, model=m, config=TINY))
        alg = FedAvg(loss_fn, data, fcfg, TopK(0.1), wire="packed")
        alg.use_mesh(mesh)
        p0 = params0 if m == 1 else jax.device_put(
            params0, sspecs.param_shardings(params0, mesh))
        _, ms = alg.run_rounds(alg.init(p0), jax.random.PRNGKey(3), 2)
        runs[m] = {k: np.asarray(v) for k, v in ms.items()}

    np.testing.assert_allclose(runs[2]["train_loss"], runs[1]["train_loss"],
                               rtol=2e-3)
    # bits equal up to threshold-tie flips (64 bits/slot) on diverging
    # float trajectories
    np.testing.assert_allclose(runs[2]["uplink_bits"],
                               runs[1]["uplink_bits"], rtol=1e-4)
    for m in (1, 2):
        assert (runs[m]["uplink_payload_bytes"] * 8
                >= runs[m]["uplink_bits"]).all()     # §8 reconcile
