"""Baseline FL algorithms (paper §4.7): sanity + comparative behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed_data
from repro.core.baselines import (FedAvg, FedConfig, FedDyn, Scaffold,
                                  SparseFedAvg)

jax.config.update("jax_platform_name", "cpu")


def quadratic_setup(n_clients=5, d=4, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, d))
    b = rng.normal(size=(n_clients,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(n_clients)]
    return fed_data.from_numpy_partition(x, y, parts), A, b


def sq_loss(params, xb, yb):
    return 0.5 * jnp.mean((xb @ params["w"] - yb) ** 2)


def run(alg, d, rounds=150, seed=0):
    state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
    key = jax.random.PRNGKey(seed)
    losses = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        state, m = alg.round(state, sub)
        losses.append(m["train_loss"])
    return state, losses


@pytest.mark.parametrize("cls", [FedAvg, Scaffold, FedDyn])
def test_baseline_decreases_loss(cls):
    d = 4
    data, A, b = quadratic_setup(d=d)
    cfg = FedConfig(gamma=0.05, local_steps=5, n_clients=5,
                    clients_per_round=5, batch_size=4, alpha=0.1)
    alg = cls(sq_loss, data, cfg)
    _, losses = run(alg, d)
    assert np.mean(losses[-10:]) < 0.3 * np.mean(losses[:3])


def test_sparse_fedavg_fewer_bits():
    data, A, b = quadratic_setup(d=4)
    cfg = FedConfig(gamma=0.05, local_steps=5, n_clients=5,
                    clients_per_round=5, batch_size=4)
    dense = FedAvg(sq_loss, data, cfg)
    sparse = SparseFedAvg(sq_loss, data, cfg, density=0.25)
    run(dense, 4, rounds=3)
    run(sparse, 4, rounds=3)
    assert sparse.meter.uplink_bits < dense.meter.uplink_bits
    assert sparse.meter.downlink_bits == dense.meter.downlink_bits


def test_scaffold_double_comm_cost():
    data, A, b = quadratic_setup(d=4)
    cfg = FedConfig(gamma=0.05, local_steps=5, n_clients=5,
                    clients_per_round=5, batch_size=4)
    fedavg = FedAvg(sq_loss, data, cfg)
    scaffold = Scaffold(sq_loss, data, cfg)
    run(fedavg, 4, rounds=2)
    run(scaffold, 4, rounds=2)
    assert scaffold.meter.total_bits == 2 * fedavg.meter.total_bits


def test_scaffold_beats_fedavg_under_heterogeneity():
    """With heterogeneous clients and many local steps, FedAvg drifts;
    Scaffold's control variates correct it."""
    d = 4
    data, A, b = quadratic_setup(d=d, seed=3)
    w_star = np.linalg.solve(A.T @ A / 5 + 1e-12 * np.eye(d),
                             A.T @ b / 5)
    cfg = FedConfig(gamma=0.08, local_steps=20, n_clients=5,
                    clients_per_round=5, batch_size=4)
    sf, _ = run(Scaffold(sq_loss, data, cfg), d, rounds=300)
    ff, _ = run(FedAvg(sq_loss, data, cfg), d, rounds=300)
    err_s = np.linalg.norm(np.asarray(sf.x["w"]) - w_star)
    err_f = np.linalg.norm(np.asarray(ff.x["w"]) - w_star)
    assert err_s < err_f
