"""Optimizers + checkpointing substrates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.optim import optimizers

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name,kw", [("sgd", {}), ("momentum", {}),
                                     ("adam", {})])
def test_optimizer_minimizes_quadratic(name, kw):
    init, update = optimizers.make(name, lr=0.1, **kw)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init(params)

    def loss(p):
        return jnp.sum((p["w"] - jnp.asarray([1.0, 1.0])) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=1e-2)


def test_sgd_stateless():
    init, _ = optimizers.make("sgd", lr=0.1)
    assert init({"w": jnp.ones(3)}) == ()


def test_adam_fp32_state_for_bf16_params():
    init, update = optimizers.make("adam", lr=0.1)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init(params)
    assert state["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    new, state = update(g, state, params)
    assert new["w"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                      "b": jnp.zeros(3)},
            "step": jnp.asarray(7)}
    path = tmp_path / "ckpt.npz"
    checkpoint.save(path, tree, meta={"round": 7})
    loaded, meta = checkpoint.load(path, like=tree)
    assert meta["round"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_overwrite(tmp_path):
    path = tmp_path / "c.npz"
    checkpoint.save(path, {"a": jnp.zeros(2)}, meta={"v": 1})
    checkpoint.save(path, {"a": jnp.ones(2)}, meta={"v": 2})
    loaded, meta = checkpoint.load(path, like={"a": jnp.zeros(2)})
    assert meta["v"] == 2
    np.testing.assert_array_equal(np.asarray(loaded["a"]), 1.0)
