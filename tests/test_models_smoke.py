"""Per-architecture smoke tests (deliverable (f)).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(<= 2-4 layers, d_model <= 512, <= 4 experts, same family structure) and run
one forward/train step on CPU, asserting output shapes and no NaNs.  Also
exercises the serve path (prefill + one decode step).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_spec
from repro.configs.base import reduced
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm

jax.config.update("jax_platform_name", "cpu")

B, T = 2, 32


def _toks(spec, t=T):
    return jax.random.randint(jax.random.PRNGKey(1), (B, t), 0,
                              spec.model.vocab)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    spec = reduced(get_spec(arch))
    m = spec.model
    key = jax.random.PRNGKey(0)
    if spec.is_encdec:
        params = encdec_mod.init_params(key, m)
        src = jax.random.normal(jax.random.PRNGKey(2), (B, T, m.d_model))
        tgt = _toks(spec)

        def loss_fn(p):
            return encdec_mod.loss(p, m, src, tgt, loss_chunk=16)
    else:
        params = tfm.init_params(key, m)
        toks = _toks(spec)
        npre = min(spec.n_prefix_tokens, 4)
        prefix = (jax.random.normal(jax.random.PRNGKey(3),
                                    (B, npre, m.d_model))
                  if npre else None)

        def loss_fn(p):
            return tfm.loss(p, m, toks, prefix_embeds=prefix, loss_chunk=16)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), arch
    # one SGD step changes params and keeps the loss finite
    new = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = loss_fn(new)
    assert np.isfinite(float(loss2)), arch
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_step(arch):
    spec = reduced(get_spec(arch))
    m = spec.model
    key = jax.random.PRNGKey(0)
    if spec.is_encdec:
        params = encdec_mod.init_params(key, m)
        src = jax.random.normal(jax.random.PRNGKey(2), (B, 16, m.d_model))
        tgt = _toks(spec, 8)
        logits, state = encdec_mod.prefill(params, m, src, tgt,
                                           max_len=16, dtype=jnp.float32)
        assert logits.shape == (B, m.vocab)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits2, state = encdec_mod.decode_step(params, m, tok, state)
        assert logits2.shape == (B, m.vocab)
        assert bool(jnp.isfinite(logits2).all()), arch
    else:
        params = tfm.init_params(key, m)
        toks = _toks(spec, 16)
        logits, state = tfm.prefill(params, m, toks, max_len=24,
                                    dtype=jnp.float32)
        assert logits.shape == (B, m.vocab)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits2, state = tfm.decode_step(params, m, tok, state)
        assert logits2.shape == (B, m.vocab)
        assert bool(jnp.isfinite(logits2).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dimensions(arch):
    """The full (dry-run) configs carry the exact published dimensions."""
    spec = get_spec(arch)
    m = spec.model
    expected = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
    }[arch]
    nl = m.n_layers if not spec.is_encdec else (m.n_enc_layers
                                                + m.n_dec_layers)
    assert (nl, m.d_model, m.n_heads, m.n_kv_heads, m.d_ff,
            m.vocab) == expected, arch


def test_param_counts_plausible():
    """Analytic parameter counts should land near the published sizes."""
    cases = {
        "recurrentgemma-2b": (2.0e9, 3.5e9),
        "rwkv6-3b": (2.5e9, 3.8e9),
        "mixtral-8x7b": (42e9, 50e9),
        "llama4-maverick-400b-a17b": (350e9, 440e9),
        "gemma2-9b": (8e9, 11e9),
        "qwen2-0.5b": (0.35e9, 0.65e9),
        "qwen2-7b": (6.5e9, 8.5e9),
        "gemma3-4b": (3.2e9, 5e9),
    }
    for arch, (lo, hi) in cases.items():
        n = get_spec(arch).model.num_params()
        assert lo <= n <= hi, (arch, f"{n:.3e}")
    # active params: llama4 ~17B, mixtral ~13B
    a = get_spec("llama4-maverick-400b-a17b").model.active_params()
    assert 10e9 <= a <= 25e9, a
    a = get_spec("mixtral-8x7b").model.active_params()
    assert 10e9 <= a <= 16e9, a


def test_long_500k_policy():
    """Sub-quadratic archs run long_500k; pure full-attention archs skip."""
    runs = {a: get_spec(a).runs("long_500k") for a in ARCH_IDS}
    assert runs["rwkv6-3b"] and runs["recurrentgemma-2b"]
    assert runs["mixtral-8x7b"] and runs["gemma3-4b"]
    assert not runs["qwen2-7b"] and not runs["qwen2-0.5b"]
    assert not runs["qwen2-vl-7b"] and not runs["seamless-m4t-large-v2"]
