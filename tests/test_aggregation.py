"""Aggregation policies (DESIGN.md §7): sync / semi_sync(K) / async_buffered.

Contracts:

* **Neutral-settings equivalence** — ``semi_sync(K = clients_per_round)``
  and ``async_buffered(capacity = clients_per_round, alpha = 0)``
  reproduce the sync engine's metrics **bit-identically** (params allclose;
  the async server update is applied in delta form) for all four
  algorithms, composed with §5 straggler schedules, EF, and the §6
  ``shard_map`` mesh at every realisable shard count;
* **Semi-sync semantics** — the server waits for the K-th smallest finish
  time (``sim_time`` drops accordingly); excluded stragglers transmit
  nothing, keep their control variates, and are excluded from the average;
* **Async semantics** — arrivals ordered by finish time, one staleness
  level per buffer flush, weights ``1/(1+staleness)^alpha``, uplink bits
  unchanged (buffering permutes application order, never payloads);
* validation fails fast on unrealisable policies.

Runs on the single-device path by default; under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI matrix's
second leg) the mesh sweep covers 1/2/4/8-way sharding.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import TopK
from repro.core import fed_data, server
from repro.core.aggregation import (
    AggregationPolicy, HierarchicalPolicy, apply_policy, uses_delta_combine,
    validate_policy)
from repro.core.baselines import FedAvg, FedConfig, FedDyn, Scaffold
from repro.core.clients import ClientProfile, ClientSchedule
from repro.core.distributed import usable_shard_counts
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.launch.mesh import make_client_mesh

jax.config.update("jax_platform_name", "cpu")

N_CLIENTS, DIM, S, ROUNDS = 8, 6, 4, 4

# every metric except the trajectory-dependent loss is structural
# accounting and must survive the policy change bit-for-bit
APPROX_METRICS = ("train_loss",)


def quadratic_data(n_clients=N_CLIENTS, d=DIM, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, d))
    b = rng.normal(size=(n_clients,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(n_clients)]
    return fed_data.from_numpy_partition(x, y, parts)


def sq_loss(params, xb, yb):
    return 0.5 * jnp.mean((xb @ params["w"] - yb) ** 2)


DATA = quadratic_data()
P0 = {"w": jnp.zeros((DIM,), jnp.float32)}

NEUTRAL = [
    ("semi_sync", AggregationPolicy.semi_sync(S)),
    ("async_buffered", AggregationPolicy.async_buffered(S, 0.0)),
]


def lognormal_schedule(*, drop=False):
    return ClientSchedule(
        profile=ClientProfile.lognormal(N_CLIENTS, speed_sigma=1.0, seed=3),
        deadline=3.0 if drop else None, drop_stragglers=drop, bit_cost=1e-6)


def build(name, policy=None):
    if name.startswith("fedcomloc"):
        cfg = FedComLocConfig(gamma=0.05, p=0.2, n_clients=N_CLIENTS,
                              clients_per_round=S, batch_size=4,
                              variant="com",
                              error_feedback=name == "fedcomloc_ef")
        sched = lognormal_schedule(drop=name == "fedcomloc_drop")
        return FedComLoc(sq_loss, DATA, cfg, TopK(density=0.5),
                         schedule=sched, policy=policy)
    fed = FedConfig(gamma=0.05, local_steps=5, n_clients=N_CLIENTS,
                    clients_per_round=S, batch_size=4)
    sched = lognormal_schedule(drop=name == "fedavg_drop")
    if name.startswith("fedavg"):
        return FedAvg(sq_loss, DATA, fed, TopK(density=0.5),
                      schedule=sched, policy=policy)
    if name == "scaffold":
        return Scaffold(sq_loss, DATA, fed, schedule=sched, policy=policy)
    if name == "feddyn":
        return FedDyn(sq_loss, DATA, fed, schedule=sched, policy=policy)
    raise ValueError(name)


ALGORITHMS = ["fedcomloc", "fedcomloc_ef", "fedcomloc_drop",
              "fedavg", "fedavg_drop", "scaffold", "feddyn"]


def run_fused(alg, rounds=ROUNDS, seed=9):
    state, metrics = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(seed),
                                    rounds)
    return state, metrics


@pytest.fixture(scope="module")
def sync_refs():
    return {name: run_fused(build(name)) for name in ALGORITHMS}


def assert_matches_sync(m_ref, st_ref, m, st, label):
    for k in m_ref:
        if k in APPROX_METRICS:
            np.testing.assert_allclose(m_ref[k], m[k], rtol=1e-5,
                                       atol=1e-7, err_msg=f"{label} {k}")
        else:
            np.testing.assert_array_equal(m_ref[k], m[k],
                                          err_msg=f"{label} {k}")
    # params are allclose, not bit-identical: the policy paths aggregate
    # via masked/delta forms whose reductions XLA may fuse differently
    np.testing.assert_allclose(np.asarray(st_ref.x["w"]),
                               np.asarray(st.x["w"]),
                               rtol=1e-5, atol=1e-6,
                               err_msg=f"{label} params")


# --------------------------------------------------------------------------- #
# 1. Neutral settings reproduce sync — every algorithm, every shard count
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", ALGORITHMS)
@pytest.mark.parametrize("pol_name,policy", NEUTRAL)
def test_neutral_policy_matches_sync(name, pol_name, policy, sync_refs):
    st_ref, m_ref = sync_refs[name]
    st, m = run_fused(build(name, policy))
    assert_matches_sync(m_ref, st_ref, m, st, f"{name}/{pol_name}")


@pytest.mark.parametrize("pol_name,policy", NEUTRAL)
def test_neutral_policy_matches_sync_on_mesh(pol_name, policy, sync_refs):
    """Policy x §6 mesh cross-product: metrics bit-identical to the
    unsharded sync reference at every realisable device count."""
    for name in ("fedcomloc", "fedcomloc_drop", "feddyn"):
        st_ref, m_ref = sync_refs[name]
        for n_shards in usable_shard_counts(S):
            alg = build(name, policy).use_mesh(make_client_mesh(n_shards))
            st, m = run_fused(alg)
            assert_matches_sync(m_ref, st_ref, m, st,
                                f"{name}/{pol_name} D={n_shards}")


@pytest.mark.parametrize("policy", [
    AggregationPolicy.semi_sync(2),
    AggregationPolicy.async_buffered(2, 0.5),
])
def test_non_neutral_policies_device_count_invariant(policy):
    """Non-neutral policies: metrics bit-identical across shard counts
    (the policy outcome is computed from replicated full vectors)."""
    ref = None
    for n_shards in usable_shard_counts(S):
        alg = build("fedcomloc", policy).use_mesh(make_client_mesh(n_shards))
        st, m = run_fused(alg)
        if ref is None:
            ref = (st, m)
            continue
        for k in m:
            if k in APPROX_METRICS:
                np.testing.assert_allclose(ref[1][k], m[k], rtol=1e-5,
                                           atol=1e-7, err_msg=k)
            else:
                np.testing.assert_array_equal(ref[1][k], m[k], err_msg=k)
        np.testing.assert_allclose(np.asarray(ref[0].x["w"]),
                                   np.asarray(st.x["w"]),
                                   rtol=1e-5, atol=1e-6)


def test_policy_matches_per_round_driver():
    """Both drivers agree under a non-neutral policy (same key chain)."""
    policy = AggregationPolicy.async_buffered(2, 0.5)
    alg_a, alg_b = build("fedcomloc", policy), build("fedcomloc", policy)
    sb, fused = run_fused(alg_b)
    state = alg_a.init(P0)
    key = jax.random.PRNGKey(9)
    for r in range(ROUNDS):
        key, sub = jax.random.split(key)
        state, m = alg_a.round(state, sub)
        assert m["uplink_bits"] == float(fused["uplink_bits"][r])
        np.testing.assert_array_equal(m["client_staleness"],
                                      fused["client_staleness"][r])
    np.testing.assert_array_equal(np.asarray(state.x["w"]),
                                  np.asarray(sb.x["w"]))
    assert alg_a.meter.snapshot() == alg_b.meter.snapshot()


# --------------------------------------------------------------------------- #
# 2. Semi-sync semantics
# --------------------------------------------------------------------------- #

def test_semi_sync_waits_for_kth_finish(sync_refs):
    """sim_time == K-th smallest finish; the K fastest aggregate, the rest
    transmit nothing."""
    k = 2
    _, m = run_fused(build("fedcomloc", AggregationPolicy.semi_sync(k)))
    _, m_sync = sync_refs["fedcomloc"]
    for r in range(ROUNDS):
        finish = np.sort(np.asarray(m["client_finish"][r]))
        assert m["sim_time"][r] == finish[k - 1]
        assert m["clients_aggregated"][r] == k      # generic float finishes
        bits = np.asarray(m["client_uplink_bits"][r])
        assert (bits == 0).sum() == S - k
        assert m["uplink_bits"][r] == bits.sum()
    # the server stops waiting for the tail: never slower than sync
    assert (m["sim_time"] <= m_sync["sim_time"] + 1e-6).all()
    assert m["sim_time"].sum() < 0.7 * m_sync["sim_time"].sum()


def test_semi_sync_excluded_clients_keep_control_variates():
    """An excluded straggler must look exactly like a §5 dropped one:
    untouched h, no uplink payload."""
    n, d = 5, 6
    data = quadratic_data(n, d)
    speed = np.ones(n, np.float32)
    speed[0] = 1e-3                       # client 0 always finishes last
    sched = ClientSchedule(
        profile=ClientProfile(speed=jnp.asarray(speed),
                              bandwidth=jnp.ones((n,), jnp.float32)))
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=n, batch_size=4, variant="com")
    alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.5), schedule=sched,
                    policy=AggregationPolicy.semi_sync(n - 1))
    state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
    state, m = alg.round(state, jax.random.PRNGKey(0))
    finish = np.asarray(m["client_finish"])
    bits = np.asarray(m["client_uplink_bits"])
    assert bits[np.argmax(finish)] == 0.0         # the slow client sent 0
    assert m["clients_aggregated"] == n - 1
    h = np.asarray(state.h["w"])                  # rows follow client ids
    assert np.all(h[0] == 0.0)                    # variate untouched
    assert np.all(np.any(h[1:] != 0.0, axis=1))


def test_semi_sync_with_drops_counts_only_real_reports():
    """A §5-dropped straggler never finishes, so its deadline-held finish
    must not crowd a real report out of the K-fastest selection.  Two
    clients drop at deadline=2.0 while the three participants' uplink
    pushes their finish past it: semi_sync(2) must still aggregate 2
    *real* updates, at the 2nd participant arrival on the clock."""
    n, d = 5, 8
    data = quadratic_data(n, d)
    speed = np.asarray([1e-3, 1e-3, 1.0, 1.2, 1.4], np.float32)
    sched = ClientSchedule(
        profile=ClientProfile(speed=jnp.asarray(speed),
                              bandwidth=jnp.full((n,), 0.01, jnp.float32)),
        deadline=2.0, drop_stragglers=True, bit_cost=1e-1)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=n, batch_size=4, variant="com")
    alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.5), schedule=sched,
                    policy=AggregationPolicy.semi_sync(2))
    state = alg.init({"w": jnp.ones((d,), jnp.float32)})
    state, m = alg.round(state, jax.random.PRNGKey(0))
    assert (np.asarray(m["client_steps"]) == 0).sum() == 2   # 2 dropped
    assert m["clients_aggregated"] == 2.0                    # 2 real reports
    bits = np.asarray(m["client_uplink_bits"])
    assert (bits > 0).sum() == 2
    # the clock stops at the 2nd-fastest *participant* arrival, which is
    # later than the dropped clients' deadline-held 2.0
    finish = np.asarray(m["client_finish"])
    part_finish = np.sort(finish[np.asarray(m["client_steps"]) > 0])
    assert m["sim_time"] == part_finish[1] > 2.0
    # the server moved (participants were aggregated, not the empty set)
    assert not np.allclose(np.asarray(state.x["w"]), 1.0)


def test_semi_sync_fewer_participants_than_k_holds_to_deadline():
    """K larger than the surviving cohort: every real report is applied
    and the dropped stragglers hold the round until the deadline."""
    n, d = 4, 6
    data = quadratic_data(n, d)
    speed = np.asarray([1e-3, 1e-3, 1e-3, 1.0], np.float32)
    sched = ClientSchedule(
        profile=ClientProfile(speed=jnp.asarray(speed),
                              bandwidth=jnp.ones((n,), jnp.float32)),
        deadline=10.0, drop_stragglers=True)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=n, batch_size=4, variant="com")
    alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.5), schedule=sched,
                    policy=AggregationPolicy.semi_sync(3))
    state, m = alg.round(alg.init(P0), jax.random.PRNGKey(0))
    assert (np.asarray(m["client_steps"]) == 0).sum() == 3
    assert m["clients_aggregated"] == 1.0     # the one real report applied
    assert m["sim_time"] == pytest.approx(10.0)   # deadline-held round


def test_semi_sync_ties_all_kept():
    """Homogeneous finishes: threshold semantics keeps every tie at the
    K-th finish, so K < s degenerates to sync (all arrive together)."""
    alg_k = build("fedavg", AggregationPolicy.semi_sync(2))
    alg_k.sched = dataclasses.replace(
        alg_k.sched, profile=ClientProfile.homogeneous(N_CLIENTS))
    _, m = run_fused(alg_k)
    np.testing.assert_array_equal(np.asarray(m["clients_aggregated"]),
                                  np.full((ROUNDS,), float(S)))


# --------------------------------------------------------------------------- #
# 3. Async-buffered semantics
# --------------------------------------------------------------------------- #

def test_async_staleness_levels_follow_arrival_order():
    """capacity=2 of s=4: the 2 earliest arrivals flush at staleness 0,
    the next 2 at staleness 1; uplink bits match sync exactly."""
    policy = AggregationPolicy.async_buffered(2, 0.5)
    _, m = run_fused(build("fedcomloc", policy))
    _, m_sync = run_fused(build("fedcomloc"))
    for r in range(ROUNDS):
        finish = np.asarray(m["client_finish"][r])
        stale = np.asarray(m["client_staleness"][r])
        order = np.argsort(finish)
        np.testing.assert_array_equal(stale[order], [0.0, 0.0, 1.0, 1.0])
    # buffering never changes what is on the wire
    np.testing.assert_array_equal(m["uplink_bits"], m_sync["uplink_bits"])
    np.testing.assert_array_equal(m["client_uplink_bits"],
                                  m_sync["client_uplink_bits"])
    np.testing.assert_array_equal(m["sim_time"], m_sync["sim_time"])


def test_async_server_applies_staleness_weighted_flushes():
    """Exact weighting algebra.  With capacity=2 of s=4 the server step is
    ``mean_0 + 2^{-alpha} * mean_1`` (flush means, staleness weights
    ``(1+j)^{-alpha}``).  Two alphas pin down mean_0/mean_1 — the model at
    any third alpha must then be fully determined."""

    def step(alpha):
        alg = build("fedavg", AggregationPolicy.async_buffered(2, alpha))
        state, _ = alg.round(alg.init(P0), jax.random.PRNGKey(5))
        return np.asarray(state.x["w"], np.float64)

    s0, s1 = step(0.0), step(1.0)             # mean0+mean1, mean0+mean1/2
    mean1 = 2.0 * (s0 - s1)
    mean0 = s0 - mean1
    np.testing.assert_allclose(step(2.0), mean0 + 0.25 * mean1,
                               rtol=1e-4, atol=1e-6)


def test_async_alpha_zero_applies_full_cohort():
    """alpha=0 with capacity<s: every flush at weight 1, so the server
    takes s/capacity buffer-mean steps — from x0 = 0 with equal flush
    sizes, exactly twice the single sync step."""
    st_sync, _ = run_fused(build("fedavg"), rounds=1)
    st, _ = run_fused(
        build("fedavg", AggregationPolicy.async_buffered(2, 0.0)), rounds=1)
    np.testing.assert_allclose(np.asarray(st.x["w"]),
                               2.0 * np.asarray(st_sync.x["w"]),
                               rtol=1e-5, atol=1e-7)


# --------------------------------------------------------------------------- #
# 4. Plumbing: server driver, engine rebinding, validation
# --------------------------------------------------------------------------- #

def test_run_federated_accepts_policy():
    alg = build("fedcomloc")
    hist = server.run_federated(
        alg, P0, num_rounds=3, key=jax.random.PRNGKey(2),
        policy=AggregationPolicy.semi_sync(2))
    assert alg.policy.mode == "semi_sync"
    assert alg.meter.rounds == 3
    assert hist.final_params is not None


def test_set_policy_rebinds_and_is_idempotent():
    alg = build("fedcomloc")
    assert alg.policy.is_sync
    fused = alg._fused(2)
    assert alg.set_policy(None) is alg          # no-op: cache kept
    assert alg._fused(2) is fused
    alg.set_policy(AggregationPolicy.semi_sync(2))
    assert alg._fused(2) is not fused           # caches cleared
    st, m = run_fused(alg)
    assert (np.asarray(m["clients_aggregated"]) == 2.0).all()


def test_set_policy_after_traced_round_retraces():
    """Regression: pjit's trace cache keys on the wrapped callable, and a
    bound ``_round_impl`` compares equal across accesses — a rebind after
    the first traced round must not silently reuse the old policy's graph
    (``RoundEngine._rebind_impl`` wraps a fresh closure per rebind)."""
    alg = build("fedcomloc")
    _, m_sync = alg.round(alg.init(P0), jax.random.PRNGKey(0))
    alg.set_policy(AggregationPolicy.semi_sync(1))
    _, m_rebound = alg.round(alg.init(P0), jax.random.PRNGKey(0))
    ref = build("fedcomloc", AggregationPolicy.semi_sync(1))
    _, m_fresh = ref.round(ref.init(P0), jax.random.PRNGKey(0))
    assert m_rebound["clients_aggregated"] == m_fresh["clients_aggregated"]
    assert m_rebound["sim_time"] == m_fresh["sim_time"]
    assert m_rebound["sim_time"] != m_sync["sim_time"]


def test_policy_validation():
    with pytest.raises(ValueError, match="wait_for"):
        validate_policy(AggregationPolicy.semi_sync(S + 1), S)
    with pytest.raises(ValueError, match="divide"):
        validate_policy(AggregationPolicy.async_buffered(3), S)
    with pytest.raises(ValueError, match="mode"):
        AggregationPolicy(mode="nope")
    with pytest.raises(ValueError):
        AggregationPolicy(mode="sync", capacity=2)
    with pytest.raises(ValueError):
        AggregationPolicy(mode="async_buffered", alpha=-1.0)
    with pytest.raises(TypeError):
        validate_policy("semi_sync", S)
    # defaults resolve to the neutral settings
    assert validate_policy(
        AggregationPolicy.async_buffered(), S).capacity == S
    assert validate_policy(
        AggregationPolicy(mode="semi_sync"), S).wait_for == S
    # constructor-level validation fires through the algorithms too
    with pytest.raises(ValueError, match="divide"):
        build("fedcomloc", AggregationPolicy.async_buffered(3))


def test_launch_config_policy_validation():
    from repro.launch import fed_train
    fed = fed_train.FedTrainConfig(aggregation="semi_sync", wait_for=2)
    assert fed.aggregation_policy().mode == "semi_sync"
    with pytest.raises(ValueError, match="unknown aggregation"):
        fed_train.FedTrainConfig(aggregation="nope").aggregation_policy()
    # stray knobs for a different mode fail fast, never silently drop
    with pytest.raises(ValueError, match="wait_for"):
        fed_train.FedTrainConfig(aggregation="sync",
                                 wait_for=4).aggregation_policy()
    with pytest.raises(ValueError, match="capacity"):
        fed_train.FedTrainConfig(aggregation="semi_sync", wait_for=2,
                                 buffer_capacity=2).aggregation_policy()
    with pytest.raises(ValueError, match="wait_for"):
        fed_train.FedTrainConfig(aggregation="async_buffered",
                                 wait_for=2).aggregation_policy()


# --------------------------------------------------------------------------- #
# 6. Hierarchical edge→server aggregation (DESIGN.md §11)
# --------------------------------------------------------------------------- #

def hier(edge=None, server=None, n_edges=2, latency=0.0):
    return HierarchicalPolicy(
        edge=edge or AggregationPolicy.sync(),
        server=server or AggregationPolicy.sync(),
        n_edges=n_edges, edge_latency=latency)


@pytest.mark.parametrize("name", ["fedcomloc_ef", "fedavg", "scaffold",
                                  "feddyn"])
def test_hierarchical_sync_sync_equals_flat_sync(name, sync_refs):
    """sync/sync tiers, zero latency, no drops: every edge mean carries
    equal weight, so the mean of edge means IS the client mean and the
    composed outcome reproduces the flat sync policy."""
    m_ref, st_ref = sync_refs[name][1], sync_refs[name][0]
    st, m = run_fused(build(name, hier()))
    assert_matches_sync(m_ref, st_ref, m, st, f"{name} hier-sync")


def plan_with_speeds(speeds, bits=0.0, latency=0.0, **policy_kw):
    """A 4-client cohort (client i = sampled slot i) with given speeds."""
    speeds = jnp.asarray(speeds, jnp.float32)
    sched = ClientSchedule(
        profile=ClientProfile(speed=speeds,
                              bandwidth=jnp.ones_like(speeds)))
    plan = sched.plan(jnp.arange(speeds.shape[0]), nominal_steps=2)
    pol = validate_policy(hier(latency=latency, **policy_kw),
                          speeds.shape[0])
    bits_v = jnp.full(speeds.shape, float(bits), jnp.float32)
    return apply_policy(pol, sched, plan, bits_v), sched, plan


def test_hierarchical_edge_latency_shifts_clock():
    out0, _, _ = plan_with_speeds([1.0, 2.0, 1.0, 0.5])
    out1, _, _ = plan_with_speeds([1.0, 2.0, 1.0, 0.5], latency=7.5)
    # zero-latency sync/sync: the server clock is the slowest client...
    assert float(out0.sim_time) == pytest.approx(2.0 / 0.5)
    # ...and each edge→server hop adds exactly the latency
    assert float(out1.sim_time) == pytest.approx(2.0 / 0.5 + 7.5)
    np.testing.assert_array_equal(np.asarray(out0.participating),
                                  np.asarray(out1.participating))


def test_hierarchical_semi_sync_server_drops_slow_edge():
    """server=semi_sync(1) over 2 edges: the whole slow edge (clients 2,3)
    misses the aggregate; its clients keep state exactly like §5 drops."""
    out, _, _ = plan_with_speeds(
        [1.0, 1.0, 0.01, 0.01],               # edge 1 is 100x slower
        server=AggregationPolicy.semi_sync(1))
    np.testing.assert_array_equal(np.asarray(out.participating),
                                  [True, True, False, False])
    assert float(out.n_selected) == 2.0
    assert float(out.edges_aggregated) == 1.0
    # the server closed on the fast edge's clock
    assert float(out.sim_time) == pytest.approx(2.0)
    # mean-aggregation weights renormalise over the surviving edge
    np.testing.assert_allclose(np.asarray(out.weight), [1.0, 1.0, 0.0, 0.0])


def test_hierarchical_weights_sum_to_n_selected_under_drops():
    """Uneven participation across edges: Σ weight == n_selected (the
    masked_mean divisor), and each edge's clients split the edge's share
    equally — the mean-of-edge-means reweighting."""
    speeds = jnp.asarray([1.0, 1e-3, 1.0, 1.0], jnp.float32)
    sched = ClientSchedule(
        profile=ClientProfile(speed=speeds, bandwidth=jnp.ones((4,))),
        deadline=2.0, drop_stragglers=True)     # client 1 drops (0 steps)
    plan = sched.plan(jnp.arange(4), nominal_steps=2)
    pol = validate_policy(hier(), 4)
    out = apply_policy(pol, sched, plan, jnp.zeros((4,)))
    np.testing.assert_array_equal(np.asarray(out.participating),
                                  [True, False, True, True])
    w = np.asarray(out.weight)
    assert w.sum() == pytest.approx(float(out.n_selected))
    # edge 0 contributes one client at weight 3·(1/2·1/1), edge 1 two at
    # 3·(1/2·1/2): the lone-edge client carries its edge's full mean
    np.testing.assert_allclose(w, [1.5, 0.0, 0.75, 0.75])


def test_hierarchical_async_tier_runs_and_uses_delta_combine():
    pol = hier(edge=AggregationPolicy.async_buffered(1, 0.5))
    assert uses_delta_combine(pol)
    assert not uses_delta_combine(hier())
    assert uses_delta_combine(AggregationPolicy.async_buffered(2))
    assert not uses_delta_combine(AggregationPolicy.sync())
    st, m = run_fused(build("fedcomloc_ef", pol))
    assert np.isfinite(np.asarray(st.x["w"])).all()
    assert np.isfinite(np.asarray(m["train_loss"])).all()
    # per-edge staleness levels surface in the composed staleness vector
    assert np.asarray(m["client_staleness"]).max() >= 1.0
    assert (np.asarray(m["edges_aggregated"]) == 2.0).all()


def test_hierarchical_stepped_matches_fused():
    pol = hier(server=AggregationPolicy.semi_sync(1))
    a, b = build("fedavg", pol), build("fedavg", pol)
    st_f, m_f = run_fused(a)
    state = b.init(P0)
    key = jax.random.PRNGKey(9)
    for r in range(ROUNDS):
        key, sub = jax.random.split(key)
        state, m = b.round(state, sub)
        for k in m:
            np.testing.assert_array_equal(np.asarray(m_f[k])[r],
                                          np.asarray(m[k]),
                                          err_msg=f"r{r} {k}")
    np.testing.assert_array_equal(np.asarray(st_f.x["w"]),
                                  np.asarray(state.x["w"]))


def test_hierarchical_validation():
    assert validate_policy(hier(), 4).mode == "hierarchical"
    with pytest.raises(ValueError, match="must divide"):
        validate_policy(hier(n_edges=3), 4)
    with pytest.raises(ValueError, match="wait_for"):
        # edge tier semi_sync K is checked against the GROUP size s/E
        validate_policy(hier(edge=AggregationPolicy.semi_sync(3)), 4)
    # tier defaults resolve against their own tier width
    pol = validate_policy(hier(server=AggregationPolicy.async_buffered()), 4)
    assert pol.server.capacity == 2
    with pytest.raises(TypeError, match="tiers must be flat"):
        HierarchicalPolicy(edge=hier())
    with pytest.raises(ValueError, match="n_edges"):
        HierarchicalPolicy(n_edges=0)
    with pytest.raises(ValueError, match="edge_latency"):
        HierarchicalPolicy(edge_latency=-1.0)
    assert hier().may_exclude and not hier().is_sync
