"""Launch-layer units: dry-run HLO parsing, input specs, shape policies,
roofline analysis math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_spec
from repro.configs.base import SHAPES
from repro.launch import steps as steps_mod
from repro.launch.dryrun import _shape_bytes, collective_bytes
from repro.sharding import specs as sh

jax.config.update("jax_platform_name", "cpu")


class TestHLOParsing:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[16,1024]{1,0}") == 16 * 1024 * 4
        assert _shape_bytes("bf16[2,8,256,256]{3,2,1,0}") == 2 * 8 * 256 * 256 * 2
        assert _shape_bytes("(f32[128]{0}, f32[128,896]{1,0})") \
            == 128 * 4 + 128 * 896 * 4
        assert _shape_bytes("pred[64]") == 64

    def test_collective_bytes(self):
        hlo = """
  %all-gather.99 = f32[256,4096,896]{2,1,0} all-gather(%x), channel_id=23
  %all-reduce.1 = (f32[128]{0}, f32[896]{0}) all-reduce(%a, %b), replica_groups=[16,32]<=[512]
  %add.5 = f32[16,16]{1,0} add(%p, %q)
  ROOT %reduce-scatter.2 = bf16[64,64]{1,0} reduce-scatter(%y), channel_id=9
"""
        out = collective_bytes(hlo)
        assert out["all-gather"] == 256 * 4096 * 896 * 4
        assert out["all-reduce"] == (128 + 896) * 4
        assert out["reduce-scatter"] == 64 * 64 * 2
        assert out["all-to-all"] == 0
        assert out["count"] == 3

    def test_non_collective_ops_ignored(self):
        out = collective_bytes("  %x = f32[8]{0} all_gather_start(%y)\n"
                               "  %z = f32[8]{0} add(%x, %x)\n")
        assert out["count"] <= 1  # start variants may or may not match


class TestShardingHelpers:
    def _mesh(self):
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        return Mesh(dev, ("data", "model"))

    def test_sanitize_drops_uneven(self):
        mesh = self._mesh()
        spec = sh._sanitize(P("model", "data"), (256206, 1024), mesh)
        # sizes are 1 on the host mesh so everything divides; fake a check
        assert isinstance(spec, P)

    def test_batch_axis(self):
        mesh = self._mesh()
        assert sh.batch_axis(mesh, 4) == "data"   # 4 % 1 == 0
        # non-divisible case needs a >1 mesh; simulated via _axis_size
        assert sh._axis_size(mesh, ("data", "model")) == 1
        assert sh._axis_size(mesh, None) == 1


class TestShapePolicies:
    def test_adjust_for_shape_caps_only_long(self):
        spec = get_spec("gemma2-9b")
        assert spec.model.long_context_cap == 8192
        adj = steps_mod.adjust_for_shape(spec, "train_4k")
        assert adj.model.long_context_cap is None
        adj = steps_mod.adjust_for_shape(spec, "long_500k")
        assert adj.model.long_context_cap == 8192

    def test_input_shapes_table(self):
        assert SHAPES["train_4k"].global_batch == 256
        assert SHAPES["train_4k"].seq_len == 4096
        assert SHAPES["prefill_32k"].global_batch == 32
        assert SHAPES["decode_32k"].global_batch == 128
        assert SHAPES["long_500k"].seq_len == 524_288
        assert SHAPES["long_500k"].global_batch == 1

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_optimizer_policy(self, arch):
        spec = get_spec(arch)
        name, lr = steps_mod._optimizer_for(spec)
        if arch.startswith("llama4"):
            assert name == "sgd"      # no fp32 adam state at 400B
        else:
            assert name == "adam"


class TestRooflineMath:
    def test_analyze_terms(self):
        from benchmarks.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, analyze
        rec = {
            "status": "ok", "arch": "x", "shape": "train_4k",
            "mesh": "16x16", "n_devices": 256,
            "flops": PEAK_FLOPS,            # => exactly 1 s compute
            "bytes_accessed": HBM_BW * 2,   # => 2 s memory
            "collective_bytes": {"all-gather": LINK_BW * 3,
                                 "all-reduce": 0, "reduce-scatter": 0,
                                 "all-to-all": 0, "collective-permute": 0},
            "per_device_memory": {"argument_bytes": 0, "output_bytes": 0,
                                  "temp_bytes": 2**30, "alias_bytes": 0},
            "model": {"num_params": 10**9, "active_params": 10**9},
        }
        out = analyze(rec)
        assert abs(out["t_compute_s"] - 1.0) < 1e-9
        assert abs(out["t_memory_s"] - 2.0) < 1e-9
        assert abs(out["t_collective_s"] - 3.0) < 1e-9
        assert out["bottleneck"] == "collective"
        # 6ND: 3 (mult) * 2 * 1e9 * (4096*256) / 256 devices
        assert abs(out["model_flops_per_dev"]
                   - 3 * 2 * 1e9 * 4096 * 256 / 256) < 1
        assert out["hbm_gib_per_dev"] == 1.0

    def test_analyze_passthrough_skip(self):
        from benchmarks.roofline import analyze
        rec = {"status": "skipped", "arch": "a", "shape": "s", "reason": "r"}
        assert analyze(rec)["status"] == "skipped"


class TestFedInt8Sync:
    def test_int8_round_runs_and_learns(self):
        import dataclasses

        from repro.configs.base import reduced
        from repro.data import synthetic
        from repro.launch import fed_train
        from repro.models import transformer as tfm

        spec = reduced(get_spec("qwen2-0.5b"))
        m = dataclasses.replace(spec.model, n_layers=1, d_model=64,
                                d_ff=128, vocab=64, n_heads=2,
                                n_kv_heads=1, head_dim=32,
                                dtype=jnp.float32)
        spec = dataclasses.replace(spec, model=m)
        dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
        mesh = Mesh(dev, ("pod", "data", "model"))
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                    global_batch=4)
        fed = fed_train.FedTrainConfig(gamma=0.3, local_steps=4,
                                       compressor="quant", quant_bits=7,
                                       sync_mode="int8")
        b = fed_train.build_fed_round(spec, shape, mesh, fed)
        params = tfm.init_params(jax.random.PRNGKey(0), m)
        stack = lambda t: jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (1,) + x.shape), t)
        ps, hs = stack(params), stack(
            jax.tree_util.tree_map(jnp.zeros_like, params))
        toks = jnp.asarray(synthetic.make_lm_tokens(64, 4, 64, seed=0)
                           ).reshape(1, 4, 64)
        with mesh:
            step = jax.jit(b.fn, in_shardings=b.in_shardings,
                           out_shardings=b.out_shardings)
            losses = []
            key = jax.random.PRNGKey(1)
            for _ in range(6):
                key, sub = jax.random.split(key)
                ps, hs, loss, comm_bits = step(ps, hs, {"tokens": toks}, sub)
                losses.append(float(loss))
        assert losses[-1] < losses[0]
        assert np.isfinite(losses).all()
        # int8 wire payload: 8 bits/scalar + one f32 scale per tensor
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
        n_tensors = len(jax.tree_util.tree_leaves(params))
        assert float(comm_bits) == n_params * 8 + n_tensors * 32
