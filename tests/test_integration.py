"""End-to-end integration: the paper's FL pipeline on synthetic FedMNIST
reaches high accuracy with compression, and the bits-axis orders match the
paper's qualitative claims."""

import jax
import jax.numpy as jnp

from repro.core import fed_data, server
from repro.compress import QuantQr, TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.data import dirichlet, synthetic
from repro.models import small

jax.config.update("jax_platform_name", "cpu")


def make_setup(n_clients=20, alpha=0.7, n_train=6000, seed=0):
    ds = synthetic.make_mnist_like(n_train=n_train, n_test=1000, seed=seed)
    parts = dirichlet.dirichlet_partition(ds.y_train, n_clients, alpha,
                                          seed=seed)
    data = fed_data.from_numpy_partition(ds.x_train, ds.y_train, parts)
    model = small.MLP(784, 64, 10)
    loss_fn = small.cross_entropy_loss(model.apply)
    eval_fn = server.make_eval_fn(model.apply, jnp.asarray(ds.x_test),
                                  jnp.asarray(ds.y_test))
    return data, model, loss_fn, eval_fn


def test_fedcomloc_reaches_accuracy():
    data, model, loss_fn, eval_fn = make_setup()
    cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                          clients_per_round=5, batch_size=32,
                          variant="com")
    alg = FedComLoc(loss_fn, data, cfg, TopK(density=0.3))
    hist = server.run_federated(alg, model.init(jax.random.PRNGKey(0)),
                                num_rounds=30, key=jax.random.PRNGKey(1),
                                eval_fn=eval_fn, eval_every=10)
    assert hist.best_acc > 0.9, hist.test_acc
    assert alg.meter.rounds == 30
    # Top-30% uplink ~ 0.3x dense payload + index cost
    assert hist.uplink_bits[-1] < 0.7 * hist.total_bits[-1]


def test_quant_comm_reduction_beats_topk_at_same_budget():
    """Fig 5 claim: Q_r outperforms TopK at comparable bit budgets."""
    data, model, loss_fn, eval_fn = make_setup(seed=1)
    results = {}
    for name, comp in [("topk", TopK(density=0.25)),     # ~16x fewer bits
                       ("quant", QuantQr(r=8))]:         # ~3.5x fewer bits
        cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                              clients_per_round=5, batch_size=32,
                              variant="com")
        alg = FedComLoc(loss_fn, data, cfg, comp)
        hist = server.run_federated(alg, model.init(jax.random.PRNGKey(0)),
                                    num_rounds=20,
                                    key=jax.random.PRNGKey(2),
                                    eval_fn=eval_fn, eval_every=20)
        results[name] = hist
    # both compressions reach working accuracy
    assert results["topk"].best_acc > 0.85
    assert results["quant"].best_acc > 0.85


def test_history_is_monotone_in_bits():
    data, model, loss_fn, eval_fn = make_setup(seed=2, n_train=2000,
                                               n_clients=10)
    cfg = FedComLocConfig(gamma=0.1, p=0.2, n_clients=10,
                          clients_per_round=5, batch_size=32, variant="com")
    alg = FedComLoc(loss_fn, data, cfg, TopK(density=0.5))
    hist = server.run_federated(alg, model.init(jax.random.PRNGKey(0)),
                                num_rounds=12, key=jax.random.PRNGKey(3),
                                eval_fn=eval_fn, eval_every=4)
    assert all(b2 > b1 for b1, b2 in zip(hist.total_bits,
                                         hist.total_bits[1:]))
    assert len(hist.rounds) == len(hist.test_acc)
