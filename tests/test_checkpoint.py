"""Checkpointing (repro/checkpoint/checkpoint.py) — previously untested.

Contracts:

* round-trip: ``save``/``load`` restores any FL state pytree (NamedTuple
  states with nested dicts of arrays, mixed dtypes, empty subtrees) plus
  JSON meta, with shapes/dtypes/values intact;
* atomicity: a failing save leaves no temp files behind and never
  clobbers an existing checkpoint;
* resume: save at round r, reload, continue — the spliced trajectory is
  **bit-identical** to an uninterrupted ``run_rounds`` run (states and
  metrics), because the checkpoint carries the RNG key alongside the
  state and both drivers share one key chain.
"""

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpoint
from repro.compress import TopK
from repro.core import fed_data
from repro.core.aggregation import AggregationPolicy
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------- #
# round-trip
# --------------------------------------------------------------------------- #

def test_roundtrip_nested_tree_and_meta(tmp_path):
    tree = {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
        "stack": (jnp.zeros((2, 3)), jnp.asarray([True, False])),
    }
    path = tmp_path / "ckpt.npz"
    checkpoint.save(path, tree, meta={"round": 7, "tag": "x"})
    out, meta = checkpoint.load(path, like=tree)
    assert meta == {"round": 7, "tag": "x"}
    flat_a = jax.tree_util.tree_leaves_with_path(tree)
    flat_b = jax.tree_util.tree_leaves_with_path(out)
    assert [p for p, _ in flat_a] == [p for p, _ in flat_b]
    for (_, a), (_, b) in zip(flat_a, flat_b):
        assert a.dtype == b.dtype
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_roundtrip_fl_state(tmp_path):
    """A real algorithm state (NamedTuple with empty () subtrees)."""
    alg = make_alg()
    state = alg.init(P0)
    state, _ = alg.round(state, jax.random.PRNGKey(0))
    path = tmp_path / "state.npz"
    checkpoint.save(path, state, meta={"round": 1})
    restored, meta = checkpoint.load(path, like=state)
    assert meta["round"] == 1
    assert type(restored).__name__ == "FedComLocState"
    np.testing.assert_array_equal(np.asarray(state.x["w"]),
                                  np.asarray(restored.x["w"]))
    np.testing.assert_array_equal(np.asarray(state.h["w"]),
                                  np.asarray(restored.h["w"]))
    assert int(restored.round) == 1
    assert restored.e == () and restored.mom == ()


def test_load_without_like_returns_leaves(tmp_path):
    tree = {"a": jnp.ones((3,)), "b": jnp.zeros((2,))}
    path = tmp_path / "c.npz"
    checkpoint.save(path, tree)
    leaves, meta = checkpoint.load(path)
    assert isinstance(leaves, list) and len(leaves) == 2
    assert meta == {}


def test_save_creates_parent_dirs(tmp_path):
    path = tmp_path / "deep" / "er" / "c.npz"
    checkpoint.save(path, {"a": jnp.ones(())})
    assert path.exists()


# --------------------------------------------------------------------------- #
# atomicity
# --------------------------------------------------------------------------- #

def test_failed_save_leaves_no_temp_files(tmp_path, monkeypatch):
    path = tmp_path / "ckpt.npz"

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError, match="disk full"):
        checkpoint.save(path, {"a": jnp.ones((4,))})
    assert not path.exists()
    assert list(tmp_path.iterdir()) == []        # tmp file cleaned up


def test_failed_save_preserves_existing_checkpoint(tmp_path, monkeypatch):
    path = tmp_path / "ckpt.npz"
    checkpoint.save(path, {"a": jnp.full((4,), 3.0)}, meta={"round": 3})

    real_savez = np.savez

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError):
        checkpoint.save(path, {"a": jnp.full((4,), 9.0)}, meta={"round": 9})
    monkeypatch.setattr(np, "savez", real_savez)
    out, meta = checkpoint.load(path, like={"a": jnp.zeros((4,))})
    assert meta == {"round": 3}                  # old checkpoint intact
    np.testing.assert_array_equal(np.asarray(out["a"]), 3.0)
    assert [p.name for p in tmp_path.iterdir()] == ["ckpt.npz"]


def test_successful_save_leaves_only_the_checkpoint(tmp_path):
    path = tmp_path / "ckpt.npz"
    checkpoint.save(path, {"a": jnp.ones((4,))})
    assert [p.name for p in tmp_path.iterdir()] == ["ckpt.npz"]


# --------------------------------------------------------------------------- #
# mid-run resume == uninterrupted run, bit-identically
# --------------------------------------------------------------------------- #

def quadratic_setup(n_clients=5, d=6, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, d))
    b = rng.normal(size=(n_clients,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(n_clients)]
    return fed_data.from_numpy_partition(x, y, parts)


def sq_loss(params, xb, yb):
    return 0.5 * jnp.mean((xb @ params["w"] - yb) ** 2)


N, D = 5, 6
P0 = {"w": jnp.zeros((D,), jnp.float32)}


def make_alg(policy=None):
    data = quadratic_setup(N, D)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=N,
                          clients_per_round=3, batch_size=4, variant="com")
    return FedComLoc(sq_loss, data, cfg, TopK(density=0.4), policy=policy)


@pytest.mark.parametrize("policy", [
    # capacity=1 of s=3: three flushes at staleness 0..2 — the genuinely
    # asynchronous path, not the neutral cap=s setting
    None, AggregationPolicy.async_buffered(1, 0.5)])
def test_resume_matches_uninterrupted_run(tmp_path, policy):
    """save at round r + resume == one uninterrupted run_rounds, exactly."""
    R, r_save = 8, 3
    key0 = jax.random.PRNGKey(17)

    # uninterrupted reference: the fused engine over all R rounds
    ref = make_alg(policy)
    ref_state, ref_metrics = ref.run_rounds(ref.init(P0), key0, R)

    # interrupted run: r_save rounds, checkpoint (state + key), new
    # process (fresh algorithm instance), resume for the remaining rounds
    a = make_alg(policy)
    state, _ = a.run_rounds(a.init(P0), key0, r_save)
    key = key0
    for _ in range(r_save):                 # stay on the host key chain
        key, _ = jax.random.split(key)
    path = tmp_path / "mid.npz"
    checkpoint.save(path, {"state": state, "key": key},
                    meta={"rounds_done": r_save})

    b = make_alg(policy)                    # simulates a fresh process
    like = {"state": b.init(P0), "key": key0}
    restored, meta = checkpoint.load(path, like=like)
    assert meta["rounds_done"] == r_save
    state_b, metrics_b = b.run_rounds(restored["state"], restored["key"],
                                      R - r_save)

    np.testing.assert_array_equal(np.asarray(ref_state.x["w"]),
                                  np.asarray(state_b.x["w"]))
    np.testing.assert_array_equal(np.asarray(ref_state.h["w"]),
                                  np.asarray(state_b.h["w"]))
    assert int(state_b.round) == R
    for k in ref_metrics:
        np.testing.assert_array_equal(
            np.asarray(ref_metrics[k])[r_save:], np.asarray(metrics_b[k]),
            err_msg=f"metric {k} after resume")


# --------------------------------------------------------------------------- #
# named load errors: structure mismatch + pre-'dtypes' manifests
# --------------------------------------------------------------------------- #

def test_structure_mismatch_raises_named_error(tmp_path):
    path = tmp_path / "c.npz"
    checkpoint.save(path, {"a": jnp.ones((3,)), "b": jnp.zeros((2,))})
    with pytest.raises(checkpoint.CheckpointStructureError,
                       match="stores 2 leaves but like= has 3"):
        checkpoint.load(path, like={"a": jnp.ones((3,)),
                                    "b": jnp.zeros((2,)),
                                    "c": jnp.zeros(())})
    assert issubclass(checkpoint.CheckpointStructureError, ValueError)


def test_old_manifest_bf16_raises_named_error(tmp_path):
    """A checkpoint written before the manifest recorded dtype names stores
    bfloat16 leaves as opaque void bytes — load must fail with the named
    error instead of handing a raw |V2 array to tree_unflatten."""
    import json

    path = tmp_path / "old.npz"
    leaf = np.asarray(jnp.arange(4, dtype=jnp.bfloat16))
    manifest = {"treedef": "PyTreeDef({'a': *})", "meta": {}, "n_leaves": 1}
    np.savez(path, __manifest__=json.dumps(manifest), leaf_0=leaf)
    with pytest.raises(checkpoint.CheckpointDtypeError,
                       match="predates the 'dtypes' field"):
        checkpoint.load(path, like={"a": jnp.zeros((4,), jnp.bfloat16)})
    assert issubclass(checkpoint.CheckpointDtypeError, ValueError)
    # non-extension dtypes in old checkpoints still load fine
    path2 = tmp_path / "old_f32.npz"
    np.savez(path2, __manifest__=json.dumps(manifest),
             leaf_0=np.arange(4, dtype=np.float32))
    out, _ = checkpoint.load(path2, like={"a": jnp.zeros((4,))})
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.arange(4, dtype=np.float32))


def test_current_writer_roundtrips_bf16(tmp_path):
    """The current manifest records dtype names, so extension dtypes
    view-cast back losslessly."""
    path = tmp_path / "bf16.npz"
    tree = {"a": jnp.asarray([1.5, -2.25, 0.0], jnp.bfloat16)}
    checkpoint.save(path, tree)
    out, _ = checkpoint.load(path, like=tree)
    assert out["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
