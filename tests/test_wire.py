"""Wire codec layer (DESIGN.md §8): packed payloads, reconcile, rounds.

Four contracts:

1. pack/unpack round-trips exactly at the edges — k=0 (empty support) and
   k=n (dense), r=1 and r=8, bf16 leaves, odd sizes that don't fill a
   uint32 word, and under ``vmap`` over a client axis — with the Pallas
   kernel (interpret mode) matching the jnp reference bit-for-bit;
2. ``decode(encode(tree))`` equals the transform's ``compress`` output and
   the returned ``BitsReport`` equals the transform's, for every supported
   compressor x scope;
3. measured payload bytes reconcile **in-graph** with the accounted bits:
   ``payload.nbytes == ceil(report.total_bits / 8)`` up to the documented
   word-padding slack (closed forms pinned below);
4. ``wire="packed"`` rounds match account-only rounds — params allclose,
   accounted bit metrics identical — for all four algorithms, and a
   deadline-dropped / policy-excluded client contributes a zero-length
   (fully masked) payload under ``semi_sync`` and ``async_buffered``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import (
    Compose, Identity, Int8Sync, QuantQr, TopK, wire)
from repro.core import aggregation, fed_data
from repro.core.baselines import FedAvg, FedConfig, FedDyn, Scaffold
from repro.core.clients import ClientProfile, ClientSchedule, mask_payload
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.kernels import pack_codes as pack_kernel
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def tree_of(seed, shapes, dtype=jnp.float32):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return {f"p{i}": jax.random.normal(k, s).astype(dtype)
            for i, (k, s) in enumerate(zip(keys, shapes))}


# odd sizes that don't fill a uint32 word (33, 67), plus a word-aligned one
SHAPES = [(33,), (8, 8), (67,)]


# --------------------------------------------------------------------------- #
# 1. pack/unpack kernels
# --------------------------------------------------------------------------- #

class TestPackCodes:
    @pytest.mark.parametrize("n", [1, 31, 32, 33, 100, 1024, 1030])
    @pytest.mark.parametrize("b", [1, 2, 9, 17, 32])
    def test_roundtrip_and_kernel_parity(self, n, b):
        rng = np.random.default_rng(n * 37 + b)
        hi = 2 ** min(b, 31)
        codes = jnp.asarray(rng.integers(0, hi, n), jnp.uint32)
        words = kref.pack_codes(codes, b)
        assert words.shape == (-(-n // 32) * b,)
        np.testing.assert_array_equal(
            np.asarray(kref.unpack_codes(words, b, n)), np.asarray(codes))
        # Pallas kernel (interpret) is bit-identical to the reference
        np.testing.assert_array_equal(
            np.asarray(pack_kernel.pack_codes(codes, b, interpret=True)),
            np.asarray(words))
        np.testing.assert_array_equal(
            np.asarray(pack_kernel.unpack_codes(words, b, n,
                                                interpret=True)),
            np.asarray(codes))

    def test_vmap(self):
        rng = np.random.default_rng(0)
        codes = jnp.asarray(rng.integers(0, 32, (4, 45)), jnp.uint32)
        words = jax.vmap(lambda c: kref.pack_codes(c, 5))(codes)
        back = jax.vmap(lambda w: kref.unpack_codes(w, 5, 45))(words)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))

    def test_validation(self):
        with pytest.raises(ValueError):
            kref.pack_codes(jnp.zeros((4,), jnp.uint32), 33)
        with pytest.raises(ValueError):
            kref.unpack_codes(jnp.zeros((3,), jnp.uint32), 2, 100)


# --------------------------------------------------------------------------- #
# 2. encode/decode == transform, report identical
# --------------------------------------------------------------------------- #

CODECS = [
    ("identity", Identity(), False),
    ("topk", TopK(density=0.1), False),
    ("topk-k1", TopK(density=0.01), False),        # k = max(1, ...) floor
    ("topk-dense", TopK(density=1.0), False),      # k = n: dense payload
    ("topk-global", TopK(density=0.3, scope="global"), False),
    ("qr-r1", QuantQr(r=1), True),
    ("qr-r8", QuantQr(r=8), True),
    ("qr-global", QuantQr(r=4, scope="global"), True),
    ("compose", Compose(TopK(0.25), QuantQr(4)), True),
    ("compose-global", Compose(TopK(0.2, scope="global"),
                               QuantQr(3, scope="global")), True),
    ("compose-dense", Compose(TopK(1.0), QuantQr(4)), True),
    ("int8", Int8Sync(), True),
]


def assert_wire_matches_transform(comp, tree, rng, exact=True):
    out_t, rep_t = comp.compress(tree, rng)
    payload, rep_w = wire.encode(comp, tree, rng)
    dec = wire.decode(payload)
    for k in tree:
        if exact:
            np.testing.assert_array_equal(np.asarray(out_t[k]),
                                          np.asarray(dec[k]), err_msg=k)
        else:
            np.testing.assert_allclose(np.asarray(out_t[k]),
                                       np.asarray(dec[k]), err_msg=k)
    for f in ("value_bits", "index_bits", "meta_bits"):
        assert float(getattr(rep_t, f)) == float(getattr(rep_w, f)), f
    assert float(wire.padding_bits(payload, rep_w)) >= 0
    return payload, rep_w


class TestEncodeDecode:
    @pytest.mark.parametrize("name,comp,needs_rng", CODECS)
    @pytest.mark.parametrize("seed", range(3))
    def test_matches_transform(self, name, comp, needs_rng, seed):
        tree = tree_of(seed, SHAPES)
        rng = jax.random.PRNGKey(seed + 100) if needs_rng else None
        assert_wire_matches_transform(comp, tree, rng)

    @pytest.mark.parametrize("name,comp,needs_rng", [
        ("topk", TopK(density=0.2), False),
        ("qr-r4", QuantQr(r=4), True),
        ("compose", Compose(TopK(0.25), QuantQr(4)), True),
        ("int8", Int8Sync(), True),
    ])
    def test_bf16_leaves(self, name, comp, needs_rng):
        tree = tree_of(7, SHAPES, dtype=jnp.bfloat16)
        rng = jax.random.PRNGKey(9) if needs_rng else None
        payload, rep = assert_wire_matches_transform(comp, tree, rng)
        if name == "topk":
            # bf16 values ship 16 bits each — both in the report and in the
            # packed value buffer (satellite: dtype-derived value bits)
            nnz = float(rep.index_bits) / 32
            assert float(rep.value_bits) == nnz * 16
            for idx, vals in payload.data:
                assert vals.dtype == jnp.bfloat16

    def test_empty_support(self):
        """k=0 edge: an all-zero tree (an EF innovation that vanished)
        packs to sentinel-only slots and decodes to zeros, with 0 bits
        accounted."""
        z = {k: jnp.zeros_like(v) for k, v in tree_of(0, SHAPES).items()}
        payload, rep = wire.encode(TopK(density=0.1), z)
        dec = wire.decode(payload)
        assert all(np.all(np.asarray(v) == 0) for v in dec.values())
        assert float(rep.total_bits) == 0
        # documented slack: every static slot is empty
        caps = payload.spec.caps
        assert float(wire.padding_bits(payload, rep)) == sum(
            c * (32 + 32) for c in caps)

    def test_vmap_client_axis(self):
        comp = Compose(TopK(0.25), QuantQr(4))
        tree = tree_of(3, SHAPES)
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.stack([x, 2 * x, -x, 0 * x]), tree)
        keys = jax.random.split(jax.random.PRNGKey(5), 4)
        payload, rep = jax.vmap(
            lambda t, k: wire.encode(comp, t, k))(stacked, keys)
        dec = jax.vmap(wire.decode)(payload)
        out_t, rep_t = jax.vmap(comp.compress)(stacked, keys)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(out_t[k]),
                                          np.asarray(dec[k]))
        np.testing.assert_array_equal(np.asarray(rep_t.total_bits),
                                      np.asarray(rep.total_bits))
        # spec (and so nbytes) stays per-client under vmap
        assert payload.nbytes == wire.payload_nbytes(comp, tree)

    def test_unbatched_unit_buffers_have_static_shapes(self):
        p, _ = wire.encode(TopK(density=0.1), tree_of(0, SHAPES))
        for (idx, vals), cap in zip(p.data, p.spec.caps):
            assert idx.shape == (cap,) and idx.dtype == jnp.uint32
            assert vals.shape == (cap,)


# --------------------------------------------------------------------------- #
# 3. in-graph reconcile: nbytes == ceil(total_bits / 8) + bounded slack
# --------------------------------------------------------------------------- #

class TestReconcile:
    @pytest.mark.parametrize("name,comp,needs_rng", CODECS)
    def test_in_graph_reconcile(self, name, comp, needs_rng):
        """Inside jit: measured bytes equal ceil(accounted bits / 8) plus
        the documented slack — 0 for dense/int8/full-support TopK (random
        continuous data fills every slot), and the exact word-padding
        closed form for packed-code units."""
        tree = tree_of(11, SHAPES)
        rng = jax.random.PRNGKey(12) if needs_rng else None

        @jax.jit
        def roundtrip(t, k):
            payload, rep = wire.encode(comp, t, k)
            return rep.total_bits, wire.padding_bits(payload, rep)

        total_bits, pad = roundtrip(tree, rng)
        measured = wire.payload_nbytes(comp, tree) * 8
        assert float(total_bits) + float(pad) == measured
        spec = jax.eval_shape(
            lambda t: wire.encode(comp, t, jax.random.PRNGKey(0))[0],
            tree).spec
        b = 1 + spec.r
        if spec.codec in ("dense", "topk", "int8"):
            expected_pad = 0.0          # full support, byte-granular
        elif spec.codec == "qr":        # word padding over each unit's size
            sizes = ([sum(int(np.prod(s)) for s in SHAPES)]
                     if spec.scope == "global"
                     else [int(np.prod(s)) for s in SHAPES])
            expected_pad = sum((32 * -(-n // 32) - n) * b for n in sizes)
        else:                           # topk_qr: padding over the cap slots
            expected_pad = sum((32 * -(-c // 32) - c) * b for c in spec.caps)
        assert float(pad) == expected_pad
        # the documented bound: < 32*b bits of word padding per unit
        n_units = 1 if spec.scope == "global" else len(SHAPES)
        assert float(pad) < 32 * b * n_units + 1


# --------------------------------------------------------------------------- #
# 4. wire rounds == account rounds
# --------------------------------------------------------------------------- #

def quadratic_setup(n_clients=6, d=10, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, d))
    b = rng.normal(size=(n_clients,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(n_clients)]
    return fed_data.from_numpy_partition(x, y, parts)


def sq_loss(params, xb, yb):
    pred = xb @ params["w"]
    return 0.5 * jnp.mean((pred - yb) ** 2)


N, D = 6, 10
DATA = quadratic_setup(N, D)
P0 = {"w": jnp.zeros((D,), jnp.float32)}
DROP_SCHED = ClientSchedule(
    profile=ClientProfile.lognormal(N, speed_sigma=1.0, seed=3),
    deadline=3.0, drop_stragglers=True)


def run_fedcomloc(wire_mode, comp, R=4, policy=None, schedule=None, **cfg_kw):
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=N,
                          clients_per_round=4, batch_size=4,
                          variant="com", **cfg_kw)
    alg = FedComLoc(sq_loss, DATA, cfg, comp, schedule=schedule,
                    policy=policy, wire=wire_mode)
    state, metrics = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(7), R)
    return np.asarray(state.x["w"]), metrics


def assert_round_equivalence(ma, mw, wa, ww):
    np.testing.assert_allclose(wa, ww, rtol=1e-6, atol=1e-7)
    for key in ("uplink_bits", "downlink_bits", "client_uplink_bits",
                "sim_time", "clients_aggregated"):
        np.testing.assert_array_equal(ma[key], mw[key], err_msg=key)
    pad = mw["uplink_payload_bytes"] * 8 - mw["uplink_bits"]
    assert (pad >= 0).all()
    return pad


class TestWireRounds:
    @pytest.mark.parametrize("comp,extra", [
        (TopK(density=0.3), {}),
        (QuantQr(r=6), {}),
        (Compose(TopK(0.3), QuantQr(4)), {}),
        (Int8Sync(), {}),
        (TopK(density=0.3), {"error_feedback": True}),
    ])
    def test_fedcomloc_matches_account(self, comp, extra):
        wa, ma = run_fedcomloc("account", comp, **extra)
        ww, mw = run_fedcomloc("packed", comp, **extra)
        pad = assert_round_equivalence(ma, mw, wa, ww)
        if isinstance(comp, (TopK, Int8Sync)):
            np.testing.assert_array_equal(pad, 0)   # byte-exact payloads

    @pytest.mark.parametrize("alg_cls", [FedAvg, Scaffold, FedDyn])
    def test_baselines_match_account(self, alg_cls):
        cfg = FedConfig(gamma=0.05, local_steps=4, n_clients=N,
                        clients_per_round=4, batch_size=4)
        outs = {}
        for mode in ("account", "packed"):
            if alg_cls is FedAvg:
                alg = alg_cls(sq_loss, DATA, cfg, TopK(0.25), wire=mode)
            else:
                alg = alg_cls(sq_loss, DATA, cfg, wire=mode)
            st, m = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(5), 4)
            outs[mode] = (np.asarray(st.x["w"]), m)
        wa, ma = outs["account"]
        ww, mw = outs["packed"]
        assert_round_equivalence(ma, mw, wa, ww)
        if alg_cls is Scaffold:     # model + control variate, both dense
            np.testing.assert_array_equal(
                mw["uplink_payload_bytes"] * 8, mw["uplink_bits"])

    def test_meter_and_goldens_unchanged_in_account_mode(self):
        """Account mode is the constructor default and its graph/metrics
        carry no wire keys — golden traces stay valid byte-for-byte."""
        _, m = run_fedcomloc("account", TopK(density=0.3))
        assert "uplink_payload_bytes" not in m
        assert "client_payload_bytes" not in m


class TestStragglerPayloads:
    """Satellite: a deadline-dropped (or policy-excluded) client contributes
    a zero-length, fully masked payload — not packed zeros counted as
    transmitted — under both semi_sync and async_buffered."""

    @pytest.mark.parametrize("policy", [
        aggregation.AggregationPolicy.semi_sync(2),
        aggregation.AggregationPolicy.async_buffered(2, 1.0),
    ])
    def test_dropped_clients_send_nothing(self, policy):
        comp = TopK(density=0.3)
        wa, ma = run_fedcomloc("account", comp, policy=policy,
                               schedule=DROP_SCHED, R=6)
        ww, mw = run_fedcomloc("packed", comp, policy=policy,
                               schedule=DROP_SCHED, R=6)
        assert_round_equivalence(ma, mw, wa, ww)
        cpb = np.asarray(mw["client_payload_bytes"])
        cub = np.asarray(mw["client_uplink_bits"])
        # at this deadline the lognormal tail drops clients in some rounds
        assert (cub == 0).any(), "expected dropped clients in this setup"
        # zero accounted bits <-> zero measured bytes, per client per round
        np.testing.assert_array_equal(cpb == 0, cub == 0)
        # non-excluded clients all ship the same static packed size
        assert np.unique(cpb[cpb > 0]).size == 1

    def test_masked_payload_buffers_are_zero(self):
        """mask_payload zeroes every buffer of a non-participant, and the
        masked payload decodes to an all-zero tree."""
        comp = Compose(TopK(0.25), QuantQr(4))
        tree = tree_of(1, SHAPES)
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.stack([x, 2 * x, -x]), tree)
        keys = jax.random.split(jax.random.PRNGKey(2), 3)
        payload, _ = jax.vmap(
            lambda t, k: wire.encode(comp, t, k))(stacked, keys)
        partf = jnp.asarray([1.0, 0.0, 1.0])
        masked = mask_payload(payload, partf)
        for unit in masked.data:
            for buf in unit:
                assert np.all(np.asarray(buf)[1] == 0)      # dropped client
        dec = jax.vmap(wire.decode)(masked)
        keep = jax.vmap(wire.decode)(payload)
        for k, v in dec.items():
            assert np.all(np.asarray(v)[1] == 0)            # decodes to 0
            # participants' lanes are untouched by the masking
            np.testing.assert_array_equal(np.asarray(v)[[0, 2]],
                                          np.asarray(keep[k])[[0, 2]])


class TestValidation:
    def test_quantile_topk_rejected(self):
        with pytest.raises(ValueError, match="static capacity"):
            FedComLoc(sq_loss, DATA,
                      FedComLocConfig(n_clients=N, clients_per_round=4,
                                      variant="com"),
                      TopK(density=0.1, impl="quantile"), wire="packed")

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="wire must be"):
            FedComLoc(sq_loss, DATA,
                      FedComLocConfig(n_clients=N, clients_per_round=4),
                      wire="bytes")

    def test_per_client_overrides_rejected(self):
        sched = ClientSchedule(
            profile=ClientProfile.homogeneous(N).with_comp_param(
                "density", jnp.full((N,), 0.2)))
        with pytest.raises(ValueError, match="overrides"):
            FedComLoc(sq_loss, DATA,
                      FedComLocConfig(n_clients=N, clients_per_round=4,
                                      variant="com"),
                      TopK(density=0.2), schedule=sched, wire="packed")

    def test_unsupported_compose_rejected(self):
        with pytest.raises(ValueError, match="Compose"):
            wire.check_supported(Compose(QuantQr(4), TopK(0.2)))
        with pytest.raises(ValueError, match="matching scopes"):
            wire.check_supported(
                Compose(TopK(0.2, scope="global"), QuantQr(4)))

    def test_set_wire_rebinds_and_clears_caches(self):
        cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=N,
                              clients_per_round=4, batch_size=4,
                              variant="com")
        alg = FedComLoc(sq_loss, DATA, cfg, TopK(0.3))
        st, m = alg.round(alg.init(P0), jax.random.PRNGKey(0))
        assert "uplink_payload_bytes" not in m
        assert alg.set_wire("packed") is alg
        _, m2 = alg.round(alg.init(P0), jax.random.PRNGKey(0))
        assert m2["uplink_payload_bytes"] > 0
        assert m2["uplink_bits"] == m["uplink_bits"]
        alg.set_wire("packed")          # rebind same mode: no-op


# --------------------------------------------------------------------------- #
# 5. packed uplink over a >1-shard client mesh (CI's 8-device leg)
# --------------------------------------------------------------------------- #

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices for a sharded client mesh")
class TestShardedWire:
    @pytest.mark.parametrize("comp", [TopK(0.3), QuantQr(r=6)])
    def test_packed_uplink_multi_shard(self, comp):
        """The §8 contract on a real >1-shard mesh: the gathered packed
        buffers reproduce the single-device wire round — accounted bits
        AND measured payload bytes bit-identical, params allclose."""
        from repro.launch.mesh import make_client_mesh

        shards = 2
        ww, mw = run_fedcomloc("packed", comp)
        cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=N,
                              clients_per_round=4, batch_size=4,
                              variant="com")
        alg = FedComLoc(sq_loss, DATA, cfg, comp, wire="packed")
        alg.use_mesh(make_client_mesh(shards))
        assert alg._mesh is not None
        st, ms = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(7), 4)
        for key in ("uplink_bits", "uplink_payload_bytes",
                    "client_payload_bytes", "client_uplink_bits"):
            np.testing.assert_array_equal(mw[key], ms[key], err_msg=key)
        np.testing.assert_allclose(ww, np.asarray(st.x["w"]),
                                   rtol=1e-6, atol=1e-7)
