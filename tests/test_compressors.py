"""Unit + property tests for the compression operators (paper §3.1)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressors import (
    Compose, Identity, QuantQr, TopK, make_compressor)

jax.config.update("jax_platform_name", "cpu")


def tree_of(key, shapes):
    keys = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(keys, shapes))}


class TestTopK:
    def test_keeps_exactly_k(self):
        x = {"a": jax.random.normal(jax.random.PRNGKey(0), (1000,))}
        out = TopK(density=0.1).compress(x)
        assert int((out["a"] != 0).sum()) == 100

    def test_keeps_largest(self):
        x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
        out = TopK(density=0.4).compress({"a": x})["a"]
        np.testing.assert_allclose(out, [0.0, -5.0, 0.0, 3.0, 0.0])

    def test_density_one_identity(self):
        x = tree_of(jax.random.PRNGKey(1), [(64,), (8, 8)])
        out = TopK(density=1.0).compress(x)
        for k in x:
            np.testing.assert_array_equal(out[k], x[k])

    def test_global_scope(self):
        x = {"a": jnp.asarray([10.0, 0.1]), "b": jnp.asarray([5.0, 0.2])}
        out = TopK(density=0.5, scope="global").compress(x)
        np.testing.assert_allclose(out["a"], [10.0, 0.0])
        np.testing.assert_allclose(out["b"], [5.0, 0.0])

    @hypothesis.given(
        st.integers(10, 300), st.floats(0.05, 1.0),
        st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_best_k_approx_property(self, n, density, seed):
        """TopK(x) is the best ||.||-approximation among k-sparse vectors:
        the kept set has magnitudes >= every dropped one."""
        x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
        out = np.asarray(TopK(density=density).compress(
            {"a": jnp.asarray(x)})["a"])
        kept = np.abs(x[out != 0])
        dropped = np.abs(x[out == 0])
        if kept.size and dropped.size:
            assert kept.min() >= dropped.max() - 1e-7
        # kept values pass through unchanged
        np.testing.assert_allclose(out[out != 0], x[out != 0])

    def test_bits(self):
        x = {"a": jnp.zeros((1000,))}
        assert TopK(density=0.1).bits(x) == 100 * 64
        assert Identity().bits(x) == 1000 * 32


class TestQuantQr:
    def test_requires_rng(self):
        with pytest.raises(ValueError):
            QuantQr(r=4).compress({"a": jnp.ones((4,))})

    def test_zero_input(self):
        out = QuantQr(r=4).compress({"a": jnp.zeros((16,))},
                                    jax.random.PRNGKey(0))
        np.testing.assert_array_equal(out["a"], 0.0)

    def test_values_on_grid(self):
        x = {"a": jax.random.normal(jax.random.PRNGKey(0), (256,))}
        r = 3
        out = QuantQr(r=r).compress(x, jax.random.PRNGKey(1))["a"]
        norm = float(jnp.linalg.norm(x["a"]))
        levels = np.asarray(out) / norm * (2 ** r)
        np.testing.assert_allclose(levels, np.round(levels), atol=1e-4)

    def test_unbiased(self):
        """E[Q_r(x)] = x (Def. 3.2)."""
        x = {"a": jnp.asarray([0.3, -1.2, 2.0, 0.017])}
        comp = QuantQr(r=2)
        keys = jax.random.split(jax.random.PRNGKey(2), 3000)
        acc = np.zeros(4)
        for k in keys:
            acc += np.asarray(comp.compress(x, k)["a"])
        np.testing.assert_allclose(acc / len(keys), x["a"], atol=0.02)

    @hypothesis.given(st.integers(1, 10), st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_error_bound(self, r, seed):
        """|Q_r(x)_i - x_i| <= ||x|| / 2^r componentwise."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
        out = QuantQr(r=r).compress({"a": x}, jax.random.PRNGKey(seed + 1))
        err = np.abs(np.asarray(out["a"]) - np.asarray(x))
        bound = float(jnp.linalg.norm(x)) / 2 ** r + 1e-5
        assert err.max() <= bound

    def test_bits_fewer_than_dense(self):
        x = {"a": jnp.zeros((1000,))}
        assert QuantQr(r=8).bits(x) == 1000 * 9 + 32


class TestCompose:
    def test_topk_then_quant(self):
        x = {"a": jax.random.normal(jax.random.PRNGKey(0), (512,))}
        comp = Compose(TopK(0.25), QuantQr(4))
        out = comp.compress(x, jax.random.PRNGKey(1))["a"]
        assert int((out != 0).sum()) <= 128
        # bits: 25% coords x (32 idx + 1 sign + 4 level) + norm
        assert comp.bits(x) == 128 * 37 + 32


def test_registry():
    assert isinstance(make_compressor("topk", density=0.3), TopK)
    assert isinstance(make_compressor("quant", r=4), QuantQr)
    assert isinstance(make_compressor("none"), Identity)
    with pytest.raises(ValueError):
        make_compressor("nope")
