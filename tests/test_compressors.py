"""Unit + property tests for the unified compression subsystem (paper §3.1).

Covers operator semantics AND the exact in-graph bit accounting: every
``compress`` returns ``(tree, BitsReport)`` whose totals must equal the
hand-computed paper formulas — (32+32)*nnz for TopK, (1+r)*n + 32/tensor
for Q_r, (32+1+r)*nnz + 32 for the double compression.

The property checks are plain functions driven two ways: a random
hypothesis search when the optional dep is installed, and an always-on
seeded parameter sweep — so the properties execute (not skip) in
no-hypothesis environments and CI legs too.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                       # optional dep: widens, never gates, the sweep
    import hypothesis
    import hypothesis.strategies as st
except ImportError:        # pragma: no cover - exercised on clean envs
    hypothesis = st = None

from repro.compress import (
    BitsReport, Compose, Identity, Int8Sync, QuantQr, TopK, available,
    dense_bits, make_compressor, register)

jax.config.update("jax_platform_name", "cpu")


def tree_of(key, shapes):
    keys = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(keys, shapes))}


# --------------------------------------------------------------------------- #
# Property bodies — shared by the hypothesis search and the seeded sweeps
# --------------------------------------------------------------------------- #

def check_best_k_approx(n, density, seed):
    """TopK(x) is the best ||.||-approximation among k-sparse vectors:
    the kept set has magnitudes >= every dropped one."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
    out = np.asarray(TopK(density=density).apply(
        {"a": jnp.asarray(x)})["a"])
    kept = np.abs(x[out != 0])
    dropped = np.abs(x[out == 0])
    if kept.size and dropped.size:
        assert kept.min() >= dropped.max() - 1e-7
    # kept values pass through unchanged
    np.testing.assert_allclose(out[out != 0], x[out != 0])


def check_topk_bits_formula(n, density, seed):
    """BitsReport total == (32 + 32) * nnz of the actual mask."""
    x = {"a": jax.random.normal(jax.random.PRNGKey(seed), (n,))}
    out, rep = TopK(density=density).compress(x)
    nnz = int((out["a"] != 0).sum())
    assert float(rep.value_bits) == nnz * 32
    assert float(rep.index_bits) == nnz * 32
    assert float(rep.total_bits) == nnz * (32 + 32)


def check_quant_error_bound(r, seed):
    """|Q_r(x)_i - x_i| <= ||x|| / 2^r componentwise."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    out, _ = QuantQr(r=r).compress({"a": x}, jax.random.PRNGKey(seed + 1))
    err = np.abs(np.asarray(out["a"]) - np.asarray(x))
    bound = float(jnp.linalg.norm(x)) / 2 ** r + 1e-5
    assert err.max() <= bound


def check_quant_bits_formula(r, n_tensors, seed):
    """BitsReport total == (1 + r) * n + 32 per tensor norm."""
    shapes = [(8 * (i + 1),) for i in range(n_tensors)]
    x = tree_of(jax.random.PRNGKey(seed), shapes)
    n = sum(v.size for v in x.values())
    _, rep = QuantQr(r=r).compress(x, jax.random.PRNGKey(seed + 1))
    assert float(rep.total_bits) == n * (1 + r) + n_tensors * 32
    assert QuantQr(r=r).expected_bits(x) == n * (1 + r) + n_tensors * 32


class TestTopK:
    def test_keeps_exactly_k(self):
        x = {"a": jax.random.normal(jax.random.PRNGKey(0), (1000,))}
        out, rep = TopK(density=0.1).compress(x)
        assert int((out["a"] != 0).sum()) == 100
        assert float(rep.total_bits) == 100 * 64

    def test_keeps_largest(self):
        x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
        out = TopK(density=0.4).apply({"a": x})["a"]
        np.testing.assert_allclose(out, [0.0, -5.0, 0.0, 3.0, 0.0])

    def test_density_one_identity(self):
        x = tree_of(jax.random.PRNGKey(1), [(64,), (8, 8)])
        out, rep = TopK(density=1.0).compress(x)
        for k in x:
            np.testing.assert_array_equal(out[k], x[k])
        # dense payload, no indices
        assert float(rep.index_bits) == 0
        assert float(rep.total_bits) == 128 * 32

    def test_global_scope(self):
        x = {"a": jnp.asarray([10.0, 0.1]), "b": jnp.asarray([5.0, 0.2])}
        out, _ = TopK(density=0.5, scope="global").compress(x)
        np.testing.assert_allclose(out["a"], [10.0, 0.0])
        np.testing.assert_allclose(out["b"], [5.0, 0.0])

    def test_quantile_impl_matches_threshold_semantics(self):
        x = {"a": jax.random.normal(jax.random.PRNGKey(3), (512,))}
        out, rep = TopK(density=0.25, impl="quantile").compress(x)
        kept = np.abs(np.asarray(x["a"]))[np.asarray(out["a"]) != 0]
        dropped = np.abs(np.asarray(x["a"]))[np.asarray(out["a"]) == 0]
        assert kept.min() >= dropped.max() - 1e-7
        # bits follow the *actual* (approximate) support
        nnz = int((out["a"] != 0).sum())
        assert float(rep.total_bits) == nnz * 64

    @pytest.mark.parametrize("seed", range(6))
    @pytest.mark.parametrize("n,density", [
        (10, 0.05), (17, 0.3), (100, 0.1), (128, 0.5), (300, 1.0),
    ])
    def test_best_k_approx_seeded(self, n, density, seed):
        check_best_k_approx(n, density, seed)

    @pytest.mark.parametrize("seed", range(6))
    @pytest.mark.parametrize("n,density", [
        (16, 0.05), (33, 0.25), (100, 0.5), (200, 0.9),
    ])
    def test_bits_equal_nnz_formula_seeded(self, n, density, seed):
        check_topk_bits_formula(n, density, seed)

    def test_expected_bits(self):
        x = {"a": jnp.zeros((1000,))}
        assert TopK(density=0.1).expected_bits(x) == 100 * 64
        assert Identity().expected_bits(x) == 1000 * 32
        assert dense_bits(x) == 1000 * 32


class TestQuantQr:
    def test_requires_rng(self):
        with pytest.raises(ValueError):
            QuantQr(r=4).compress({"a": jnp.ones((4,))})

    def test_zero_input(self):
        out, _ = QuantQr(r=4).compress({"a": jnp.zeros((16,))},
                                       jax.random.PRNGKey(0))
        np.testing.assert_array_equal(out["a"], 0.0)

    def test_values_on_grid(self):
        x = {"a": jax.random.normal(jax.random.PRNGKey(0), (256,))}
        r = 3
        out, _ = QuantQr(r=r).compress(x, jax.random.PRNGKey(1))
        norm = float(jnp.linalg.norm(x["a"]))
        levels = np.asarray(out["a"]) / norm * (2 ** r)
        np.testing.assert_allclose(levels, np.round(levels), atol=1e-4)

    def test_unbiased(self):
        """E[Q_r(x)] = x (Def. 3.2)."""
        x = {"a": jnp.asarray([0.3, -1.2, 2.0, 0.017])}
        comp = QuantQr(r=2)
        keys = jax.random.split(jax.random.PRNGKey(2), 3000)
        acc = np.zeros(4)
        for k in keys:
            acc += np.asarray(comp.apply(x, k)["a"])
        np.testing.assert_allclose(acc / len(keys), x["a"], atol=0.02)

    @pytest.mark.parametrize("seed", range(5))
    @pytest.mark.parametrize("r", [1, 2, 4, 8, 10])
    def test_error_bound_seeded(self, r, seed):
        check_quant_error_bound(r, seed)

    @pytest.mark.parametrize("seed", range(5))
    @pytest.mark.parametrize("r,n_tensors", [
        (1, 1), (4, 2), (8, 3), (12, 4),
    ])
    def test_bits_equal_formula_seeded(self, r, n_tensors, seed):
        check_quant_bits_formula(r, n_tensors, seed)

    def test_bits_fewer_than_dense(self):
        x = {"a": jnp.zeros((1000,))}
        _, rep = QuantQr(r=8).compress(x, jax.random.PRNGKey(0))
        assert float(rep.total_bits) == 1000 * 9 + 32


class TestCompose:
    def test_topk_then_quant(self):
        x = {"a": jax.random.normal(jax.random.PRNGKey(0), (512,))}
        comp = Compose(TopK(0.25), QuantQr(4))
        out, rep = comp.compress(x, jax.random.PRNGKey(1))
        assert int((out["a"] != 0).sum()) <= 128
        # bits: nnz of the sparsifier support x (32 idx + 1 sign + 4 level)
        # + per-tensor norm — support-aware, counted in-graph
        assert float(rep.total_bits) == 128 * 37 + 32
        assert comp.expected_bits(x) == 128 * 37 + 32


class TestInt8Sync:
    def test_roundtrip_unbiased(self):
        x = {"a": jax.random.normal(jax.random.PRNGKey(0), (128,))}
        comp = Int8Sync()
        keys = jax.random.split(jax.random.PRNGKey(1), 2000)
        acc = np.zeros(128)
        for k in keys:
            acc += np.asarray(comp.apply(x, k)["a"])
        np.testing.assert_allclose(acc / len(keys), x["a"], atol=0.05)

    def test_payload_is_int8(self):
        x = {"a": jax.random.normal(jax.random.PRNGKey(0), (64,))}
        payload, scales = Int8Sync().encode(x, jax.random.PRNGKey(1))
        assert payload["a"].dtype == jnp.int8
        _, rep = Int8Sync().compress(x, jax.random.PRNGKey(1))
        assert float(rep.total_bits) == 64 * 8 + 32

    def test_rejects_wide_levels(self):
        with pytest.raises(ValueError):
            Int8Sync(magnitude_bits=8)


class TestReport:
    def test_add_and_scale(self):
        a = BitsReport(10.0, 5.0, 1.0)
        b = BitsReport(2.0, 1.0, 0.5)
        assert (a + b).total_bits == 19.5
        assert a.scale(3).total_bits == 48.0

    def test_report_flows_through_jit_and_vmap(self):
        comp = TopK(density=0.5)
        tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (4, 32))}
        keys = jax.random.split(jax.random.PRNGKey(1), 4)

        @jax.jit
        def f(t, ks):
            out, rep = jax.vmap(comp.compress)(t, ks)
            return rep.reduce_sum().total_bits

        assert float(f(tree, keys)) == 4 * 16 * 64


def test_registry():
    assert isinstance(make_compressor("topk", density=0.3), TopK)
    assert isinstance(make_compressor("quant", r=4), QuantQr)
    assert isinstance(make_compressor("none"), Identity)
    assert isinstance(make_compressor("int8"), Int8Sync)
    assert "topk+quant" in available()
    with pytest.raises(ValueError):
        make_compressor("nope")


def test_registry_extension():
    class Noop(Identity):
        pass

    register("test-noop", Noop, overwrite=True)
    assert isinstance(make_compressor("test-noop"), Noop)
    with pytest.raises(ValueError):
        register("test-noop", Noop)


# --------------------------------------------------------------------------- #
# Hypothesis widening of the seeded sweeps (optional dep)
# --------------------------------------------------------------------------- #

if hypothesis is not None:

    class TestProperties:
        @hypothesis.given(
            st.integers(10, 300), st.floats(0.05, 1.0),
            st.integers(0, 2**31 - 1))
        @hypothesis.settings(max_examples=30, deadline=None)
        def test_best_k_approx_property(self, n, density, seed):
            check_best_k_approx(n, density, seed)

        @hypothesis.given(st.integers(16, 200), st.floats(0.05, 0.9),
                          st.integers(0, 2**31 - 1))
        @hypothesis.settings(max_examples=30, deadline=None)
        def test_bits_equal_nnz_formula(self, n, density, seed):
            check_topk_bits_formula(n, density, seed)

        @hypothesis.given(st.integers(1, 10), st.integers(0, 2**31 - 1))
        @hypothesis.settings(max_examples=25, deadline=None)
        def test_error_bound(self, r, seed):
            check_quant_error_bound(r, seed)

        @hypothesis.given(st.integers(1, 12), st.integers(1, 4),
                          st.integers(0, 2**31 - 1))
        @hypothesis.settings(max_examples=25, deadline=None)
        def test_bits_equal_formula(self, r, n_tensors, seed):
            check_quant_bits_formula(r, n_tensors, seed)
