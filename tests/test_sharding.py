"""Sharding rules + pjit lowering of the production step functions on the
host mesh (1x1 / 1x1x1), plus the federated multi-pod round."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import get_spec
from repro.configs.base import SHAPES, reduced
from repro.launch import fed_train, steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.sharding import specs as sh

jax.config.update("jax_platform_name", "cpu")


def small_shape(kind):
    base = {"train": SHAPES["train_4k"], "prefill": SHAPES["prefill_32k"],
            "decode": SHAPES["decode_32k"]}[kind]
    return dataclasses.replace(base, seq_len=256, global_batch=2)


class TestParamSpecs:
    def test_rules_cover_every_param(self):
        mesh = make_host_mesh()
        for arch in ("mixtral-8x7b", "recurrentgemma-2b", "rwkv6-3b",
                     "gemma2-9b", "seamless-m4t-large-v2"):
            spec = reduced(get_spec(arch))
            pstruct = steps_mod._params_struct(spec)
            shardings = sh.param_shardings(pstruct, mesh)
            # every leaf got a NamedSharding with matching rank
            flat_p = jax.tree_util.tree_leaves_with_path(pstruct)
            flat_s = jax.tree_util.tree_leaves(shardings)
            assert len(flat_p) == len(flat_s)
            for (path, leaf), ns in zip(flat_p, flat_s):
                assert len(ns.spec) <= len(leaf.shape), (path, ns.spec)

    def test_big_tensors_are_sharded(self):
        """On the production mesh no parameter > 64 MiB may be replicated."""
        mesh_devs = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(mesh_devs, ("data", "model"))
        for arch in ("qwen2-7b", "mixtral-8x7b", "rwkv6-3b"):
            spec = get_spec(arch)
            pstruct = steps_mod._params_struct(spec)
            shardings = sh.param_shardings(
                pstruct, mesh,
                n_experts=steps_mod._n_experts(spec))
            for (path, leaf), ns in zip(
                    jax.tree_util.tree_leaves_with_path(pstruct),
                    jax.tree_util.tree_leaves(shardings)):
                size = leaf.size * 2
                if size > 64 * 2**20:
                    assert any(s is not None for s in ns.spec), \
                        (arch, jax.tree_util.keystr(path), leaf.shape)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x7b",
                                  "recurrentgemma-2b", "rwkv6-3b",
                                  "seamless-m4t-large-v2", "qwen2-vl-7b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_steps_lower_on_host_mesh(arch, kind):
    spec = reduced(get_spec(arch))
    mesh = make_host_mesh()
    shape = small_shape(kind)
    if kind == "train":
        b = steps_mod.build_train_step(spec, shape, mesh)
    elif kind == "prefill":
        b = steps_mod.build_prefill_step(spec, shape, mesh)
    else:
        b = steps_mod.build_serve_step(spec, shape, mesh)
    with mesh:
        lowered = jax.jit(b.fn, in_shardings=b.in_shardings,
                          out_shardings=b.out_shardings,
                          donate_argnums=b.donate_argnums).lower(*b.args)
        lowered.compile()


class TestFedRound:
    def _mesh(self):
        dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
        return Mesh(dev, ("pod", "data", "model"))

    def test_fed_round_lowers(self):
        spec = reduced(get_spec("qwen2-0.5b"))
        mesh = self._mesh()
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128,
                                    global_batch=2)
        fed = fed_train.FedTrainConfig(local_steps=2, compressor="topk",
                                       density=0.25)
        b = fed_train.build_fed_round(spec, shape, mesh, fed)
        with mesh:
            jax.jit(b.fn, in_shardings=b.in_shardings,
                    out_shardings=b.out_shardings,
                    donate_argnums=b.donate_argnums).lower(*b.args).compile()

    def test_fed_round_executes_and_learns(self):
        """Run real federated rounds of a tiny LM on the host multi-pod mesh
        and check the loss drops."""
        spec = reduced(get_spec("qwen2-0.5b"))
        m = dataclasses.replace(spec.model, n_layers=1, d_model=64,
                                d_ff=128, vocab=64, n_heads=2, n_kv_heads=1,
                                head_dim=32, dtype=jnp.float32)
        spec = dataclasses.replace(spec, model=m)
        mesh = self._mesh()
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                    global_batch=4)
        fed = fed_train.FedTrainConfig(gamma=0.3, local_steps=4,
                                       compressor="quant", quant_bits=8)
        b = fed_train.build_fed_round(spec, shape, mesh, fed)
        params = tfm.init_params(jax.random.PRNGKey(0), m)
        h = jax.tree_util.tree_map(jnp.zeros_like, params)
        stackp = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (1,) + x.shape), params)
        stackh = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (1,) + x.shape), h)
        from repro.data import synthetic
        toks = jnp.asarray(synthetic.make_lm_tokens(64, 4, 64, seed=0)
                           ).reshape(1, 4, 64)
        with mesh:
            step = jax.jit(b.fn, in_shardings=b.in_shardings,
                           out_shardings=b.out_shardings)
            losses = []
            key = jax.random.PRNGKey(1)
            for r in range(8):
                key, sub = jax.random.split(key)
                stackp, stackh, loss, bits = step(
                    stackp, stackh, {"tokens": toks},
                    jax.random.key_data(sub) if hasattr(
                        jax.random, "key_data") else sub)
                losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        assert np.isfinite(losses).all()
        assert float(bits) > 0


def test_fed_train_uses_unified_compressors():
    """The launch layer resolves its config to repro.compress entries
    (quantile-threshold TopK at scale) — no local compression code."""
    tree = {"a": jnp.asarray(np.random.default_rng(0).normal(
        size=(64,)).astype(np.float32))}
    fed = fed_train.FedTrainConfig(compressor="topk", density=0.25)
    comp = fed_train.make_compressor(fed)
    assert comp.impl == "quantile"
    out, rep = comp.compress(tree, jax.random.PRNGKey(0))
    nnz = int((out["a"] != 0).sum())
    assert 10 <= nnz <= 22   # ~16 kept (threshold semantics)
    # bits are counted from the actual support, in-graph
    assert float(rep.total_bits) == nnz * 64
    assert comp.expected_bits(tree) == 0.25 * 64 * 64
    fedq = fed_train.FedTrainConfig(compressor="quant", quant_bits=4)
    compq = fed_train.make_compressor(fedq)
    outq, repq = compq.compress(tree, jax.random.PRNGKey(1))
    assert outq["a"].shape == (64,)
    assert float(repq.total_bits) == 64 * 5 + 32   # + per-tensor norm
