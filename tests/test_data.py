"""Data pipeline: Dirichlet partitioning properties + synthetic datasets.

The partition-cover property runs as an always-on seeded sweep; hypothesis
(optional dep) only widens the search — it never gates the module, so the
non-property tests execute on clean environments too.
"""

import jax
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:        # pragma: no cover - exercised on clean envs
    hypothesis = st = None

from repro.core import fed_data
from repro.data import dirichlet, synthetic

jax.config.update("jax_platform_name", "cpu")


def check_partition_is_exact_cover(n_clients, alpha, seed):
    labels = np.random.default_rng(seed).integers(0, 10, size=500)
    parts = dirichlet.dirichlet_partition(labels, n_clients, alpha,
                                          seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == 500
    assert len(np.unique(allidx)) == 500          # no dup, no loss
    assert all(len(p) >= 1 for p in parts)


class TestDirichlet:
    @pytest.mark.parametrize("seed", range(5))
    @pytest.mark.parametrize("n_clients,alpha", [
        (2, 0.05), (5, 0.5), (10, 1.0), (20, 10.0),
    ])
    def test_partition_is_exact_cover_seeded(self, n_clients, alpha, seed):
        check_partition_is_exact_cover(n_clients, alpha, seed)

    def test_alpha_controls_heterogeneity(self):
        """Smaller alpha -> each client more concentrated on few classes."""
        labels = np.random.default_rng(0).integers(0, 10, size=20_000)
        shares = {}
        for alpha in (0.1, 100.0):
            parts = dirichlet.dirichlet_partition(labels, 20, alpha, seed=1)
            stats = dirichlet.partition_stats(parts, labels)
            shares[alpha] = stats["max_class_share"]
        assert shares[0.1] > shares[100.0] + 0.2

    def test_fed_data_batching(self):
        labels = np.arange(100) % 10
        x = np.random.default_rng(0).normal(size=(100, 4)).astype(np.float32)
        parts = dirichlet.dirichlet_partition(labels, 5, 0.5, seed=0)
        data = fed_data.from_numpy_partition(x, labels, parts)
        xb, yb = data.sample_batch(jax.random.PRNGKey(0),
                                   np.int32(2), batch=8)
        assert xb.shape == (8, 4) and yb.shape == (8,)
        # every drawn sample belongs to client 2's shard
        client_set = set(parts[2].tolist())
        flat = np.asarray(data.client_indices[2][:data.client_sizes[2]])
        assert set(flat.tolist()) == client_set


class TestSynthetic:
    def test_shapes(self):
        ds = synthetic.make_mnist_like(n_train=2000, n_test=500)
        assert ds.x_train.shape == (2000, 784)
        assert ds.x_test.shape == (500, 784)
        assert ds.n_classes == 10
        ds2 = synthetic.make_cifar_like(n_train=1000, n_test=200)
        assert ds2.x_train.shape == (1000, 32, 32, 3)

    def test_learnable(self):
        """A linear probe must beat chance by a wide margin (the dataset has
        class structure, unlike pure noise)."""
        ds = synthetic.make_mnist_like(n_train=4000, n_test=1000)
        # one-vs-rest least squares
        Y = np.eye(10)[ds.y_train]
        X = np.concatenate([ds.x_train, np.ones((len(ds.x_train), 1))], 1)
        W, *_ = np.linalg.lstsq(X, Y, rcond=None)
        Xt = np.concatenate([ds.x_test, np.ones((len(ds.x_test), 1))], 1)
        acc = (np.argmax(Xt @ W, 1) == ds.y_test).mean()
        assert acc > 0.6, acc

    def test_cifar_like_harder(self):
        easy = synthetic.make_mnist_like(n_train=3000, n_test=800)
        hard = synthetic.make_cifar_like(n_train=3000, n_test=800)

        def probe_acc(ds):
            Xf = ds.x_train.reshape(len(ds.x_train), -1)
            Y = np.eye(10)[ds.y_train]
            X = np.concatenate([Xf, np.ones((len(Xf), 1))], 1)
            W, *_ = np.linalg.lstsq(X, Y, rcond=None)
            Xt = ds.x_test.reshape(len(ds.x_test), -1)
            Xt = np.concatenate([Xt, np.ones((len(Xt), 1))], 1)
            return (np.argmax(Xt @ W, 1) == ds.y_test).mean()

        assert probe_acc(easy) > probe_acc(hard) + 0.1

    def test_lm_tokens(self):
        toks = synthetic.make_lm_tokens(vocab=256, n_seqs=8, seq_len=64)
        assert toks.shape == (8, 64)
        assert toks.min() >= 0 and toks.max() < 256


if hypothesis is not None:

    class TestDirichletProperties:
        @hypothesis.given(st.integers(2, 20), st.floats(0.05, 10.0),
                          st.integers(0, 1000))
        @hypothesis.settings(max_examples=20, deadline=None)
        def test_partition_is_exact_cover(self, n_clients, alpha, seed):
            check_partition_is_exact_cover(n_clients, alpha, seed)
