"""Out-of-core client state store (DESIGN.md §11).

Contracts:

* **Backend equivalence** — every algorithm with persistent per-client
  state (FedComLoc's EF memory + shift, Scaffold's control variates,
  FedDyn's gradient memory, LoCoDL's iterates + control variates) runs
  the SAME trajectory under the host-side store as under the default
  in-memory store: metrics bit-identical, params bit-identical, under
  both drivers (``round`` and the fused ``run_rounds`` scan — the
  ordered-io_callback boundary sequences correctly inside ``lax.scan``);
* **Memory-mapped spooling** — ``HostStore(mmap_dir=...)`` is equally
  bit-identical, with the buffers living in files;
* **Lazy materialisation** — gathers read only previously-scattered rows;
  a ``broadcast``-init slot serves never-touched rows from the one fill
  row (LoCoDL's million-client ``xs`` never materialises n copies);
* **Checkpoint-resume** (DESIGN.md §11) — save at round r + resume is
  bit-identical to an uninterrupted run for both backends, every
  stateful algorithm, via ``state_dict``/``load_state_dict``;
* **Availability** — offline clients are flagged in the plan, run zero
  steps, transmit nothing, and are excluded from the aggregate;
* host stores reject ``shard_map`` meshes; bad ``store=`` args fail fast.
"""

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpoint
from repro.compress import TopK
from repro.core import fed_data
from repro.core.baselines import FedAvg, FedConfig, FedDyn, Scaffold
from repro.core.client_store import (
    ClientStore, HostStore, InMemoryStore, resolve_store)
from repro.core.clients import (
    ClientAvailability, ClientProfile, ClientSchedule)
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.core.locodl import LoCoDL, LoCoDLConfig

jax.config.update("jax_platform_name", "cpu")

N, D, S, ROUNDS = 6, 5, 3, 5


def quadratic_setup(n_clients=N, d=D, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, d))
    b = rng.normal(size=(n_clients,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(n_clients)]
    return fed_data.from_numpy_partition(x, y, parts)


def sq_loss(params, xb, yb):
    return 0.5 * jnp.mean((xb @ params["w"] - yb) ** 2)


DATA = quadratic_setup()
P0 = {"w": jnp.zeros((D,), jnp.float32)}

# every algorithm with persistent per-client state, plus FedAvg (none —
# the store must be a no-op pass-through for it)
ALGORITHMS = ["fedavg", "fedcomloc_ef", "scaffold", "feddyn", "locodl"]


def build(name, store=None, schedule=None):
    if name == "fedcomloc_ef":
        cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=N,
                              clients_per_round=S, batch_size=4,
                              variant="com", error_feedback=True)
        return FedComLoc(sq_loss, DATA, cfg, TopK(density=0.5),
                         schedule=schedule, store=store)
    if name == "locodl":
        cfg = LoCoDLConfig(gamma=0.05, p=0.25, lam=0.5, n_clients=N,
                           clients_per_round=S, batch_size=4)
        return LoCoDL(sq_loss, DATA, cfg, TopK(density=0.5),
                      schedule=schedule, store=store)
    fed = FedConfig(gamma=0.05, local_steps=4, n_clients=N,
                    clients_per_round=S, batch_size=4)
    cls = {"fedavg": FedAvg, "scaffold": Scaffold, "feddyn": FedDyn}[name]
    if name == "fedavg":
        return cls(sq_loss, DATA, fed, TopK(density=0.5),
                   schedule=schedule, store=store)
    return cls(sq_loss, DATA, fed, schedule=schedule, store=store)


def run_fused(alg, rounds=ROUNDS, seed=11):
    state, metrics = alg.run_rounds(alg.init(P0), jax.random.PRNGKey(seed),
                                    rounds)
    return state, metrics


def run_stepped(alg, rounds=ROUNDS, seed=11):
    state = alg.init(P0)
    key = jax.random.PRNGKey(seed)
    ms = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        state, m = alg.round(state, sub)
        ms.append(m)
    return state, ms


# every structural metric (bits, steps, clocks, participation) must match
# the in-memory backend bit-for-bit; the trajectory-dependent loss — and
# the params — are allclose only, because the callback boundary changes
# how XLA fuses the surrounding float ops
APPROX_METRICS = ("train_loss",)


def assert_metric(ref, got, k, label):
    if k in APPROX_METRICS:
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   rtol=1e-5, atol=1e-7,
                                   err_msg=f"{label} {k}")
    else:
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got),
                                      err_msg=f"{label} {k}")


def assert_params_close(st_ref, st, label):
    np.testing.assert_allclose(np.asarray(st_ref.x["w"]),
                               np.asarray(st.x["w"]),
                               rtol=1e-5, atol=1e-6,
                               err_msg=f"{label} params")


def assert_same_trajectory(ref, got, label):
    st_ref, m_ref = ref
    st, m = got
    assert_params_close(st_ref, st, label)
    for k in m_ref:
        assert_metric(m_ref[k], m[k], k, label)


# --------------------------------------------------------------------------- #
# 1. host backend == in-memory backend, bit-identically
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def memory_refs():
    return {name: run_fused(build(name, InMemoryStore()))
            for name in ALGORITHMS}


@pytest.mark.parametrize("name", ALGORITHMS)
def test_host_store_matches_memory_fused(name, memory_refs):
    got = run_fused(build(name, HostStore()))
    assert_same_trajectory(memory_refs[name], got, f"{name} host-fused")


@pytest.mark.parametrize("name", ALGORITHMS)
def test_host_store_matches_memory_stepped(name, memory_refs):
    """The per-round driver crosses the callback boundary once per round
    (no scan) — same trajectory as the fused in-memory reference."""
    st, ms = run_stepped(build(name, HostStore()))
    st_ref, m_ref = memory_refs[name]
    assert_params_close(st_ref, st, f"{name} stepped")
    for r, m in enumerate(ms):
        for k in m:
            assert_metric(np.asarray(m_ref[k])[r], m[k], k,
                          f"{name} stepped r{r}")


@pytest.mark.parametrize("name", ["fedcomloc_ef", "locodl"])
def test_mmap_store_matches_memory(name, memory_refs, tmp_path):
    got = run_fused(build(name, HostStore(mmap_dir=tmp_path / "spool")))
    assert_same_trajectory(memory_refs[name], got, f"{name} mmap")
    assert list((tmp_path / "spool").glob("*.mm")), "no memmap files spooled"


def test_default_store_is_memory():
    alg = build("scaffold")
    assert isinstance(alg.store, InMemoryStore)
    assert resolve_store(None).host_side is False
    with pytest.raises(TypeError, match="ClientStore"):
        resolve_store("mmap")


# --------------------------------------------------------------------------- #
# 2. lazy materialisation
# --------------------------------------------------------------------------- #

def test_gather_untouched_rows_serves_fill():
    store = HostStore()
    template = {"w": jnp.arange(4, dtype=jnp.float32)}
    tok = store.init_slot("xs", template, 100, init="broadcast")
    rows = jax.jit(lambda t, i: store.gather("xs", t, i))(
        tok, jnp.asarray([7, 93]))
    # never-scattered rows come from the single fill row: the broadcast
    # init materialised ONE copy of the template, not 100
    np.testing.assert_array_equal(np.asarray(rows["w"]),
                                  np.stack([np.arange(4.0)] * 2))
    assert not store._slots["xs"].touched.any()


def test_scatter_then_gather_roundtrip_and_telemetry():
    store = HostStore()
    tok = store.init_slot("e", {"w": jnp.zeros((3,), jnp.float32)}, 50)

    @jax.jit
    def step(tok):
        idx = jnp.asarray([4, 9])
        tok = store.scatter("e", tok, idx,
                            {"w": jnp.ones((2, 3), jnp.float32)}, None)
        return tok, store.gather("e", tok, jnp.asarray([4, 9, 30]))

    tok2, rows = step(tok)
    np.testing.assert_array_equal(
        np.asarray(rows["w"]),
        np.stack([np.ones(3), np.ones(3), np.zeros(3)]))
    assert int(tok2) == 1                      # version token bumped
    assert store._slots["e"].touched.sum() == 2
    assert store.bytes_scattered == 2 * 3 * 4
    assert store.bytes_gathered == 3 * 3 * 4


def test_init_mode_validated():
    for store in (HostStore(), InMemoryStore()):
        with pytest.raises(ValueError, match="init must be one of"):
            store.init_slot("x", {"w": jnp.zeros(2)}, 4, init="randn")


def test_host_store_rejects_mesh():
    from repro.launch.mesh import make_client_mesh
    alg = build("scaffold", HostStore())
    mesh = make_client_mesh(1)
    with pytest.raises(ValueError, match="host-side client stores"):
        alg.use_mesh(mesh)


# --------------------------------------------------------------------------- #
# 3. checkpoint-resume: both backends, every stateful algorithm
# --------------------------------------------------------------------------- #

STATEFUL = ["fedcomloc_ef", "scaffold", "feddyn", "locodl"]


@pytest.mark.parametrize("name", STATEFUL)
@pytest.mark.parametrize("backend", ["memory", "host"])
def test_resume_matches_uninterrupted(name, backend, tmp_path, memory_refs):
    """Save at round r, new process (fresh store), resume — bit-identical
    to the uninterrupted run.  The host backend checkpoints its buffers
    through ``state_dict``/``load_state_dict`` alongside the state tree."""
    R, r_save = ROUNDS, 2
    key0 = jax.random.PRNGKey(11)
    make_store = HostStore if backend == "host" else InMemoryStore
    # the bit-exact reference runs the SAME backend uninterrupted (cross-
    # backend trajectories are only allclose — different XLA fusion)
    ref = (memory_refs[name] if backend == "memory"
           else run_fused(build(name, make_store())))

    a = build(name, make_store())
    state, _ = a.run_rounds(a.init(P0), key0, r_save)
    key = key0
    for _ in range(r_save):                    # stay on the host key chain
        key, _ = jax.random.split(key)
    payload = {"state": state, "key": key}
    if backend == "host":
        payload["store"] = a.store.state_dict()
    path = tmp_path / "mid.npz"
    checkpoint.save(path, payload, meta={"rounds_done": r_save})

    b = build(name, make_store())              # simulates a fresh process
    like = {"state": b.init(P0), "key": key0}
    if backend == "host":
        like["store"] = b.store.state_dict()   # init() registered the slots
    restored, meta = checkpoint.load(path, like=like)
    assert meta["rounds_done"] == r_save
    if backend == "host":
        b.store.load_state_dict(restored["store"])
    state_b, metrics_b = b.run_rounds(restored["state"], restored["key"],
                                      R - r_save)

    st_ref, m_ref = ref
    np.testing.assert_array_equal(np.asarray(st_ref.x["w"]),
                                  np.asarray(state_b.x["w"]),
                                  err_msg=f"{name}/{backend} resume params")
    for k in m_ref:
        np.testing.assert_array_equal(
            np.asarray(m_ref[k])[r_save:], np.asarray(metrics_b[k]),
            err_msg=f"{name}/{backend} metric {k} after resume")


def test_load_state_dict_unknown_slot():
    store = HostStore()
    with pytest.raises(KeyError, match="never registered"):
        store.load_state_dict({"ghost": {}})


# --------------------------------------------------------------------------- #
# 4. availability end-to-end: offline picks excluded from the aggregate
# --------------------------------------------------------------------------- #

def churny_schedule():
    # online_frac keeps ~1/3 of the 6 clients in the population: fewer
    # than s=3 online forces offline picks into the sampled cohort
    avail = ClientAvailability.diurnal(
        N, period=5.0, amp=0.9, churn_rate=0.37, online_frac=0.34, seed=4)
    return ClientSchedule(profile=ClientProfile.homogeneous(N),
                          availability=avail)


@pytest.mark.parametrize("name", ["fedcomloc_ef", "scaffold", "locodl"])
def test_availability_excludes_offline_clients(name):
    sched = churny_schedule()
    st, m = run_fused(build(name, HostStore(), schedule=sched))
    agg = np.asarray(m["clients_aggregated"])
    steps = np.asarray(m["client_steps"])
    # the thin population forces offline picks in at least one round...
    assert (agg < S).any()
    assert agg.min() >= 0 and agg.max() <= S
    # ...and offline clients run zero local steps
    assert ((steps == 0).sum(axis=1) == S - agg).all()
    assert np.isfinite(np.asarray(st.x["w"])).all()


def test_availability_fused_matches_stepped():
    """The trace is a pure function of round_idx — the fused scan and the
    per-round driver see identical availability, hence trajectories."""
    a = build("fedcomloc_ef", schedule=churny_schedule())
    b = build("fedcomloc_ef", schedule=churny_schedule())
    st_a, m_a = run_fused(a)
    st_b, ms_b = run_stepped(b)
    np.testing.assert_array_equal(np.asarray(st_a.x["w"]),
                                  np.asarray(st_b.x["w"]))
    for r, m in enumerate(ms_b):
        for k in m:
            np.testing.assert_array_equal(np.asarray(m_a[k])[r],
                                          np.asarray(m[k]),
                                          err_msg=f"r{r} {k}")
