"""Pipelined HostStore (DESIGN.md §12): bit-identical to the plain store.

Contracts:

* **Bit-identity** — ``HostStore(prefetch=True)`` (write-behind scatters,
  plan-driven cohort prefetch) produces byte-for-byte the trajectories of
  the plain ``HostStore`` for all five algorithms, fused AND stepped,
  with and without a cohort plan (gumbel schedules get no plan — pure
  write-behind; tree/neutral schedules get planned prefetch).  Unlike the
  host-vs-memory comparison (allclose on loss/params — different XLA
  fusion), plain-vs-pipelined runs the SAME graph, so everything
  including ``train_loss`` and params must be exactly equal;
* **The plan is a hint** — prefetch hits on a correct plan, falls back
  (miss/flush-stall) on a wrong one, invalidates staged rows a scatter
  overlaps (RAW hazard) — never a wrong row;
* **Checkpoint-resume mid-pipeline** — ``state_dict`` flushes the
  write-behind queue, so save-at-r + fresh-store resume is bit-identical;
* edge cases: all-dropped cohorts (thin population), memmap spooling,
  worker-error surfacing.
"""

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpoint
from repro.core.client_store import HostStore

from tests.test_client_store import (
    ALGORITHMS, P0, ROUNDS, STATEFUL, build, churny_schedule, run_fused,
    run_stepped)

jax.config.update("jax_platform_name", "cpu")


def tree_schedule():
    return dataclasses.replace(churny_schedule(), sampler="tree")


def assert_bit_identical(ref, got, label):
    st_ref, m_ref = ref
    st_got, m_got = got
    for a, b in zip(jax.tree_util.tree_leaves(st_ref),
                    jax.tree_util.tree_leaves(st_got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{label} state leaf")
    assert set(m_ref) == set(m_got)
    for k in m_ref:
        np.testing.assert_array_equal(np.asarray(m_ref[k]),
                                      np.asarray(m_got[k]),
                                      err_msg=f"{label} metric {k}")


# --------------------------------------------------------------------------- #
# 1. pipelined == plain, all five algorithms, fused + stepped
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", ALGORITHMS)
@pytest.mark.parametrize("schedule", ["gumbel", "tree"])
def test_pipelined_matches_plain_fused(name, schedule):
    sched_fn = churny_schedule if schedule == "gumbel" else tree_schedule
    ref = run_fused(build(name, HostStore(), sched_fn()))
    alg = build(name, HostStore(prefetch=True), sched_fn())
    got = run_fused(alg)
    alg.store.flush()
    assert_bit_identical(ref, got, f"{name}/{schedule} fused")
    tel = alg.store.telemetry()
    if schedule == "tree" and name != "fedavg":
        # planned prefetch actually engaged (fedavg has no store slots)
        assert tel["prefetch_hits"] > 0
    if schedule == "gumbel":
        # no plan for in-graph gumbel sampling: write-behind only
        assert tel["prefetch_hits"] == 0


@pytest.mark.parametrize("name", ALGORITHMS)
def test_pipelined_matches_plain_stepped(name):
    sched = tree_schedule()
    st_ref, ms_ref = run_stepped(build(name, HostStore(), sched))
    alg = build(name, HostStore(prefetch=True), sched)
    st_got, ms_got = run_stepped(alg)
    alg.store.flush()
    for a, b in zip(jax.tree_util.tree_leaves(st_ref),
                    jax.tree_util.tree_leaves(st_got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} stepped state")
    for r, (ma, mb) in enumerate(zip(ms_ref, ms_got)):
        for k in ma:
            np.testing.assert_array_equal(
                np.asarray(ma[k]), np.asarray(mb[k]),
                err_msg=f"{name} stepped r{r} {k}")


def test_pipelined_matches_plain_with_mmap(tmp_path):
    sched = tree_schedule()
    ref = run_fused(build(
        "locodl", HostStore(mmap_dir=tmp_path / "plain"), sched))
    alg = build("locodl",
                HostStore(mmap_dir=tmp_path / "pipe", prefetch=True), sched)
    got = run_fused(alg)
    alg.store.flush()
    assert_bit_identical(ref, got, "locodl mmap pipelined")
    assert list((tmp_path / "pipe").glob("*.mm")), "no memmap files spooled"


def test_all_dropped_cohort_edge():
    """Rounds where every sampled client is offline (near-empty churny
    population) still pipeline bit-identically — gathers/scatters of
    fully-dropped cohorts move rows for clients that then contribute
    nothing."""
    from repro.core.clients import (
        ClientAvailability, ClientProfile, ClientSchedule)
    n = 6
    avail = ClientAvailability.diurnal(
        n, period=5.0, amp=1.0, churn_rate=0.41, online_frac=0.08, seed=4)
    sched = ClientSchedule(profile=ClientProfile.homogeneous(n),
                           availability=avail, sampler="tree")
    ref = run_fused(build("fedcomloc_ef", HostStore(), sched), rounds=8)
    got = run_fused(build("fedcomloc_ef", HostStore(prefetch=True), sched),
                    rounds=8)
    agg = np.asarray(ref[1]["clients_aggregated"])
    assert (agg == 0).any(), "schedule no longer produces an empty cohort"
    assert_bit_identical(ref, got, "all-dropped cohort")
    assert np.isfinite(np.asarray(got[1]["train_loss"])).all()


# --------------------------------------------------------------------------- #
# 2. plan-as-hint semantics: hits, misses, hazards
# --------------------------------------------------------------------------- #

def _token_plus_rows(store, name, tok, idx):
    return store.gather(name, tok, jnp.asarray(idx))


def test_correct_plan_hits_and_wrong_plan_falls_back():
    store = HostStore(prefetch=True)
    tok = store.init_slot("e", {"w": jnp.zeros((3,), jnp.float32)}, 50)
    store.submit_cohort_plan([np.asarray([4, 9])])
    store.flush()
    assert store._staged        # plan[0] staged for the registered slot

    rows = jax.jit(lambda t: store.gather("e", t, jnp.asarray([4, 9])))(tok)
    np.testing.assert_array_equal(np.asarray(rows["w"]), np.zeros((2, 3)))
    assert store.telemetry()["prefetch_hits"] == 1

    # wrong plan: staged indices don't match the gather — sync fallback
    store.submit_cohort_plan([np.asarray([1, 2])])
    store.flush()
    rows = jax.jit(lambda t: store.gather("e", t, jnp.asarray([7, 8])))(tok)
    np.testing.assert_array_equal(np.asarray(rows["w"]), np.zeros((2, 3)))
    tel = store.telemetry()
    assert tel["prefetch_misses"] == 1
    assert tel["rows_gathered"] == 4


def test_raw_hazard_invalidates_staged_rows():
    """A write-behind scatter overlapping the staged cohort must kill the
    stale staging entry; the next gather re-reads post-write rows."""
    store = HostStore(prefetch=True)
    tok = store.init_slot("e", {"w": jnp.zeros((3,), jnp.float32)}, 50)
    store.submit_cohort_plan([np.asarray([4, 9])])
    store.flush()                              # rows 4, 9 staged (zeros)

    @jax.jit
    def write_then_read(tok):
        tok = store.scatter("e", tok, jnp.asarray([9, 30]),
                            {"w": jnp.ones((2, 3), jnp.float32)}, None)
        return store.gather("e", tok, jnp.asarray([4, 9]))

    rows = write_then_read(tok)
    store.flush()
    # row 9 reflects the scatter, NOT the stale staged zeros
    np.testing.assert_array_equal(
        np.asarray(rows["w"]), np.stack([np.zeros(3), np.ones(3)]))
    tel = store.telemetry()
    assert tel["raw_hazards"] == 1
    assert tel["prefetch_hits"] == 0


def test_disjoint_scatter_keeps_staged_rows():
    store = HostStore(prefetch=True)
    tok = store.init_slot("e", {"w": jnp.zeros((3,), jnp.float32)}, 50)
    store.submit_cohort_plan([np.asarray([4, 9])])
    store.flush()

    @jax.jit
    def write_then_read(tok):
        tok = store.scatter("e", tok, jnp.asarray([30, 31]),
                            {"w": jnp.ones((2, 3), jnp.float32)}, None)
        return store.gather("e", tok, jnp.asarray([4, 9]))

    rows = write_then_read(tok)
    store.flush()
    np.testing.assert_array_equal(np.asarray(rows["w"]), np.zeros((2, 3)))
    tel = store.telemetry()
    assert tel["raw_hazards"] == 0
    assert tel["prefetch_hits"] == 1


def test_replan_flushes_and_replaces_stale_staging():
    store = HostStore(prefetch=True)
    tok = store.init_slot("e", {"w": jnp.zeros((3,), jnp.float32)}, 50)
    store.submit_cohort_plan([np.asarray([1, 2]), np.asarray([3, 4])])
    store.flush()
    store.submit_cohort_plan([np.asarray([5, 6])])
    store.flush()
    rows = jax.jit(lambda t: store.gather("e", t, jnp.asarray([5, 6])))(tok)
    np.testing.assert_array_equal(np.asarray(rows["w"]), np.zeros((2, 3)))
    assert store.telemetry()["prefetch_hits"] == 1


def test_worker_error_surfaces():
    store = HostStore(prefetch=True)
    store.init_slot("e", {"w": jnp.zeros((3,), jnp.float32)}, 50)
    with store._cond:
        store._queue.append(("apply", "ghost", np.asarray([0]),
                             [np.zeros((1, 3), np.float32)]))
        store._pending += 1
        store._cond.notify_all()
    store._ensure_worker()
    with pytest.raises(RuntimeError, match="pipeline worker failed"):
        store.flush()


# --------------------------------------------------------------------------- #
# 3. checkpoint-resume mid-pipeline
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", STATEFUL)
def test_resume_mid_pipeline_matches_uninterrupted(name, tmp_path):
    """``state_dict`` is a flush barrier: checkpointing right after a
    fused chunk (write-behind scatters possibly still queued) captures
    every committed row, and a fresh pipelined store resumes
    bit-identically."""
    sched = tree_schedule()
    R, r_save = ROUNDS, 2
    key0 = jax.random.PRNGKey(11)
    ref = run_fused(build(name, HostStore(prefetch=True), sched))

    a = build(name, HostStore(prefetch=True), sched)
    state, _ = a.run_rounds(a.init(P0), key0, r_save)
    key = key0
    for _ in range(r_save):
        key, _ = jax.random.split(key)
    path = tmp_path / "mid.npz"
    checkpoint.save(path, {"state": state, "key": key,
                           "store": a.store.state_dict()},
                    meta={"rounds_done": r_save})

    b = build(name, HostStore(prefetch=True), sched)
    like = {"state": b.init(P0), "key": key0,
            "store": b.store.state_dict()}
    restored, _ = checkpoint.load(path, like=like)
    b.store.load_state_dict(restored["store"])
    st_b, m_b = b.run_rounds(restored["state"], restored["key"], R - r_save)
    b.store.flush()

    st_ref, m_ref = ref
    np.testing.assert_array_equal(np.asarray(st_ref.x["w"]),
                                  np.asarray(st_b.x["w"]),
                                  err_msg=f"{name} resume params")
    for k in m_ref:
        np.testing.assert_array_equal(
            np.asarray(m_ref[k])[r_save:], np.asarray(m_b[k]),
            err_msg=f"{name} metric {k} after resume")


# --------------------------------------------------------------------------- #
# 4. engine guards
# --------------------------------------------------------------------------- #

def test_tree_sampler_rejects_mesh():
    from repro.launch.mesh import make_client_mesh
    alg = build("fedavg", None, tree_schedule())
    with pytest.raises(ValueError, match="host-side cohort sampling"):
        alg.use_mesh(make_client_mesh(1))
