"""Per-kernel allclose sweeps: Pallas (interpret=True) vs the pure-jnp
oracles in repro.kernels.ref, across shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_attention as fa, ops, quantize, ref,
                           rglru_scan as rg, topk_compress, wkv6)

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------- #
# TopK radix select
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("n,k", [
    (128, 1), (1000, 100), (1024, 1024 - 1), (4096, 2048),
    (5000, 13), (333, 300),
])
def test_topk_matches_oracle(n, k):
    x = jax.random.normal(jax.random.PRNGKey(n + k), (n,))
    a = ref.topk_mask(x, k)
    b = topk_compress.topk_mask(x, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_topk_with_ties():
    x = jnp.asarray([1.0, -1.0, 1.0, 0.5, 2.0] * 40)
    a = ref.topk_mask(x, 3)
    b = topk_compress.topk_mask(x, 3, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # threshold semantics: all ties at the kth value are kept
    assert int((np.asarray(b) != 0).sum()) >= 3


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (777,)).astype(dtype)
    a = ref.topk_mask(x.astype(jnp.float32), 77).astype(dtype)
    b = topk_compress.topk_mask(x.astype(jnp.float32), 77,
                                interpret=True).astype(dtype)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
# Sort-based dynamic-k TopK (traced k, DESIGN.md §5) vs the static oracle
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("n,k", [
    # the edges the static sweep above misses: k=0 (clamped to 1, a TopK
    # payload is never empty), k=n (dense: every entry kept), and their
    # neighbours
    (128, 0), (128, 1), (128, 127), (128, 128), (333, 0), (333, 333),
])
def test_topk_dynamic_k_edges_match_static(n, k):
    x = jax.random.normal(jax.random.PRNGKey(n + k), (n,))
    want = ref.topk_mask(x, min(max(k, 1), n))     # documented clamp
    got = ref.topk_mask_dynamic(x, jnp.asarray(k, jnp.int32))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    # the ops dispatcher must route a traced k onto the same path
    via_ops = ops.topk_mask(x, jnp.asarray(k, jnp.int32))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(via_ops))


def test_topk_dynamic_k_equals_full_input_at_k_n():
    """k = n is the dense payload: the mask keeps every entry, x == out."""
    x = jax.random.normal(jax.random.PRNGKey(0), (257,))
    out = ref.topk_mask_dynamic(x, jnp.asarray(257, jnp.int32))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(out))


@pytest.mark.parametrize("k", [0, 7, 77, 777])
def test_topk_dynamic_bf16_matches_static(k):
    """bf16 inputs round many magnitudes onto ties; both paths use
    threshold semantics on the same k-th value, so the masks agree."""
    x = jax.random.normal(jax.random.PRNGKey(3), (777,)).astype(jnp.bfloat16)
    want = ref.topk_mask(x, min(max(k, 1), 777))
    got = ref.topk_mask_dynamic(x, jnp.asarray(k, jnp.int32))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(want, np.float32),
                                  np.asarray(got, np.float32))


def test_topk_dynamic_under_vmap_per_client_k():
    """One vmapped call with per-client k values, edges included, equals
    the per-row static masks (the §5 per-client density machinery)."""
    xs = jax.random.normal(jax.random.PRNGKey(5), (4, 64))
    ks = jnp.asarray([0, 1, 32, 64], jnp.int32)
    got = jax.vmap(ref.topk_mask_dynamic)(xs, ks)
    for i, k in enumerate([0, 1, 32, 64]):
        want = ref.topk_mask(xs[i], min(max(k, 1), 64))
        np.testing.assert_array_equal(np.asarray(want),
                                      np.asarray(got[i]), err_msg=f"k={k}")


# --------------------------------------------------------------------------- #
# QSGD quantization
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("n", [64, 1000, 4096])
@pytest.mark.parametrize("r", [1, 4, 8])
def test_quantize_matches_oracle(n, r):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    u = jax.random.uniform(jax.random.PRNGKey(n + 1), (n,))
    a = ref.quantize_qr_with_uniforms(x, r, u)
    b = quantize.quantize_qr_with_uniforms(x, r, u, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-7)


def test_quantize_zero_vector():
    x = jnp.zeros((256,))
    u = jnp.full((256,), 0.5)
    b = quantize.quantize_qr_with_uniforms(x, 4, u, interpret=True)
    np.testing.assert_array_equal(np.asarray(b), 0.0)


@pytest.mark.parametrize("r", [1, 4])
def test_quantize_traced_r_matches_static(r):
    """The §5 per-client override path traces r; at the same key it must
    be bit-identical to the static-r oracle — r=1 (binary sign levels) is
    the edge where 2**r arithmetic differences would show first."""
    x = jax.random.normal(jax.random.PRNGKey(r), (513,))
    key = jax.random.PRNGKey(r + 1)
    want = ref.quantize_qr(x, r, key)
    got = ops.quantize_qr(x, jnp.asarray(r, jnp.int32), key)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_quantize_r1_values_on_sign_grid():
    """r=1 payloads live on the 2-level grid {0, ±norm/2, ±norm}."""
    x = jax.random.normal(jax.random.PRNGKey(9), (256,))
    out = np.asarray(ref.quantize_qr(x, 1, jax.random.PRNGKey(10)))
    norm = float(jnp.linalg.norm(x))
    levels = np.abs(out) / norm * 2
    np.testing.assert_allclose(levels, np.round(levels), atol=1e-5)
    assert levels.max() <= 2 + 1e-6


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=64),
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=32, softcap=50.0),
])
def test_flash_matches_oracle(kwargs):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 4, 128, 64))
    k = jax.random.normal(ks[1], (2, 2, 128, 64))
    v = jax.random.normal(ks[2], (2, 2, 128, 64))
    a = ref.mha_attention(q, k, v, **kwargs)
    b = fa.flash_attention(q, k, v, interpret=True, bq=64, bk=64, **kwargs)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("hq,hkv,dh", [(8, 8, 32), (8, 1, 64), (6, 2, 128)])
def test_flash_gqa_shapes(hq, hkv, dh):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, hq, 128, dh))
    k = jax.random.normal(ks[1], (1, hkv, 128, dh))
    v = jax.random.normal(ks[2], (1, hkv, 128, dh))
    a = ref.mha_attention(q, k, v, causal=True)
    b = fa.flash_attention(q, k, v, interpret=True, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_offset():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 4, 1, 64))
    k = jax.random.normal(ks[1], (2, 2, 256, 64))
    v = jax.random.normal(ks[2], (2, 2, 256, 64))
    a = ref.mha_attention(q, k, v, causal=True, q_offset=255)
    b = fa.flash_attention(q, k, v, causal=True, q_offset=255,
                           interpret=True, bq=1, bk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    a = ref.mha_attention(q, k, v, causal=True)
    b = fa.flash_attention(q, k, v, causal=True, interpret=True,
                           bq=64, bk=64)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------- #
# RG-LRU scan
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("b,t,d,bt,bd", [
    (1, 8, 128, 8, 128), (2, 64, 256, 8, 128), (3, 32, 384, 16, 128),
])
def test_rglru_matches_oracle(b, t, d, bt, bd):
    ks = jax.random.split(jax.random.PRNGKey(t + d), 2)
    x = jax.random.normal(ks[0], (b, t, d))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, d)))
    ya, ha = ref.rglru_scan(x, a)
    yb, hb = rg.rglru_scan(x, a, interpret=True, bt=bt, bd=bd)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(hb),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------- #
# RWKV6 WKV scan
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("b,h,t,kd", [(1, 1, 16, 64), (2, 3, 64, 64)])
def test_wkv6_matches_oracle(b, h, t, kd):
    ks = jax.random.split(jax.random.PRNGKey(b * h + t), 5)
    r = jax.random.normal(ks[0], (b, h, t, kd)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, kd)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, kd)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, kd)))
    u = jax.random.normal(ks[4], (h, kd)) * 0.1
    ya, sa = ref.wkv6_scan(r, k, v, w, u)
    yb, sb = wkv6.wkv6_scan(r, k, v, w, u, interpret=True, bt=min(16, t))
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                               rtol=3e-4, atol=3e-4)


def test_wkv6_chunked_equals_flat():
    """The two-level remat scan is numerically identical to a flat scan."""
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    b, h, t, kd = 2, 2, 64, 32
    r = jax.random.normal(ks[0], (b, h, t, kd)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, kd)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, kd)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, kd)))
    u = jax.random.normal(ks[4], (h, kd)) * 0.1
    ya, sa = ref.wkv6_scan(r, k, v, w, u, chunk=t)   # single chunk = flat
    yb, sb = ref.wkv6_scan(r, k, v, w, u, chunk=8)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# custom-VJP flash gradient vs naive autodiff
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=True, window=16),
    dict(causal=True, softcap=20.0),
])
def test_flash_custom_vjp_grads(kwargs):
    from repro.models import attention as attn
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 4, 64, 32))
    k = jax.random.normal(ks[1], (2, 2, 64, 32))
    v = jax.random.normal(ks[2], (2, 2, 64, 32))

    def f_ref(q_, k_, v_):
        return (ref.mha_attention(q_, k_, v_, **kwargs)
                .astype(jnp.float32) ** 2).sum()

    def f_new(q_, k_, v_):
        return (attn.chunked_attention(q_, k_, v_, chunk=16, **kwargs)
                .astype(jnp.float32) ** 2).sum()

    ga = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)
