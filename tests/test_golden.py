"""Golden-trace regression tests: committed fixed-seed trajectories.

``tests/golden/<algorithm>_<policy>.json`` holds a tiny 3-round metrics
trajectory (fused ``run_rounds``, fixed seeds, lognormal client speeds)
for all five algorithms x the three aggregation policies (DESIGN.md §7);
the LoCoDL traces additionally pin its account-mode downlink bits (§10).
Future refactors cannot silently shift the bit accounting, the RNG key
chain, the straggler schedule or the policy semantics: any such change
trips an exact comparison here and must be accompanied by a deliberate
trace regeneration:

    PYTHONPATH=src python tests/test_golden.py --write

Per-metric tolerances: counting/accounting metrics (steps, bits,
staleness, participation) compare **exactly**; sim-clock metrics compare
at rtol 1e-6 (pure arithmetic on exact inputs); the trajectory-dependent
``train_loss`` at rtol 2e-4 (XLA may re-fuse reductions across versions).
"""

import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import TopK
from repro.core import fed_data
from repro.core.aggregation import AggregationPolicy
from repro.core.baselines import FedAvg, FedConfig, FedDyn, Scaffold
from repro.core.clients import ClientProfile, ClientSchedule
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.core.locodl import LoCoDL, LoCoDLConfig

jax.config.update("jax_platform_name", "cpu")

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
N, D, S, ROUNDS, SEED = 6, 8, 4, 3, 123

# metric -> (rtol, atol); None = exact
TOLERANCES = {
    "train_loss": (2e-4, 1e-6),
    "sim_time": (1e-6, 0.0),
    "client_finish": (1e-6, 0.0),
}

POLICIES = {
    "sync": None,
    "semi_sync": AggregationPolicy.semi_sync(2),
    "async_buffered": AggregationPolicy.async_buffered(2, 0.5),
}


def quadratic_data():
    rng = np.random.default_rng(0)
    A = rng.normal(size=(N, D))
    b = rng.normal(size=(N,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(N)]
    return fed_data.from_numpy_partition(x, y, parts)


def sq_loss(params, xb, yb):
    return 0.5 * jnp.mean((xb @ params["w"] - yb) ** 2)


def schedule():
    return ClientSchedule(
        profile=ClientProfile.lognormal(N, speed_sigma=1.0, seed=3),
        bit_cost=1e-6)


def build(algorithm, policy_name):
    data, policy = quadratic_data(), POLICIES[policy_name]
    if algorithm == "fedcomloc":
        cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=N,
                              clients_per_round=S, batch_size=4,
                              variant="com")
        return FedComLoc(sq_loss, data, cfg, TopK(density=0.5),
                         schedule=schedule(), policy=policy)
    if algorithm == "locodl":
        cfg = LoCoDLConfig(gamma=0.05, p=0.25, lam=0.5, n_clients=N,
                           clients_per_round=S, batch_size=4)
        return LoCoDL(sq_loss, data, cfg, TopK(density=0.5),
                      schedule=schedule(), policy=policy,
                      downlink="account",
                      downlink_compressor=TopK(density=0.5))
    fed = FedConfig(gamma=0.05, local_steps=4, n_clients=N,
                    clients_per_round=S, batch_size=4)
    cls = {"fedavg": FedAvg, "scaffold": Scaffold, "feddyn": FedDyn}[algorithm]
    kw = {"compressor": TopK(density=0.5)} if algorithm == "fedavg" else {}
    return cls(sq_loss, data, fed, schedule=schedule(), policy=policy, **kw)


def trace(algorithm, policy_name) -> dict:
    alg = build(algorithm, policy_name)
    state = alg.init({"w": jnp.zeros((D,), jnp.float32)})
    _, metrics = alg.run_rounds(state, jax.random.PRNGKey(SEED), ROUNDS)
    return {k: np.asarray(v, np.float64).tolist()
            for k, v in sorted(metrics.items())}


ALGORITHMS = ("fedcomloc", "locodl", "fedavg", "scaffold", "feddyn")
CASES = [(a, p) for a in ALGORITHMS for p in POLICIES]


@pytest.mark.parametrize("algorithm,policy_name", CASES)
def test_matches_golden_trace(algorithm, policy_name):
    path = GOLDEN_DIR / f"{algorithm}_{policy_name}.json"
    assert path.exists(), (
        f"missing golden trace {path.name}; regenerate with "
        f"`PYTHONPATH=src python tests/test_golden.py --write`")
    golden = json.loads(path.read_text())
    assert golden["rounds"] == ROUNDS
    live = trace(algorithm, policy_name)
    assert sorted(live) == sorted(golden["metrics"]), (
        "metric set changed — regenerate the golden traces deliberately")
    for k, want in golden["metrics"].items():
        got = np.asarray(live[k], np.float64)
        tol = TOLERANCES.get(k)
        if tol is None:
            np.testing.assert_array_equal(
                got, np.asarray(want), err_msg=f"{path.name} metric {k}")
        else:
            np.testing.assert_allclose(
                got, np.asarray(want), rtol=tol[0], atol=tol[1],
                err_msg=f"{path.name} metric {k}")


def write_golden() -> None:
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    for algorithm, policy_name in CASES:
        path = GOLDEN_DIR / f"{algorithm}_{policy_name}.json"
        path.write_text(json.dumps(
            {"algorithm": algorithm, "policy": policy_name,
             "rounds": ROUNDS, "seed": SEED,
             "metrics": trace(algorithm, policy_name)},
            indent=1, sort_keys=True) + "\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    if "--write" not in sys.argv:
        sys.exit("usage: PYTHONPATH=src python tests/test_golden.py --write")
    write_golden()
