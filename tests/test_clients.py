"""Client-heterogeneity layer (DESIGN.md §5).

Contracts:

* with a homogeneous profile the schedule-aware round implementations
  reproduce the schedule-less (today's) trajectories exactly;
* heterogeneous per-client step counts + per-client TopK densities run
  under both drivers — ``round()`` and the fused ``run_rounds()`` — with
  bit-identical trajectories, and the per-client uplink bits match the
  §3.2 formulas (nnz from each client's actual mask), for both
  ``impl="select"`` and ``impl="quantile"``;
* straggler deadline/dropout semantics: dropped clients transmit nothing,
  keep their control variates, and are excluded from the server average;
* geometric local-step sampling: truncation at ``steps_cap``, mean ≈ 1/p
  for small p, and fixed == geometric when the draw equals the cap;
* config/schedule validation fails fast.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import Compose, QuantQr, TopK
from repro.core import fed_data, server
from repro.core.baselines import FedAvg, FedConfig, FedDyn, Scaffold
from repro.core.clients import ClientProfile, ClientSchedule
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

jax.config.update("jax_platform_name", "cpu")


def quadratic_setup(n_clients=6, d=8, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, d))
    b = rng.normal(size=(n_clients,))
    reps = 8
    x = np.repeat(A, reps, axis=0).astype(np.float32)
    y = np.repeat(b, reps).astype(np.float32)
    parts = [np.arange(i * reps, (i + 1) * reps) for i in range(n_clients)]
    return fed_data.from_numpy_partition(x, y, parts)


def sq_loss(params, xb, yb):
    return 0.5 * jnp.mean((xb @ params["w"] - yb) ** 2)


def drive(alg, d, rounds, seed=0, w0=None):
    state = alg.init({"w": jnp.zeros((d,), jnp.float32) if w0 is None
                      else w0})
    key = jax.random.PRNGKey(seed)
    ms = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        state, m = alg.round(state, sub)
        ms.append(m)
    return state, ms


# --------------------------------------------------------------------------- #
# 1. Homogeneous profile == today's schedule-less behaviour, exactly
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("variant,comp", [
    ("com", TopK(density=0.4)),
    ("local", TopK(density=0.5)),
    ("global", QuantQr(r=6)),
])
def test_homogeneous_schedule_is_identity(variant, comp):
    n, d = 6, 8
    data = quadratic_setup(n, d)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=3, batch_size=4, variant=variant)
    base = FedComLoc(sq_loss, data, cfg, comp)
    homog = FedComLoc(sq_loss, data, cfg, comp,
                      schedule=ClientSchedule.homogeneous(n))
    sa, ma = drive(base, d, 6)
    sb, mb = drive(homog, d, 6)
    np.testing.assert_array_equal(np.asarray(sa.x["w"]), np.asarray(sb.x["w"]))
    np.testing.assert_array_equal(np.asarray(sa.h["w"]), np.asarray(sb.h["w"]))
    for a, b in zip(ma, mb):
        assert a["train_loss"] == b["train_loss"]
        assert a["uplink_bits"] == b["uplink_bits"]
        assert a["downlink_bits"] == b["downlink_bits"]


# --------------------------------------------------------------------------- #
# 2. Heterogeneous rounds: both drivers, bit-identical, exact per-client bits
# --------------------------------------------------------------------------- #

def het_schedule(n, *, drop=False, impl_density=0.3):
    profile = ClientProfile(
        speed=jnp.asarray(np.linspace(0.3, 2.1, n), jnp.float32),
        bandwidth=jnp.asarray(np.linspace(2.0, 0.5, n), jnp.float32),
    ).with_density_allocation(impl_density, mode="bandwidth")
    return ClientSchedule(profile=profile, deadline=3.0,
                          drop_stragglers=drop, bit_cost=1e-6)


@pytest.mark.parametrize("impl", ["select", "quantile"])
@pytest.mark.parametrize("drop", [False, True])
def test_het_round_matches_run_rounds(impl, drop):
    n, d, R = 6, 8, 5
    data = quadratic_setup(n, d)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=4, batch_size=4, variant="com")
    mk = lambda: FedComLoc(sq_loss, data, cfg, TopK(density=0.3, impl=impl),
                           schedule=het_schedule(n, drop=drop))
    alg_a, alg_b = mk(), mk()
    sa, per = drive(alg_a, d, R, seed=42)
    sb = alg_b.init({"w": jnp.zeros((d,), jnp.float32)})
    sb, fused = alg_b.run_rounds(sb, jax.random.PRNGKey(42), R)

    np.testing.assert_array_equal(np.asarray(sa.x["w"]), np.asarray(sb.x["w"]))
    np.testing.assert_array_equal(np.asarray(sa.h["w"]), np.asarray(sb.h["w"]))
    for i, m in enumerate(per):
        assert m["uplink_bits"] == float(fused["uplink_bits"][i])
        assert m["sim_time"] == float(fused["sim_time"][i])
        np.testing.assert_array_equal(np.asarray(m["client_steps"]),
                                      fused["client_steps"][i])
        np.testing.assert_array_equal(np.asarray(m["client_uplink_bits"]),
                                      fused["client_uplink_bits"][i])
    assert alg_a.meter.snapshot() == alg_b.meter.snapshot()
    # per-client bits sum to the round total
    np.testing.assert_allclose(fused["client_uplink_bits"].sum(axis=1),
                               fused["uplink_bits"])


@pytest.mark.parametrize("impl", ["select", "quantile"])
def test_per_client_bits_match_formulas(impl):
    """Full participation: sorted per-client uplink bits == the §3.2 TopK
    formula 64·nnz with nnz = each client's k_i (no ties for generic float
    data), and per-client steps == the deadline truncation."""
    n, d = 6, 8
    data = quadratic_setup(n, d)
    sched = het_schedule(n)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=n, batch_size=4, variant="com")
    alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.3, impl=impl),
                    schedule=sched)
    # nonzero init: a zero-step straggler retransmits the broadcast model,
    # and nnz-from-mask only equals k for generically nonzero payloads
    _, ms = drive(alg, d, 3,
                  w0=jax.random.normal(jax.random.PRNGKey(7), (d,)))
    dens = np.asarray(sched.profile.comp_params["density"])
    exp_k = np.clip(np.round(dens * d), 1, d)
    exp_steps = np.minimum(cfg.steps_cap,
                           np.floor(3.0 * np.asarray(sched.profile.speed)))
    for m in ms:
        np.testing.assert_array_equal(np.sort(m["client_uplink_bits"]),
                                      np.sort(64.0 * exp_k))
        np.testing.assert_array_equal(np.sort(m["client_steps"]),
                                      np.sort(exp_steps))


def test_per_client_quant_bits():
    """Per-client Q_r bit widths: (1+r_i)·d + 32 per tensor, exactly."""
    n, d = 5, 16
    data = quadratic_setup(n, d)
    rs = np.asarray([2, 4, 6, 8, 3])
    profile = ClientProfile.homogeneous(n).with_comp_param(
        "r", jnp.asarray(rs, jnp.int32))
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=n, batch_size=4, variant="com")
    alg = FedComLoc(sq_loss, data, cfg, QuantQr(r=8),
                    schedule=ClientSchedule(profile=profile))
    _, ms = drive(alg, d, 2)
    expected = (1 + rs) * d + 32
    for m in ms:
        np.testing.assert_array_equal(np.sort(m["client_uplink_bits"]),
                                      np.sort(expected.astype(np.float64)))


def test_local_variant_accepts_per_client_density():
    n, d = 5, 8
    data = quadratic_setup(n, d)
    profile = ClientProfile.homogeneous(n).with_density_allocation(0.5)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=3, batch_size=4, variant="local")
    alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.5),
                    schedule=ClientSchedule(profile=profile))
    state, ms = drive(alg, d, 3)
    assert np.isfinite(ms[-1]["train_loss"])


# --------------------------------------------------------------------------- #
# 3. Straggler dropout semantics
# --------------------------------------------------------------------------- #

def test_dropped_straggler_transmits_nothing_and_keeps_state():
    n, d = 5, 8
    data = quadratic_setup(n, d)
    speed = np.ones(n, np.float32)
    speed[0] = 1e-3                       # client 0 can't finish one step
    sched = ClientSchedule(
        profile=ClientProfile(speed=jnp.asarray(speed),
                              bandwidth=jnp.ones((n,), jnp.float32)),
        deadline=10.0, drop_stragglers=True)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=n, batch_size=4, variant="com")
    alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.5), schedule=sched)
    state = alg.init({"w": jnp.zeros((d,), jnp.float32)})
    state, m = alg.round(state, jax.random.PRNGKey(0))
    steps = np.asarray(m["client_steps"])
    bits = np.asarray(m["client_uplink_bits"])
    assert (steps == 0).sum() == 1        # exactly the slow client dropped
    assert bits[steps == 0] == 0.0        # no uplink payload
    assert m["uplink_bits"] == bits.sum()
    # the dropped client's control variate is untouched (h starts at 0;
    # participants moved theirs)
    h = np.asarray(state.h["w"])          # rows follow client ids
    assert np.all(h[0] == 0.0)
    assert np.all(np.any(h[1:] != 0.0, axis=1))
    # a dropped straggler holds the round until the deadline
    assert m["sim_time"] == pytest.approx(10.0)


def test_all_dropped_round_keeps_server_model():
    """A round where every sampled client misses the deadline must leave
    the server model untouched (not zero it out)."""
    n, d = 4, 6
    data = quadratic_setup(n, d)
    sched = ClientSchedule(
        profile=ClientProfile(speed=jnp.full((n,), 1e-3),
                              bandwidth=jnp.ones((n,), jnp.float32)),
        deadline=1.0, drop_stragglers=True)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=2, batch_size=4, variant="com")
    alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.5), schedule=sched)
    w0 = jax.random.normal(jax.random.PRNGKey(11), (d,))
    state, ms = drive(alg, d, 2, w0=w0)
    np.testing.assert_array_equal(np.asarray(state.x["w"]), np.asarray(w0))
    np.testing.assert_array_equal(np.asarray(state.h["w"]), 0.0)
    assert all(m["uplink_bits"] == 0.0 for m in ms)

    for cls in (FedAvg, FedDyn):
        bcfg = FedConfig(gamma=0.05, local_steps=5, n_clients=n,
                         clients_per_round=2, batch_size=4)
        balg = cls(sq_loss, data, bcfg, schedule=sched)
        bstate, _ = drive(balg, d, 2, w0=w0)
        np.testing.assert_array_equal(np.asarray(bstate.x["w"]),
                                      np.asarray(w0))


def test_dropout_requires_deadline():
    with pytest.raises(ValueError):
        ClientSchedule(profile=ClientProfile.homogeneous(4),
                       drop_stragglers=True)


# --------------------------------------------------------------------------- #
# 4. Baselines consume schedules
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("cls", [FedAvg, Scaffold, FedDyn])
def test_baselines_run_heterogeneous(cls):
    n, d = 6, 8
    data = quadratic_setup(n, d)
    cfg = FedConfig(gamma=0.05, local_steps=5, n_clients=n,
                    clients_per_round=4, batch_size=4)
    sched = ClientSchedule(
        profile=ClientProfile.uniform(n, lo=0.3, hi=2.0, seed=1),
        deadline=4.0, drop_stragglers=True)
    alg = cls(sq_loss, data, cfg, schedule=sched)
    state, ms = drive(alg, d, 8)
    losses = [m["train_loss"] for m in ms]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
    assert all(0 <= m["sim_time"] <= 4.0 + 1e-6 for m in ms)


def test_scaffold_zero_step_client_keeps_control_variate():
    """Deadline without dropping: a client that completes zero steps did no
    work, so its Scaffold control variate must not shift by -c."""
    n, d = 5, 6
    data = quadratic_setup(n, d)
    speed = np.ones(n, np.float32)
    speed[0] = 1e-3
    sched = ClientSchedule(
        profile=ClientProfile(speed=jnp.asarray(speed),
                              bandwidth=jnp.ones((n,), jnp.float32)),
        deadline=2.0, drop_stragglers=False)
    cfg = FedConfig(gamma=0.05, local_steps=5, n_clients=n,
                    clients_per_round=n, batch_size=4)
    alg = Scaffold(sq_loss, data, cfg, schedule=sched)
    state, _ = drive(alg, d, 6)
    ci = np.asarray(state.ci["w"])
    assert np.all(ci[0] == 0.0), ci[0]          # never did a step
    assert np.any(np.asarray(state.c["w"]) != 0.0)


def test_fedavg_het_round_matches_run_rounds():
    n, d, R = 6, 8, 5
    data = quadratic_setup(n, d)
    cfg = FedConfig(gamma=0.05, local_steps=5, n_clients=n,
                    clients_per_round=3, batch_size=4)
    mk = lambda: FedAvg(
        sq_loss, data, cfg, TopK(density=0.4),
        schedule=het_schedule(n, drop=True))
    a, b = mk(), mk()
    sa, _ = drive(a, d, R, seed=9)
    sb = b.init({"w": jnp.zeros((d,), jnp.float32)})
    sb, _ = b.run_rounds(sb, jax.random.PRNGKey(9), R)
    np.testing.assert_array_equal(np.asarray(sa.x["w"]), np.asarray(sb.x["w"]))
    assert a.meter.snapshot() == b.meter.snapshot()


# --------------------------------------------------------------------------- #
# 5. Geometric local-step sampling (satellite)
# --------------------------------------------------------------------------- #

def make_geom_alg(p, n=4, d=3, **cfg_kw):
    data = quadratic_setup(n, d)
    cfg = FedComLocConfig(gamma=0.05, p=p, n_clients=n, clients_per_round=2,
                          batch_size=4, variant="none",
                          local_steps="geometric", **cfg_kw)
    from repro.compress import Identity
    return FedComLoc(sq_loss, data, cfg, Identity()), d


def test_geometric_truncates_at_cap():
    alg, _ = make_geom_alg(p=0.05, max_local_steps=7)
    keys = jax.random.split(jax.random.PRNGKey(0), 400)
    draws = np.asarray(jax.vmap(alg._num_local_steps)(keys))
    assert draws.min() >= 1
    assert draws.max() == 7               # p=0.05 ⇒ the cap binds often


def test_geometric_mean_close_to_1_over_p():
    p = 0.05
    alg, _ = make_geom_alg(p=p)            # default cap 4/p = 80
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    draws = np.asarray(jax.vmap(alg._num_local_steps)(keys))
    # E[min(Geom(p), 80)] = (1 - (1-p)^80)/p ≈ 19.67 for p = 0.05
    expected = (1 - (1 - p) ** 80) / p
    assert abs(draws.mean() - expected) < 1.0, (draws.mean(), expected)
    assert draws.max() <= alg.cfg.steps_cap


def test_fixed_equals_geometric_when_draw_equals_cap():
    """With cap = 1 every geometric draw is clipped to the cap, so the two
    step modes must produce identical trajectories."""
    n, d = 4, 3
    data = quadratic_setup(n, d)
    runs = {}
    for mode in ("fixed", "geometric"):
        cfg = FedComLocConfig(gamma=0.05, p=0.3, n_clients=n,
                              clients_per_round=2, batch_size=4,
                              variant="none", local_steps=mode,
                              max_local_steps=1)
        from repro.compress import Identity
        alg = FedComLoc(sq_loss, data, cfg, Identity())
        state, ms = drive(alg, d, 6, seed=3)
        runs[mode] = (np.asarray(state.x["w"]),
                      [m["num_local_steps"] for m in ms])
    assert runs["fixed"][1] == runs["geometric"][1] == [1.0] * 6
    np.testing.assert_array_equal(runs["fixed"][0], runs["geometric"][0])


# --------------------------------------------------------------------------- #
# 6. Validation + History satellites
# --------------------------------------------------------------------------- #

def test_config_rejects_bad_client_counts():
    with pytest.raises(ValueError):
        FedComLocConfig(n_clients=3, clients_per_round=4)
    with pytest.raises(ValueError):
        FedComLocConfig(n_clients=3, clients_per_round=0)
    with pytest.raises(ValueError):
        FedComLocConfig(n_clients=0, clients_per_round=0)
    with pytest.raises(ValueError):
        FedConfig(n_clients=3, clients_per_round=4)
    with pytest.raises(ValueError):
        FedConfig(local_steps=0)


def test_schedule_validation():
    n, d = 4, 3
    data = quadratic_setup(n, d)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=2, batch_size=4, variant="com")
    with pytest.raises(ValueError):   # profile size mismatch
        FedComLoc(sq_loss, data, cfg, TopK(density=0.5),
                  schedule=ClientSchedule.homogeneous(n + 1))
    with pytest.raises(ValueError):   # density override vs quantizer
        FedComLoc(sq_loss, data, cfg, QuantQr(r=4),
                  schedule=ClientSchedule(
                      profile=ClientProfile.homogeneous(n)
                      .with_density_allocation(0.5)))
    # Compose accepts both density and r overrides
    FedComLoc(sq_loss, data, cfg, Compose(TopK(0.5), QuantQr(4)),
              schedule=ClientSchedule(
                  profile=ClientProfile.homogeneous(n)
                  .with_density_allocation(0.5)
                  .with_comp_param("r", jnp.full((n,), 4, jnp.int32))))


def test_out_of_range_comp_params_rejected():
    """Traced overrides bypass the compressors' __post_init__ checks, so
    per-client values are range-validated at schedule-build time."""
    n, d = 4, 3
    data = quadratic_setup(n, d)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=2, batch_size=4, variant="com")
    bad_density = ClientSchedule(profile=ClientProfile.homogeneous(n)
                                 .with_comp_param("density", jnp.zeros((n,))))
    with pytest.raises(ValueError):
        FedComLoc(sq_loss, data, cfg, TopK(density=0.5),
                  schedule=bad_density)
    bad_r = ClientSchedule(profile=ClientProfile.homogeneous(n)
                           .with_comp_param("r", jnp.full((n,), -1,
                                                          jnp.int32)))
    with pytest.raises(ValueError):
        FedComLoc(sq_loss, data, cfg, QuantQr(r=4), schedule=bad_r)
    # an algorithm with no compressor can't consume comp_params at all
    with pytest.raises(ValueError):
        Scaffold(sq_loss, data,
                 FedConfig(gamma=0.05, local_steps=5, n_clients=n,
                           clients_per_round=2, batch_size=4),
                 schedule=ClientSchedule(
                     profile=ClientProfile.homogeneous(n)
                     .with_density_allocation(0.5)))


def test_history_records_downlink_and_final_params():
    assert "final_params" in {f.name for f in dataclasses.fields(server.History)}
    n, d = 4, 3
    data = quadratic_setup(n, d)
    cfg = FedComLocConfig(gamma=0.05, p=0.25, n_clients=n,
                          clients_per_round=2, batch_size=4, variant="com")
    alg = FedComLoc(sq_loss, data, cfg, TopK(density=0.5))
    hist = server.run_federated(
        alg, {"w": jnp.zeros((d,), jnp.float32)}, num_rounds=6,
        key=jax.random.PRNGKey(0),
        eval_fn=lambda p: (jnp.zeros(()), jnp.zeros(())), eval_every=3)
    assert hist.downlink_bits and hist.downlink_bits[-1] > 0
    assert hist.downlink_bits[-1] == alg.meter.downlink_bits
    assert hist.sim_time and hist.sim_time[-1] > 0
    assert hist.final_params is not None
    d_ = hist.as_dict()
    assert "downlink_bits" in d_ and "sim_time" in d_
    assert "final_params" not in d_   # json-friendly view


# --------------------------------------------------------------------------- #
# 7. finish_times / allocation-budget / availability regressions (§11 PR)
# --------------------------------------------------------------------------- #

def test_dropped_straggler_finish_is_exactly_deadline():
    """Regression: a §5-dropped straggler transmits nothing, so its finish
    time is the deadline EXACTLY — the uplink comm term must be zeroed for
    non-participants inside finish_times, not trusted to callers.  The old
    code added ``bits·bit_cost/bw`` on top of the deadline whenever the
    caller passed unmasked bits, inflating ``sim_time``."""
    n = 4
    speed = jnp.asarray([1.0, 1.0, 1.0, 1e-3])    # client 3 finishes 0 steps
    sched = ClientSchedule(
        profile=ClientProfile(speed=speed, bandwidth=jnp.ones((n,))),
        deadline=2.0, drop_stragglers=True, step_cost=1.0, bit_cost=1e-3)
    plan = sched.plan(jnp.arange(n), nominal_steps=2)
    assert not bool(plan.participating[3])
    bits = jnp.full((n,), 1e6, jnp.float32)        # unmasked: bits for all
    finish = np.asarray(sched.finish_times(plan, bits))
    # the dropped straggler holds the round open until the deadline — and
    # not a microsecond longer: it never transmits
    assert finish[3] == 2.0
    np.testing.assert_array_equal(
        finish, np.asarray(sched.finish_times(plan, bits * plan.participating)))
    # participants: compute + comm as before
    np.testing.assert_allclose(finish[0], 2.0 * 1.0 + 1e6 * 1e-3 / 1.0,
                               rtol=1e-6)
    assert float(sched.sim_time(plan, bits)) == pytest.approx(1002.0)


def test_bandwidth_density_allocation_preserves_budget():
    """mean(density) == base_density even when the [floor, 1] clip binds —
    the total bit budget must not silently drift with the clip."""
    # heavy-tailed bandwidths: naive d_i = base·bw_i/mean clips hard at 1
    bw = jnp.asarray([0.05, 0.1, 0.2, 0.4, 8.0, 20.0], jnp.float32)
    prof = ClientProfile(speed=jnp.ones((6,)), bandwidth=bw)
    for base in (0.3, 0.5, 0.8):
        d = np.asarray(prof.with_density_allocation(
            base, mode="bandwidth", floor=0.05).comp_params["density"])
        assert (d >= 0.05 - 1e-6).all() and (d <= 1.0 + 1e-6).all()
        np.testing.assert_allclose(d.mean(), base, atol=1e-6,
                                   err_msg=f"budget drift at base={base}")
        # fast links still carry denser payloads
        assert d[-1] >= d[0]
    # the unclipped case keeps the plain proportional formula
    mild = ClientProfile(speed=jnp.ones((4,)),
                         bandwidth=jnp.asarray([0.8, 0.9, 1.1, 1.2]))
    d = np.asarray(mild.with_density_allocation(
        0.5, mode="bandwidth").comp_params["density"])
    np.testing.assert_allclose(d, 0.5 * np.asarray(mild.bandwidth), rtol=1e-6)
    with pytest.raises(ValueError, match="outside"):
        prof.with_density_allocation(0.01, mode="bandwidth", floor=0.05)


def test_availability_weights_and_sampler():
    from repro.core.clients import ClientAvailability
    n, s = 12, 4
    avail = ClientAvailability.diurnal(n, period=6.0, amp=1.0,
                                       churn_rate=0.25, online_frac=0.5,
                                       seed=7)
    sched = ClientSchedule(profile=ClientProfile.homogeneous(n),
                           availability=avail)
    assert sched.may_drop and sched.heterogeneous_steps
    w0 = np.asarray(avail.weights(0))
    assert w0.shape == (n,) and (w0 >= 0).all() and (w0 <= 1).all()
    # churn gates ~half the population fully offline
    assert (w0 == 0).any() and (w0 > 0).any()
    key = jax.random.PRNGKey(3)
    for t in range(6):
        clients, available = sched.sample_cohort(key, s, round_idx=t)
        w = np.asarray(avail.weights(t))
        c = np.asarray(clients)
        assert len(set(c.tolist())) == s        # without replacement
        online = w[c] > 0
        np.testing.assert_array_equal(np.asarray(available), online)
        # offline clients are only drawn when fewer than s are online
        if (w > 0).sum() >= s:
            assert online.all()
    # the neutral path is exactly the historical uniform draw
    plain = ClientSchedule.homogeneous(n)
    clients, available = plain.sample_cohort(key, s)
    assert available is None
    np.testing.assert_array_equal(
        np.asarray(clients),
        np.asarray(jax.random.choice(key, n, (s,), replace=False)))


def test_availability_size_mismatch_rejected():
    from repro.core.clients import ClientAvailability
    with pytest.raises(ValueError, match="availability"):
        ClientSchedule(profile=ClientProfile.homogeneous(4),
                       availability=ClientAvailability.diurnal(5))
    with pytest.raises(ValueError, match="amp"):
        ClientAvailability.diurnal(4, amp=1.5)
    with pytest.raises(ValueError, match="online_frac"):
        ClientAvailability.diurnal(4, online_frac=0.0)
