"""Roofline analysis (deliverable (g)) — reads the dry-run artifacts.

Per (arch x shape x mesh):

  compute term    = HLO_FLOPs_total / (chips x 197e12 FLOP/s)
  memory term     = HLO_bytes_total / (chips x 819e9 B/s)
  collective term = collective_bytes_total / (chips x 50e9 B/s per link)

The **wire section** rooflines the uplink encode/decode the same way: per
codec (from ``artifacts/wire_formats.json``), the streamed bytes (dense
tree one side, packed payload the other) set a floor of ``bytes / HBM_BW``
per encode on TPU, and the measured ``pack_bytes_per_s`` is reported as a
fraction of that platform's stream roof — the distance the fused
select+pack kernels still leave on the table.

HLO flops/bytes from ``compiled.cost_analysis()`` are per-partition; the
collective bytes are parsed from the partitioned HLO (also per-partition),
so each term is per-chip time directly.  MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) with D = tokens processed per step.
"""

from __future__ import annotations

import json
from pathlib import Path

ART_DIR = Path(__file__).resolve().parent / "artifacts" / "dryrun"
WIRE_ART = Path(__file__).resolve().parent / "artifacts" / "wire_formats.json"

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)

_COLL_KEYS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token x batch
    "long_500k": 1,
}
SHAPE_MULT = {"train_4k": 3.0}   # fwd+bwd ~ 3x fwd FLOPs


def analyze(rec: dict) -> dict:
    if rec.get("status") != "ok":
        return dict(rec)
    coll = sum(rec["collective_bytes"].get(k, 0) for k in _COLL_KEYS)
    flops = rec["flops"]                    # per partition
    bytes_ = rec["bytes_accessed"]
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    tokens = SHAPE_TOKENS.get(rec["shape"], 1)
    mult = SHAPE_MULT.get(rec["shape"], 1.0)
    model_flops = (mult * 2.0 * rec["model"]["active_params"] * tokens
                   / rec["n_devices"])
    useful = model_flops / flops if flops else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok",
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_per_dev": model_flops,
        "useful_flops_ratio": useful,
        "hbm_gib_per_dev": (rec["per_device_memory"]["argument_bytes"]
                            + rec["per_device_memory"]["output_bytes"]
                            + rec["per_device_memory"]["temp_bytes"]
                            - rec["per_device_memory"]["alias_bytes"])
        / 2**30,
        "collective_gb": coll / 1e9,
    }


def load_all(mesh_tag: str = "singlepod") -> list[dict]:
    rows = []
    for f in sorted(ART_DIR.glob(f"*__{mesh_tag}.json")):
        rows.append(analyze(json.loads(f.read_text())))
    return rows


def format_table(rows: list[dict]) -> str:
    out = [f"{'arch':28s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'bound':>8s} {'useful':>7s} {'HBM GiB':>8s}"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"{r['arch']:28s} {r['shape']:12s} "
                       f"-- {r.get('status')}: {r.get('reason', r.get('error', ''))[:40]}")
            continue
        out.append(
            f"{r['arch']:28s} {r['shape']:12s} "
            f"{r['t_compute_s']*1e3:8.2f}m {r['t_memory_s']*1e3:8.2f}m "
            f"{r['t_collective_s']*1e3:8.2f}m {r['bottleneck']:>8s} "
            f"{r['useful_flops_ratio']:7.2f} {r['hbm_gib_per_dev']:8.2f}")
    return "\n".join(out)


def wire_rows() -> list[dict]:
    """Roofline the wire codecs from the committed wire_formats artifact.

    Per codec: bytes streamed per encode (dense in + payload out), the
    HBM-roof floor that traffic implies on TPU, and the measured pack /
    unpack throughput as a fraction of the artifact platform's stream
    bandwidth.  Missing artifact (or a pre-throughput one) yields [].
    """
    if not WIRE_ART.exists():
        return []
    data = json.loads(WIRE_ART.read_text())
    rows = []
    for r in data.get("rows", []):
        if "pack_bytes_per_s" not in r:
            continue    # round_overhead row / artifact predating the cols
        codec = r["name"].split("/", 1)[1]
        streamed = r["dense_bytes"] + r["payload_bytes"]
        rows.append({
            "codec": codec,
            "platform": data.get("platform", "?"),
            "streamed_bytes": streamed,
            "t_hbm_floor_s": streamed / HBM_BW,
            "pack_bytes_per_s": r["pack_bytes_per_s"],
            "unpack_bytes_per_s": r["unpack_bytes_per_s"],
            "pack_pct_stream_bw": r["pack_pct_stream_bw"],
            "unpack_pct_stream_bw": r["unpack_pct_stream_bw"],
        })
    return rows


def format_wire_table(rows: list[dict]) -> str:
    if not rows:
        return "wire: no wire_formats.json artifact with throughput columns"
    out = [f"{'codec':18s} {'streamed':>10s} {'HBM floor':>10s} "
           f"{'pack GB/s':>10s} {'%roof':>6s} {'unpack GB/s':>12s} "
           f"{'%roof':>6s}"]
    for r in rows:
        out.append(
            f"{r['codec']:18s} {r['streamed_bytes']/1e6:8.2f}MB "
            f"{r['t_hbm_floor_s']*1e6:8.2f}us "
            f"{r['pack_bytes_per_s']/1e9:10.3f} "
            f"{r['pack_pct_stream_bw']:5.1f}% "
            f"{r['unpack_bytes_per_s']/1e9:12.3f} "
            f"{r['unpack_pct_stream_bw']:5.1f}%")
    return "\n".join(out)


def run(fast: bool = False):
    rows = load_all()
    print(format_table(rows))
    wrows = wire_rows()
    print("\n-- wire encode/decode vs stream roof --")
    print(format_wire_table(wrows))
    return [
        {"name": f"roofline/{r['arch']}__{r['shape']}",
         "us_per_round": round(max(r["t_compute_s"], r["t_memory_s"],
                                   r["t_collective_s"]) * 1e6, 1),
         "best_acc": "", "total_mbits": "",
         "bottleneck": r["bottleneck"],
         "useful": round(r["useful_flops_ratio"], 3)}
        for r in rows if r.get("status") == "ok"
    ] + [
        {"name": f"roofline/wire__{w['codec']}",
         "us_per_round": round(w["t_hbm_floor_s"] * 1e6, 1),
         "best_acc": "", "total_mbits": "",
         "bottleneck": "memory",
         "useful": round(w["pack_pct_stream_bw"] / 100, 3)}
        for w in wrows
    ]


if __name__ == "__main__":
    print(format_table(load_all()))
    print("\n-- wire encode/decode vs stream roof --")
    print(format_wire_table(wire_rows()))
