"""Paper Figure 10 — FedComLoc-Com vs -Local vs -Global across sparsity."""

from repro.compress import TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.cifar_setup()
    rows = []
    densities = (0.1, 0.9) if fast else (0.1, 0.5, 0.9)
    for density in densities:
        for variant in ("com", "local", "global"):
            cfg = FedComLocConfig(gamma=0.05, p=0.1, n_clients=10,
                                  clients_per_round=5, batch_size=32,
                                  variant=variant)
            alg = FedComLoc(loss_fn, data, cfg, TopK(density=density))
            rows.append(common.run_fl(
                f"fig10/{variant}_k{int(density*100)}", alg, model,
                eval_fn, rounds,
                extra={"variant": variant, "density": density}))
    return rows
