"""Aggregation-policy sweep (DESIGN.md §7, EXPERIMENTS.md §Async).

Policy (sync / semi_sync / async_buffered) x client heterogeneity
(uniform vs lognormal speeds) x compressor (dense vs TopK), all on
FedComLoc-Com with the §5 sim-time cost model.  The headline metric is
**sim_time to target accuracy**: the simulated wall-clock until the run
first reaches 95% of the sync policy's best accuracy (same speeds, same
compressor), plus the uplink bits spent getting there.

Under lognormal (heavy-tailed) speeds one straggler sets the sync round
clock, so ``semi_sync(K = s/2)`` — aggregate the K fastest, carry the
rest — cuts time-to-target by far more than its per-round accuracy cost,
and ``async_buffered`` converts the same waiting into extra
staleness-weighted server steps.  Uniform speeds show the control: little
to gain when there is no tail.  Writes the sweep + per-policy speedups to
``benchmarks/artifacts/async_rounds.json`` (the committed artifact backing
the §Async claims).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax

from repro.compress import TopK
from repro.core import server
from repro.core.aggregation import AggregationPolicy
from repro.core.clients import ClientProfile, ClientSchedule
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common

N_CLIENTS = 20
S = 8                      # clients sampled per round (policies divide it)
DENSITY = 0.2
BIT_COST = 1e-7            # sim-time per uplink bit at bandwidth 1
TARGET_FRACTION = 0.95     # of the sync policy's best accuracy

ART = Path(__file__).resolve().parent / "artifacts"

POLICIES = [
    ("sync", None),
    ("semi_sync", AggregationPolicy.semi_sync(S // 2)),
    # alpha=1.0: undiscounted stale flushes (alpha=0) overshoot the
    # Scaffnew h-correction and stall; 1/(1+staleness) is the sweet spot
    # in the EXPERIMENTS.md §Async alpha study
    ("async_buffered", AggregationPolicy.async_buffered(S // 2, alpha=1.0)),
]


def _schedule(speeds: str) -> ClientSchedule:
    if speeds == "uniform":
        profile = ClientProfile.uniform(N_CLIENTS, lo=0.7, hi=1.4, seed=0)
    elif speeds == "lognormal":
        profile = ClientProfile.lognormal(N_CLIENTS, speed_sigma=1.0, seed=0)
    else:
        raise ValueError(speeds)
    return ClientSchedule(profile=profile, bit_cost=BIT_COST)


def _time_to_target(hist: server.History, target: float):
    """(sim_time, uplink Mbits, rounds) at the first eval point reaching
    ``target`` accuracy; None if the run never does."""
    for i, acc in enumerate(hist.test_acc):
        if acc >= target:
            return (hist.sim_time[i], hist.uplink_bits[i] / 1e6,
                    hist.rounds[i])
    return None


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.mnist_setup(n_clients=N_CLIENTS)
    speed_models = ("lognormal",) if fast else ("uniform", "lognormal")
    compressors = (("topk", TopK(density=DENSITY)),) if fast else \
        (("dense", None), ("topk", TopK(density=DENSITY)))
    rows, sweeps = [], {}
    for speeds in speed_models:
        for comp_name, comp in compressors:
            group = []
            for pol_name, policy in POLICIES:
                cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=N_CLIENTS,
                                      clients_per_round=S, batch_size=32,
                                      variant="com" if comp else "none")
                alg = FedComLoc(loss_fn, data, cfg, comp,
                                schedule=_schedule(speeds), policy=policy)
                t0 = time.time()
                hist = server.run_federated(
                    alg, model.init(jax.random.PRNGKey(0)), rounds,
                    jax.random.PRNGKey(1), eval_fn,
                    eval_every=max(1, rounds // 12),
                    fuse=common.FUSE_ROUNDS)
                wall = time.time() - t0
                group.append({
                    "name": f"async_rounds/{speeds}_{comp_name}_{pol_name}",
                    "speeds": speeds, "compressor": comp_name,
                    "policy": pol_name, "rounds": rounds,
                    "best_acc": round(hist.best_acc, 4),
                    "total_sim_time": round(hist.sim_time[-1], 2),
                    "uplink_mbits": round(alg.meter.uplink_bits / 1e6, 2),
                    "us_per_round": round(wall / rounds * 1e6, 1),
                    "_hist": hist,
                })
            # target = 95% of this group's *sync* best accuracy, so every
            # policy chases the same bar on the same data/compressor
            target = TARGET_FRACTION * group[0]["best_acc"]
            sync_t2t = None
            for row in group:
                t2t = _time_to_target(row.pop("_hist"), target)
                row["target_acc"] = round(target, 4)
                if t2t is None:
                    row["sim_time_to_target"] = None
                    row["useful"] = 0.0
                    continue
                row["sim_time_to_target"] = round(t2t[0], 2)
                row["uplink_mbits_to_target"] = round(t2t[1], 2)
                row["rounds_to_target"] = t2t[2]
                if row["policy"] == "sync":
                    sync_t2t = t2t[0]
                row["speedup_vs_sync"] = (
                    round(sync_t2t / t2t[0], 3) if sync_t2t else None)
                row["useful"] = row["speedup_vs_sync"] or 0.0
            rows.extend(group)
            sweeps[f"{speeds}/{comp_name}"] = [
                {k: v for k, v in r.items()} for r in group]
    best_lognormal = max(
        (r.get("speedup_vs_sync") or 0.0 for r in rows
         if r["speeds"] == "lognormal" and r["policy"] != "sync"),
        default=0.0)
    ART.mkdir(parents=True, exist_ok=True)
    # same convention as results.json (EXPERIMENTS.md §Artifacts): only a
    # full run may overwrite the committed artifact; fast smoke runs write
    # the .partial scratch file so they never clobber the 6.49x headline
    name = "async_rounds.partial.json" if fast else "async_rounds.json"
    (ART / name).write_text(json.dumps({
        "clients_per_round": S,
        "target_fraction": TARGET_FRACTION,
        "best_speedup_lognormal": best_lognormal,
        "sweep": sweeps,
    }, indent=2))
    return rows
