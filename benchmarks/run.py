"""Benchmark runner — one module per paper table/figure (deliverable (d)).

  PYTHONPATH=src python -m benchmarks.run            # default scale
  PYTHONPATH=src python -m benchmarks.run --fast     # quick pass
  PYTHONPATH=src python -m benchmarks.run --only table1_topk fig5_quant

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall-time per FL
round; derived = best test accuracy or the benchmark's headline metric) and
writes the rows to benchmarks/artifacts/.  Only a *full* default run (no
--fast / --only / --no-fuse) overwrites the committed ``results.json``;
anything partial goes to ``results.partial.json`` so the committed full-run
artifact survives spot checks (EXPERIMENTS.md §Artifacts).
"""

from __future__ import annotations

import argparse
import importlib
import json
import time
import traceback
from pathlib import Path

MODULES = [
    "table1_topk",
    "table2_dirichlet",
    "fig3_cifar",
    "fig5_quant",
    "fig7_quant_het",
    "fig8_local_iters",
    "fig9_baselines",
    "fig10_variants",
    "fig16_double",
    "beyond_ef",
    "het_system",
    "client_scaling",
    "big_model",
    "async_rounds",
    "wire_formats",
    "downlink",
    "roofline",
    "population_scale",
]

ART = Path(__file__).resolve().parent / "artifacts"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--no-fuse", action="store_true",
                    help="drive FL rounds one jit call per round instead of "
                         "the fused run_rounds engine (A/B timing)")
    args = ap.parse_args()

    if args.no_fuse:
        from benchmarks import common
        common.FUSE_ROUNDS = False

    mods = args.only if args.only else MODULES
    all_rows = []
    failed = []
    print("name,us_per_call,derived")
    for name in mods:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            rows = mod.run(fast=args.fast)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name},ERROR,")
            failed.append(name)
            continue
        for r in rows:
            derived = r.get("best_acc", r.get("useful", ""))
            print(f"{r['name']},{r.get('us_per_round', '')},{derived}")
            all_rows.append(r)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)

    full_run = not (args.fast or args.only or args.no_fuse)
    out = ART / ("results.json" if full_run else "results.partial.json")
    ART.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(all_rows, indent=2))
    print(f"# wrote {out.relative_to(ART.parent.parent)}", flush=True)
    if failed:  # nonzero exit so the CI smoke step catches rotted modules
        raise SystemExit(f"benchmark module(s) failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
