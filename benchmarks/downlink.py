"""Downlink codec benchmark (DESIGN.md §10, EXPERIMENTS.md §Downlink).

Bits-to-accuracy with BOTH links on the wire, FedMNIST stand-in:

* ``fedcomloc`` — the paper's setting: TopK(0.1) uplink, dense broadcast.
  The downlink dominates its total traffic (s full models per round).
* ``fedcomloc_packed_down`` — the §10 seam on FedComLoc: the broadcast
  delta-coded against the cohort's last-received model with Q_r(8),
  moved as a real packed payload.  Honest finding: FedComLoc tolerates
  only *mild* broadcast compression — an aggressive TopK(0.1) downlink
  diverges around round 20 (the control variates integrate the
  non-vanishing broadcast error; delta-coding alone does not make the
  sparsifier contractive enough), which is precisely the failure mode
  LoCoDL's y-side control variate exists to remove.
* ``locodl`` — LoCoDL (arXiv 2403.04348): bidirectional compression is
  *native* (every transmitted quantity is a control-variate-driven
  difference), so it keeps FedComLoc's round rate at a fraction of the
  bits.  This is the headline the artifact gates on: LoCoDL must beat
  FedComLoc on total (up+down) bits to the target accuracy.
* ``locodl_double`` — LoCoDL with Compose(TopK, Q_r) on both links: the
  Figure-16-style double compression applied bidirectionally.

Also reconciles the packed broadcast in-graph at benchmark scale: for the
MLP's parameter tree, ``downlink_payload_bytes * 8 - downlink_bits`` must
equal the cohort-scaled closed-form word padding every recorded round
(the §8 checked invariant, downlink direction).

Writes ``benchmarks/artifacts/downlink.json`` (``downlink.partial.json``
under ``--fast``) with a ``checks`` block; like big_model, the artifact
lands BEFORE any gate failure raises, so CI failures ship evidence.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks import common
from repro.compress import Compose, QuantQr, TopK, wire
from repro.core import server
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.core.locodl import LoCoDL, LoCoDLConfig

ART = Path(__file__).resolve().parent / "artifacts"

TARGET_ACC = 0.9
N_CLIENTS, S = 20, 5


def _fedcomloc(loss_fn, data, **kw):
    cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=N_CLIENTS,
                          clients_per_round=S, batch_size=32,
                          variant="com")
    return FedComLoc(loss_fn, data, cfg, TopK(0.1), **kw)


def _locodl(loss_fn, data, comp, **kw):
    cfg = LoCoDLConfig(gamma=0.1, p=0.1, lam=0.9, n_clients=N_CLIENTS,
                       clients_per_round=S, batch_size=32)
    return LoCoDL(loss_fn, data, cfg, comp, **kw)


def _arms(loss_fn, data):
    return {
        "fedcomloc": _fedcomloc(loss_fn, data),
        # Q_r(8), NOT TopK: a sparsified broadcast diverges (see module
        # docstring) — the quantizer's bounded relative error is what the
        # h-updates can absorb
        "fedcomloc_packed_down": _fedcomloc(
            loss_fn, data, downlink="packed",
            downlink_compressor=QuantQr(r=8)),
        "locodl": _locodl(loss_fn, data, TopK(0.1), wire="packed",
                          downlink="packed",
                          downlink_compressor=TopK(0.1)),
        # r=8 — coarser quantization (r=4) of the bidirectional
        # differences destabilizes the control-variate feedback loop
        "locodl_double": _locodl(
            loss_fn, data, Compose(TopK(0.1), QuantQr(8)), wire="packed",
            downlink="packed",
            downlink_compressor=Compose(TopK(0.1), QuantQr(8))),
    }


def _bits_to_target(hist) -> tuple[float | None, int | None]:
    for acc, bits, rnd in zip(hist.test_acc, hist.total_bits, hist.rounds):
        if acc >= TARGET_ACC:
            return float(bits), int(rnd)
    return None, None


def _reconcile_rows(loss_fn, data, model, rounds: int) -> list[dict]:
    """Per-round packed-broadcast reconcile on the real model tree."""
    out = []
    p0 = model.init(jax.random.PRNGKey(0))
    for name, comp in (("topk_d0.1", TopK(0.1)),
                       ("qr_r4", QuantQr(r=4))):
        alg = _fedcomloc(loss_fn, data, downlink="packed",
                         downlink_compressor=comp)
        _, ms = alg.run_rounds(alg.init(p0), jax.random.PRNGKey(9), rounds)
        slack = (np.asarray(ms["downlink_payload_bytes"]) * 8
                 - np.asarray(ms["downlink_bits"]))
        spec = jax.eval_shape(
            lambda t, c=comp: wire.encode(c, t, jax.random.PRNGKey(0))[0],
            p0).spec
        b = 1 + spec.r
        if spec.codec == "qr":
            sizes = [int(np.prod(s)) if s else 1 for s in spec.shapes]
            pad1 = float(sum((32 * -(-n // 32) - n) * b for n in sizes))
            exact = True
        else:
            # TopK slack varies round to round (underfull slots when the
            # broadcast delta has exact zeros) — bound, don't pin
            pad1, exact = float(sum(c * (32 + 32)
                                    for c in spec.caps)), False
        row = {"name": f"downlink/reconcile_{name}",
               "slack_bits": [float(x) for x in slack],
               "expected_slack_bits": S * pad1,
               "useful": float(slack.max())}
        ok = (np.all(slack == S * pad1) if exact
              else np.all((slack >= 0) & (slack <= S * pad1)))
        row["reconciled"] = bool(ok)
        out.append(row)
    return out


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.mnist_setup()
    rows, curves = [], {}
    for name, alg in _arms(loss_fn, data).items():
        t0 = time.time()
        hist = server.run_federated(
            alg, model.init(jax.random.PRNGKey(0)), rounds,
            jax.random.PRNGKey(1), eval_fn,
            eval_every=max(1, rounds // 6), fuse=common.FUSE_ROUNDS)
        wall = time.time() - t0
        bits, rnd = _bits_to_target(hist)
        curves[name] = hist
        rows.append({
            "name": f"downlink/{name}",
            "rounds": rounds,
            "best_acc": round(hist.best_acc, 4),
            "total_mbits": round(alg.meter.total_bits / 1e6, 2),
            "uplink_mbits": round(alg.meter.uplink_bits / 1e6, 2),
            "downlink_mbits": round(alg.meter.downlink_bits / 1e6, 2),
            "mbits_to_target": (None if bits is None
                                else round(bits / 1e6, 2)),
            "rounds_to_target": rnd,
            "us_per_round": round(wall / rounds * 1e6, 1),
            "acc_curve": [round(a, 4) for a in hist.test_acc],
            "mbits_curve": [round(b / 1e6, 2) for b in hist.total_bits],
        })
    rec_rows = _reconcile_rows(loss_fn, data, model, min(rounds, 4))

    by = {r["name"].split("/", 1)[1]: r for r in rows}
    failures = []
    fcl, lcd = by["fedcomloc"], by["locodl"]
    if lcd["mbits_to_target"] is None:
        failures.append(f"locodl never reached {TARGET_ACC}: "
                        f"best {lcd['best_acc']}")
    elif fcl["mbits_to_target"] is not None and \
            not lcd["mbits_to_target"] < fcl["mbits_to_target"]:
        failures.append(
            f"locodl did not beat fedcomloc on bits-to-{TARGET_ACC}: "
            f"{lcd['mbits_to_target']} vs {fcl['mbits_to_target']} Mbit")
    for r in rec_rows:
        if not r["reconciled"]:
            failures.append(f"{r['name']}: broadcast bytes/bits did not "
                            f"reconcile: {r['slack_bits']}")
    checks = {
        "target_acc": TARGET_ACC,
        "fedcomloc_mbits_to_target": fcl["mbits_to_target"],
        "locodl_mbits_to_target": lcd["mbits_to_target"],
        "locodl_beats_fedcomloc": not failures,
        "savings_x": (None if None in (fcl["mbits_to_target"],
                                       lcd["mbits_to_target"])
                      else round(fcl["mbits_to_target"]
                                 / lcd["mbits_to_target"], 2)),
        "failures": failures,
    }

    ART.mkdir(parents=True, exist_ok=True)
    out = ART / ("downlink.partial.json" if fast else "downlink.json")
    out.write_text(json.dumps({
        "platform": jax.devices()[0].platform,
        "rounds": rounds,
        "checks": checks,
        "rows": rows + rec_rows,
    }, indent=2))
    if failures:                     # after the artifact, so evidence lands
        raise AssertionError("; ".join(failures))
    return rows + rec_rows
