"""Paper Figure 7 / 14 — quantization vs data heterogeneity."""

from repro.compress import QuantQr
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    alphas = (0.1, 0.9) if fast else (0.1, 0.3, 0.7, 0.9)
    rows = []
    for r_bits in (8, 16):
        for alpha in alphas:
            data, model, loss_fn, eval_fn = common.mnist_setup(alpha=alpha)
            cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                                  clients_per_round=5, batch_size=32,
                                  variant="com")
            alg = FedComLoc(loss_fn, data, cfg, QuantQr(r=r_bits))
            rows.append(common.run_fl(
                f"fig7/r{r_bits}_alpha{alpha}", alg, model, eval_fn, rounds,
                extra={"r": r_bits, "alpha": alpha}))
    return rows
