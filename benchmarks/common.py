"""Shared harness for the paper-reproduction benchmarks.

Each benchmark module exposes ``run(fast: bool) -> list[dict]`` where every
row carries at least {name, rounds, best_acc, total_mbits, us_per_round}.
Scale: the paper's 100-client / 500-2500-round experiments are reduced to
CPU-tractable sizes (same mechanics, same comparisons — absolute numbers
differ; see EXPERIMENTS.md).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core import fed_data, server
from repro.data import dirichlet, synthetic
from repro.models import small

FAST_ROUNDS = 12
FULL_ROUNDS = 60

# Prefer the fused run_rounds engine (one jit per eval chunk instead of one
# per round); benchmarks/run.py --no-fuse flips this for A/B timing.
FUSE_ROUNDS = True


@functools.lru_cache(maxsize=8)
def mnist_setup(n_clients: int = 20, alpha: float = 0.7, seed: int = 0):
    ds = synthetic.make_mnist_like(n_train=8000, n_test=1000, seed=seed)
    parts = dirichlet.dirichlet_partition(ds.y_train, n_clients, alpha,
                                          seed=seed)
    data = fed_data.from_numpy_partition(ds.x_train, ds.y_train, parts)
    model = small.MLP(784, 64, 10)
    loss_fn = small.cross_entropy_loss(model.apply)
    eval_fn = server.make_eval_fn(model.apply, jnp.asarray(ds.x_test),
                                  jnp.asarray(ds.y_test))
    return data, model, loss_fn, eval_fn


@functools.lru_cache(maxsize=4)
def cifar_setup(n_clients: int = 10, alpha: float = 0.7, seed: int = 1):
    ds = synthetic.make_cifar_like(n_train=6000, n_test=1000, seed=seed)
    parts = dirichlet.dirichlet_partition(ds.y_train, n_clients, alpha,
                                          seed=seed)
    data = fed_data.from_numpy_partition(ds.x_train, ds.y_train, parts)
    model = small.CNN(3, 10, 32)
    loss_fn = small.cross_entropy_loss(model.apply)
    eval_fn = server.make_eval_fn(model.apply, jnp.asarray(ds.x_test),
                                  jnp.asarray(ds.y_test))
    return data, model, loss_fn, eval_fn


def run_fl(name: str, alg, model, eval_fn, rounds: int, seed: int = 0,
           extra: dict | None = None) -> dict:
    t0 = time.time()
    hist = server.run_federated(
        alg, model.init(jax.random.PRNGKey(seed)), rounds,
        jax.random.PRNGKey(seed + 1), eval_fn,
        eval_every=max(1, rounds // 6), fuse=FUSE_ROUNDS)
    wall = time.time() - t0
    row = {
        "name": name,
        "rounds": rounds,
        "best_acc": round(hist.best_acc, 4),
        "final_loss": round(hist.train_loss[-1], 4),
        "total_mbits": round(alg.meter.total_bits / 1e6, 2),
        "uplink_mbits": round(alg.meter.uplink_bits / 1e6, 2),
        "us_per_round": round(wall / rounds * 1e6, 1),
        "acc_per_gbit": round(hist.best_acc
                              / max(alg.meter.total_bits / 8e9, 1e-9), 2),
    }
    # straggler-aware simulated time (DESIGN.md §5): server waits for the
    # slowest sampled client each round; under a homogeneous schedule this
    # degenerates to cumulative local steps
    row["sim_time"] = round(hist.sim_time[-1], 2)
    row.update(extra or {})
    return row
