"""Beyond-paper ablation: error feedback + server momentum on FedComLoc-Com.

The paper notes that biased TopK lacks convergence theory inside Scaffnew;
EF14-style error feedback is the standard remedy for biased compressors —
this benchmark measures whether it helps empirically at aggressive sparsity
(K = 5/10%), and whether Polyak server momentum speeds up the rounds axis.
"""

from repro.compress import TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.mnist_setup()
    rows = []
    densities = (0.05, 0.1) if fast else (0.05, 0.1, 0.3)
    for density in densities:
        for tag, kw in [("plain", {}),
                        ("ef", {"error_feedback": True}),
                        ("mom", {"server_momentum": 0.6}),
                        ("ef+mom", {"error_feedback": True,
                                    "server_momentum": 0.6})]:
            cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                                  clients_per_round=5, batch_size=32,
                                  variant="com", **kw)
            alg = FedComLoc(loss_fn, data, cfg, TopK(density=density))
            rows.append(common.run_fl(
                f"beyond_ef/k{int(density*100)}_{tag}", alg, model,
                eval_fn, rounds, extra={"density": density, "mode": tag}))
    return rows
