"""Paper Appendix B.3 Figure 16 — double compression (TopK then Q_r)."""

from repro.compress import Compose, QuantQr, TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.mnist_setup()
    rows = []
    combos = [
        ("k25_q4", Compose(TopK(0.25), QuantQr(4))),
        ("k50_q16", Compose(TopK(0.5), QuantQr(16))),
        ("k25_q32", TopK(density=0.25)),
        ("k100_q4", QuantQr(r=4)),
        ("k100_q32", TopK(density=1.0)),
    ]
    for name, comp in combos:
        cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                              clients_per_round=5, batch_size=32,
                              variant="com")
        alg = FedComLoc(loss_fn, data, cfg, comp)
        rows.append(common.run_fl(f"fig16/{name}", alg, model, eval_fn,
                                  rounds))
    return rows
