"""Wire-format benchmark (DESIGN.md §8, EXPERIMENTS.md §Wire).

Three questions about the packed payload layer:

1. **Size** — measured packed bytes vs the in-graph accounted bits vs the
   dense fp32 baseline, across compressors x r x density.  The two must
   reconcile within the documented word-padding slack (the module asserts
   it row by row — this is the §8 "checked invariant" at benchmark scale).
2. **Throughput** — pack (encode) and unpack (decode) wall-time on a
   model-sized tree, plus the streamed ``bytes_per_s`` each achieves and
   what fraction of the measured stream bandwidth that is (a dense
   identity-copy over the same tree on CPU; the 819 GB/s HBM figure on
   TPU).  Both directions are memory-bound streaming transforms: the
   fused select+pack kernels exist to close the gap to that roof, and the
   smoke assertion pins packed TopK encode at <= 25x the dense copy so a
   regression back to the sort-based path fails CI.
3. **Round overhead** — fused FedComLoc-Com rounds in ``wire="packed"``
   vs ``wire="account"`` mode: the end-to-end cost of moving real packed
   buffers instead of dense trees (target: < 10% on CPU).

Writes ``benchmarks/artifacts/wire_formats.json`` (headline: the QuantQr
r=4 and TopK d=0.05 payload-vs-dense ratios and the packed-round
overhead) in addition to returning runner rows.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import FUSE_ROUNDS, mnist_setup
from repro.compress import (
    Compose, Identity, Int8Sync, QuantQr, TopK, dense_bits, wire)
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

ART = Path(__file__).resolve().parent / "artifacts"

CODECS = [
    ("dense", Identity()),
    ("topk_d0.05", TopK(density=0.05)),
    ("topk_d0.1", TopK(density=0.1)),
    ("topk_d0.2", TopK(density=0.2)),
    ("qr_r2", QuantQr(r=2)),
    ("qr_r4", QuantQr(r=4)),
    ("qr_r8", QuantQr(r=8)),
    ("double_d0.05_r4", Compose(TopK(0.05), QuantQr(4))),
    ("int8", Int8Sync()),
]


def _time_fn(fn, *args, reps: int = 5) -> float:
    out = fn(*args)                      # compile + warm
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        best = min(best, time.time() - t0)
    return best


# HBM bandwidth per chip (v5e) — the TPU stream roof; on CPU the roof is
# measured instead (see _stream_bw)
HBM_BW = 819e9


def _stream_bw(params, reps: int) -> float:
    """Stream-bandwidth roof for throughput fractions, in bytes/s.

    On TPU: the documented HBM figure.  On CPU: measured — a jit'd
    identity copy of the model tree reads and writes every leaf once, so
    bytes/time is what *this box* sustains on a pure streaming pass, and
    codec fractions compare encode/decode against an achievable roof
    rather than a spec sheet.
    """
    if jax.devices()[0].platform == "tpu":
        return HBM_BW
    copy = jax.jit(lambda t: jax.tree_util.tree_map(lambda x: x * 1.0, t))
    t = _time_fn(copy, params, reps=reps)
    nbytes = dense_bits(params) / 8
    return 2.0 * nbytes / t


def _codec_rows(params, fast: bool) -> list[dict]:
    reps = 3 if fast else 5
    key = jax.random.PRNGKey(0)
    dense_bytes = dense_bits(params) / 8
    stream_bw = _stream_bw(params, reps)
    rows = []
    for name, comp in CODECS:
        enc = jax.jit(lambda t, k, c=comp: wire.encode(c, t, k))
        payload, report = enc(params, key)
        dec = jax.jit(wire.decode)
        enc_s = _time_fn(enc, params, key, reps=reps)
        dec_s = _time_fn(dec, payload, reps=reps)
        accounted_bits = float(report.total_bits)
        pad_bits = float(wire.padding_bits(payload, report))
        # §8 checked invariant: the slack equals the *documented* closed
        # form, recomputed independently — underfull sparse slots (the
        # MLP's zero-init biases never fill their capacity) at
        # (INDEX_BITS + value width) each, plus uint32 word padding for
        # packed-code units; dense/int8 are byte-exact
        spec = payload.spec
        b = 1 + spec.r
        nnz = float(report.index_bits) / 32
        empty_slots = sum(spec.caps) - nnz
        unit_sizes = [int(np.prod(s)) if s else 1 for s in spec.shapes]
        if spec.scope == "global":
            unit_sizes = [sum(unit_sizes)]
        if spec.codec in ("dense", "int8"):
            expected_pad = 0.0
        elif spec.codec == "topk":              # fp32 values: 64 bits/slot
            expected_pad = empty_slots * (32 + 32)
        elif spec.codec == "qr":
            expected_pad = sum((32 * -(-n_ // 32) - n_) * b
                               for n_ in unit_sizes)
        else:                                   # topk_qr: word pad + slots
            expected_pad = (sum((32 * -(-c // 32) - c) * b
                                for c in spec.caps)
                            + empty_slots * (32 + b))
        assert pad_bits == expected_pad, (name, pad_bits, expected_pad)
        assert payload.nbytes * 8 == accounted_bits + pad_bits, name
        # each direction streams the dense tree on one side and the packed
        # payload on the other — that's the traffic the wall-time buys
        streamed = dense_bytes + payload.nbytes
        rows.append({
            "name": f"wire_formats/{name}",
            "payload_bytes": payload.nbytes,
            "accounted_bits": accounted_bits,
            "pad_bits": pad_bits,
            "dense_bytes": dense_bytes,
            "ratio_vs_dense": round(payload.nbytes / dense_bytes, 4),
            "pack_us": round(enc_s * 1e6, 1),
            "unpack_us": round(dec_s * 1e6, 1),
            "pack_bytes_per_s": round(streamed / enc_s, 1),
            "unpack_bytes_per_s": round(streamed / dec_s, 1),
            "pack_pct_stream_bw": round(100 * streamed / enc_s / stream_bw,
                                        2),
            "unpack_pct_stream_bw": round(100 * streamed / dec_s / stream_bw,
                                          2),
            "us_per_round": round(enc_s * 1e6, 1),
            "useful": round(payload.nbytes / dense_bytes, 4),
        })
    return rows


def _smoke_encode_ratio(params) -> None:
    """CI smoke bound: fused TopK encode within 25x of the dense copy.

    The pre-fusion sort-based encode sat at ~200x dense on this tree, so
    25x is a regression tripwire with real margin — but both encodes are
    sub-millisecond, and on a loaded one-core CI box two *independently*
    timed minima can drift apart by 2x in opposite directions.  So the
    reps are interleaved (dense, topk, dense, ...) like
    :func:`_round_overhead`'s, exposing both encoders to the same
    contention window before taking each min.
    """
    key = jax.random.PRNGKey(0)
    encs = {name: jax.jit(lambda t, k, c=comp: wire.encode(c, t, k))
            for name, comp in CODECS if name in ("dense", "topk_d0.05")}
    best = {name: float("inf") for name in encs}
    for name, enc in encs.items():       # compile + warm
        jax.block_until_ready(enc(params, key))
    for _ in range(9):
        for name, enc in encs.items():
            t0 = time.time()
            jax.block_until_ready(enc(params, key))
            best[name] = min(best[name], time.time() - t0)
    assert best["topk_d0.05"] <= 25 * best["dense"], (
        "fused TopK encode regressed past 25x dense copy:",
        round(best["topk_d0.05"] * 1e6, 1), round(best["dense"] * 1e6, 1))


def _round_overhead(fast: bool) -> dict:
    """Fused FedComLoc-Com rounds, account vs packed wire mode.

    The two modes' timing reps are *interleaved* (account, packed,
    account, ...): shared CI boxes see load swings larger than the
    quantity under test, and alternating reps exposes both modes to the
    same contention window before taking each mode's min.
    """
    data, model, loss_fn, _ = mnist_setup(n_clients=20)
    p0 = model.init(jax.random.PRNGKey(0))
    rounds = 4 if fast else 10
    reps = 3 if fast else 5

    def make_run(mode):
        cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                              clients_per_round=8, batch_size=32,
                              variant="com")
        alg = FedComLoc(loss_fn, data, cfg, TopK(density=0.05), wire=mode)
        if FUSE_ROUNDS:
            return lambda: alg.run_rounds(alg.init(p0),
                                          jax.random.PRNGKey(1), rounds)

        def run():
            st, k = alg.init(p0), jax.random.PRNGKey(1)
            for _ in range(rounds):
                k, sub = jax.random.split(k)
                st, m = alg.round(st, sub)
            return st, m
        return run

    runs = {mode: make_run(mode) for mode in ("account", "packed")}
    timings = {mode: float("inf") for mode in runs}
    for mode, run in runs.items():       # compile + warm
        state, metrics = run()
        jax.block_until_ready(state.x)
        if mode == "packed":
            # TopK payloads are byte-granular and every slot is filled on
            # continuous data: measured bytes must equal accounted bits
            up = np.asarray(metrics["uplink_bits"], dtype=float)
            pb = np.asarray(metrics["uplink_payload_bytes"], dtype=float)
            assert (pb * 8 == up).all()
    for _ in range(reps):
        for mode, run in runs.items():
            t0 = time.time()
            st, _ = run()
            jax.block_until_ready(st.x)
            timings[mode] = min(timings[mode], (time.time() - t0) / rounds)
    overhead = timings["packed"] / timings["account"] - 1.0
    return {
        "name": "wire_formats/round_overhead",
        "account_us_per_round": round(timings["account"] * 1e6, 1),
        "packed_us_per_round": round(timings["packed"] * 1e6, 1),
        "overhead_pct": round(overhead * 100, 2),
        "us_per_round": round(timings["packed"] * 1e6, 1),
        "useful": round(overhead * 100, 2),
    }


def run(fast: bool = False) -> list[dict]:
    _, model, _, _ = mnist_setup(n_clients=20)
    params = model.init(jax.random.PRNGKey(0))
    rows = _codec_rows(params, fast)
    _smoke_encode_ratio(params)
    rows.append(_round_overhead(fast))
    by = {r["name"].split("/", 1)[1]: r for r in rows}
    ART.mkdir(parents=True, exist_ok=True)
    # fast/smoke runs must not clobber the committed full-run artifact
    # (EXPERIMENTS.md §Artifacts; *.partial.json is gitignored)
    out = ART / ("wire_formats.partial.json" if fast
                 else "wire_formats.json")
    out.write_text(json.dumps({
        "platform": jax.devices()[0].platform,
        "n_params": int(sum(x.size
                            for x in jax.tree_util.tree_leaves(params))),
        "qr_r4_ratio_vs_dense": by["qr_r4"]["ratio_vs_dense"],
        "topk_d0.05_ratio_vs_dense": by["topk_d0.05"]["ratio_vs_dense"],
        "topk_d0.05_pack_us": by["topk_d0.05"]["pack_us"],
        "qr_r4_pack_us": by["qr_r4"]["pack_us"],
        "round_overhead_pct": by["round_overhead"]["overhead_pct"],
        "rows": rows,
    }, indent=2))
    return rows
