"""Million-client population benchmark (DESIGN.md §11, EXPERIMENTS.md
§Population).

Demonstrates the out-of-core client store end-to-end: FedComLoc (with EF
memory) and LoCoDL — the two algorithms with the heaviest per-client state
(two model-sized rows each) — training over a 1,000,000-client population
(``--fast``: 100,000) on one CPU host, with:

* per-client state spooled through a memory-mapped :class:`HostStore`
  with the §12 pipeline (``prefetch=True``: write-behind scatters +
  plan-driven cohort prefetch on a background worker — device memory and
  host-resident pages scale with the 64-client cohort, not the
  population);
* a diurnal + churn availability trace driving weighted cohort sampling
  through the §12 ``sampler="tree"`` segment-tree path (O(s log n) draws
  host-side, no O(n) sampling ops or population-sized constants in the
  round graph — trace/compile cost is population-independent);
* two-tier edge→server hierarchical aggregation (8 edges of 8);
* data sampled procedurally (``SyntheticFederatedData`` — O(dim) memory,
  no per-client index tables).

Writes ``benchmarks/artifacts/population_scale.json``.  Each row carries
the store's telemetry counters (rows/bytes moved, prefetch hits/misses,
flush stalls, RAW hazards) and a round-phase wall-clock breakdown
(sample / gather / scatter are critical-path callback time, apply /
prefetch run on the worker, compute is the remainder), so the
sampling-and-host-I/O-off-the-critical-path claim is reproducible from
CI.  The regression-gated fields are population-size *invariant*
(per-round host-spool traffic and uplink bits are cohort-sized;
``us_per_round`` is gated with a wide 1.5× tripwire), so a ``--fast`` CI
smoke compares against the committed full-run artifact;
``peak_rss_mb`` is recorded but not gated (machine-dependent).  Set
``POPULATION_SCALE_RSS_MB`` to make the run itself fail when peak RSS
exceeds the ceiling — the CI smoke leg runs this module in its own process
(``ru_maxrss`` is a process-wide high-water mark) with that set.
"""

from __future__ import annotations

import json
import os
import resource
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import TopK
from repro.core.aggregation import AggregationPolicy, HierarchicalPolicy
from repro.core.client_store import HostStore
from repro.core.clients import (
    ClientAvailability, ClientProfile, ClientSchedule)
from repro.core.fed_data import SyntheticFederatedData
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.core.locodl import LoCoDL, LoCoDLConfig

DIM = 2048                 # model size: per-client state rows are (DIM,)
COHORT = 64                # clients sampled per round — the memory bound
N_FULL = 1_000_000
N_FAST = 100_000

ART = Path(__file__).resolve().parent / "artifacts"


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _schedule(n: int) -> ClientSchedule:
    avail = ClientAvailability.diurnal(
        n, period=24.0, amp=0.8, churn_rate=0.05, online_frac=0.7, seed=0)
    return ClientSchedule(profile=ClientProfile.homogeneous(n),
                          availability=avail, bit_cost=1e-9,
                          sampler="tree")


def _policy() -> HierarchicalPolicy:
    return HierarchicalPolicy(edge=AggregationPolicy.sync(),
                              server=AggregationPolicy.sync(),
                              n_edges=8, edge_latency=0.5)


def _loss(p, xb, yb):
    return 0.5 * jnp.mean((xb @ p["w"] - yb) ** 2)


def _build(name: str, n: int, store: HostStore):
    # batch 256 keeps the per-step sample covariance well-conditioned at
    # dim 2048 (top eigenvalue ~(1+sqrt(dim/batch))^2), so gamma=0.1 local
    # steps are stable — at batch 32 they diverge
    data = SyntheticFederatedData.create(n, DIM, hetero=0.2, noise=0.01,
                                         seed=0)
    if name == "fedcomloc_pop":
        cfg = FedComLocConfig(gamma=0.1, p=0.2, n_clients=n,
                              clients_per_round=COHORT, batch_size=256,
                              variant="com", error_feedback=True)
        return FedComLoc(_loss, data, cfg, TopK(density=0.1),
                         schedule=_schedule(n), policy=_policy(),
                         store=store)
    cfg = LoCoDLConfig(gamma=0.1, p=0.2, lam=0.5, n_clients=n,
                       clients_per_round=COHORT, batch_size=256)
    return LoCoDL(_loss, data, cfg, TopK(density=0.1),
                  schedule=_schedule(n), policy=_policy(), store=store)


def _eval_loss(data: SyntheticFederatedData, params, n: int) -> float:
    """Population loss of the server/reference model on held-out draws
    from 8 spread-out clients — unlike ``train_loss`` (measured on cohort
    *local* iterates, which at cohort ≪ population always resume from the
    broadcast fill row), this sees cross-round progress."""
    tot = 0.0
    for c in range(8):
        xb, yb = data.sample_batch(jax.random.PRNGKey(10_000 + c),
                                   c * (n // 8), 512)
        tot += float(_loss(params, xb, yb))
    return tot / 8


def _run_one(name: str, n: int, rounds: int, spool: Path) -> dict:
    store = HostStore(mmap_dir=spool / name, prefetch=True)
    alg = _build(name, n, store)
    p0 = {"w": jnp.zeros((DIM,), jnp.float32)}
    state = alg.init(p0)
    eval_init = _eval_loss(alg.data, p0, n)
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    state, m = alg.run_rounds(state, key, rounds)
    jax.block_until_ready(state.x)
    store.flush()
    wall = time.time() - t0
    eval_final = _eval_loss(alg.data, state.x, n)
    host_mb = (store.bytes_gathered + store.bytes_scattered) / 1e6
    tel = store.telemetry()
    sample_s = alg.sched.tree_sampler.sample_seconds
    # critical-path phase split: sample + gather + scatter are measured
    # inside the ordered callbacks / sampler; compute is the remainder of
    # the fused-scan wall (includes trace+compile — population-independent
    # now that no O(n) sampling ops live in the graph)
    critical = sample_s + tel["gather_seconds"] + tel["scatter_seconds"]
    phases = {
        "sample_s": round(sample_s, 4),
        "gather_s": round(tel["gather_seconds"], 4),
        "scatter_s": round(tel["scatter_seconds"], 4),
        "compute_s": round(max(wall - critical, 0.0), 4),
        "apply_worker_s": round(tel["apply_seconds"], 4),
        "prefetch_worker_s": round(tel["prefetch_seconds"], 4),
    }
    row = {
        "name": name,
        "n_clients": n,
        "rounds": rounds,
        "first_loss": round(float(np.asarray(m["train_loss"])[0]), 4),
        "final_loss": round(float(np.asarray(m["train_loss"])[-1]), 4),
        "eval_loss_init": round(eval_init, 4),
        "eval_loss_final": round(eval_final, 4),
        "uplink_mbits": round(float(np.sum(m["uplink_bits"])) / 1e6, 3),
        "us_per_round": round(wall / rounds * 1e6, 1),
        # population-size-invariant spool traffic: cohort rows in + out
        "host_spool_mb_per_round": round(host_mb / rounds, 4),
        "clients_aggregated": round(
            float(np.mean(m["clients_aggregated"])), 2),
        "edges_aggregated": round(
            float(np.mean(m["edges_aggregated"])), 2),
        "sim_time": round(float(np.sum(m["sim_time"])), 2),
        "peak_rss_mb": round(_rss_mb(), 1),
        "phases": phases,
        "store": {k: tel[k] for k in (
            "rows_gathered", "rows_scattered", "bytes_gathered",
            "bytes_scattered", "prefetch_hits", "prefetch_misses",
            "flush_stalls", "raw_hazards")},
    }
    assert np.isfinite(row["final_loss"]), f"{name} diverged"
    # "trains end-to-end": the server/reference model must actually improve
    assert eval_final < eval_init, (
        f"{name} reference model did not improve "
        f"({eval_init:.1f} -> {eval_final:.1f})")
    return row


def run(fast: bool = False):
    n = N_FAST if fast else N_FULL
    rounds = 6 if fast else 12
    rows = []
    with tempfile.TemporaryDirectory(prefix="popscale_") as spool:
        for name in ("fedcomloc_pop", "locodl_pop"):
            rows.append(_run_one(name, n, rounds, Path(spool)))

    doc = {
        # scale markers are cohort/model-based, NOT population-based: a
        # --fast (100k) smoke stays comparable to the committed 1M run
        "arch": "linear-synthetic",
        "scale": f"cohort{COHORT}-edges8",
        "n_params": DIM,
        "n_clients": n,
        "rounds": rounds,
        "peak_rss_mb": round(_rss_mb(), 1),
        # pre-§12 committed numbers (PR 9 artifact: plain HostStore +
        # in-graph Gumbel-top-k at n=1M) — the before of the before/after
        "baseline_us_per_round": {"fedcomloc_pop": 7205352.2,
                                  "locodl_pop": 12561706.0},
        "rows": rows,
    }
    ART.mkdir(parents=True, exist_ok=True)
    out = ART / ("population_scale.partial.json" if fast
                 else "population_scale.json")
    out.write_text(json.dumps(doc, indent=2))

    ceiling = os.environ.get("POPULATION_SCALE_RSS_MB")
    if ceiling is not None and _rss_mb() > float(ceiling):
        raise SystemExit(
            f"population_scale peak RSS {_rss_mb():.0f} MB exceeds the "
            f"{float(ceiling):.0f} MB ceiling — per-client state is no "
            "longer out-of-core")
    return rows


if __name__ == "__main__":
    for r in run(fast="--fast" in __import__("sys").argv):
        print(r)
