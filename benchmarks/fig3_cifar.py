"""Paper Figure 3 — CNN on FedCIFAR10 (synthetic stand-in): sparsity ratios
with tuned vs fixed stepsize."""

from repro.compress import Identity, TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = (common.FAST_ROUNDS if fast else common.FULL_ROUNDS)
    data, model, loss_fn, eval_fn = common.cifar_setup()
    rows = []
    # tuned-ish stepsize per density (paper: optimized per K); here a small
    # grid mimicking the tuned column.
    gammas = {0.1: 0.1, 0.5: 0.05, 1.0: 0.05}
    for density in (0.1, 0.5, 1.0):
        comp = Identity() if density >= 1.0 else TopK(density=density)
        cfg = FedComLocConfig(gamma=gammas[density], p=0.1, n_clients=10,
                              clients_per_round=5, batch_size=32,
                              variant="com" if density < 1.0 else "none")
        alg = FedComLoc(loss_fn, data, cfg, comp)
        rows.append(common.run_fl(
            f"fig3/tuned_k{int(density*100)}", alg, model, eval_fn, rounds,
            extra={"density": density, "stepsize": "tuned"}))
    # fixed stepsize column (paper: 0.01 — max feasible for all configs)
    for density in (0.1, 1.0):
        comp = Identity() if density >= 1.0 else TopK(density=density)
        cfg = FedComLocConfig(gamma=0.01, p=0.1, n_clients=10,
                              clients_per_round=5, batch_size=32,
                              variant="com" if density < 1.0 else "none")
        alg = FedComLoc(loss_fn, data, cfg, comp)
        rows.append(common.run_fl(
            f"fig3/fixed_k{int(density*100)}", alg, model, eval_fn, rounds,
            extra={"density": density, "stepsize": "fixed"}))
    return rows
