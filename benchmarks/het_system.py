"""System-heterogeneity sweep (DESIGN.md §5, EXPERIMENTS.md §HetSystem).

The paper's data-heterogeneity scenarios (table2/fig7 Dirichlet alpha) run
every client with the same speed, bandwidth and density.  This sweep varies
the *system* axis: client speed distributions (uniform narrow vs lognormal
heavy-tailed) x per-client density allocation (uniform vs
bandwidth-proportional) x straggler policy (wait for all vs deadline+drop),
all on the FedComLoc-Com variant with exact per-client bit accounting.

Headline metrics per row: best accuracy, total Mbits, and ``sim_time`` —
the straggler-aware simulated wall-clock where each round costs
``max_i(steps_i/speed_i + bits_i·bit_cost/bandwidth_i)``.  Lognormal
speeds without a deadline show the classic straggler blow-up; a deadline
with dropping trades a little accuracy for a much shorter sim_time, and
bandwidth-proportional densities spend the same bit budget where the links
are fast.
"""

from repro.compress import TopK
from repro.core.clients import ClientProfile, ClientSchedule
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common

N_CLIENTS = 20
BASE_DENSITY = 0.2
BIT_COST = 1e-7   # sim-time per uplink bit at bandwidth 1 (light comm term)


def _profile(speeds: str, seed: int = 0) -> ClientProfile:
    if speeds == "uniform":
        return ClientProfile.uniform(N_CLIENTS, lo=0.7, hi=1.4,
                                     bandwidth_lo=0.5, bandwidth_hi=2.0,
                                     seed=seed)
    if speeds == "lognormal":
        return ClientProfile.lognormal(N_CLIENTS, speed_sigma=1.0,
                                       bandwidth_sigma=0.7, seed=seed)
    raise ValueError(speeds)


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.mnist_setup(n_clients=N_CLIENTS)
    speed_models = ("lognormal",) if fast else ("uniform", "lognormal")
    allocations = ("uniform", "bandwidth")
    rows = []
    for speeds in speed_models:
        for alloc in allocations:
            profile = _profile(speeds).with_density_allocation(
                BASE_DENSITY, mode=alloc)
            scenarios = [("wait", ClientSchedule(
                profile=profile, bit_cost=BIT_COST))]
            if not fast or alloc == "bandwidth":
                # deadline ~ the nominal phase length at median speed;
                # stragglers that finish zero steps are dropped
                scenarios.append(("drop", ClientSchedule(
                    profile=profile, deadline=10.0, drop_stragglers=True,
                    bit_cost=BIT_COST)))
            for policy, sched in scenarios:
                cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=N_CLIENTS,
                                      clients_per_round=5, batch_size=32,
                                      variant="com")
                alg = FedComLoc(loss_fn, data, cfg, TopK(density=BASE_DENSITY),
                                schedule=sched)
                rows.append(common.run_fl(
                    f"het_system/{speeds}_{alloc}_{policy}",
                    alg, model, eval_fn, rounds,
                    extra={"speeds": speeds, "alloc": alloc,
                           "policy": policy}))
    return rows
