"""Paper Figure 9 — FedComLoc vs FedAvg / sparseFedAvg / Scaffold / FedDyn."""

from repro.core.baselines import FedAvg, FedConfig, FedDyn, Scaffold, \
    SparseFedAvg
from repro.compress import Identity, TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.cifar_setup()
    rows = []

    fed_cfg = FedConfig(gamma=0.1, local_steps=10, n_clients=10,
                        clients_per_round=5, batch_size=32)
    fcl_cfg = FedComLocConfig(gamma=0.05, p=0.1, n_clients=10,
                              clients_per_round=5, batch_size=32,
                              variant="com")

    algs = {
        "fig9/fedavg": FedAvg(loss_fn, data, fed_cfg),
        "fig9/sparse_fedavg_k10": SparseFedAvg(loss_fn, data, fed_cfg,
                                               density=0.1),
        "fig9/scaffold": Scaffold(loss_fn, data, fed_cfg),
        "fig9/feddyn": FedDyn(loss_fn, data, fed_cfg),
        "fig9/fedcomloc_com_k10": FedComLoc(
            loss_fn, data, fcl_cfg, TopK(density=0.1)),
        "fig9/scaffnew": FedComLoc(
            loss_fn, data,
            FedComLocConfig(gamma=0.05, p=0.1, n_clients=10,
                            clients_per_round=5, batch_size=32,
                            variant="none"), Identity()),
    }
    for name, alg in algs.items():
        rows.append(common.run_fl(name, alg, model, eval_fn, rounds))
    return rows
