"""Artifact regression gate: fresh benchmark payload sizes vs committed.

Compares the size/bits fields of freshly produced benchmark artifacts
against the committed baselines (``git show <ref>:...``) and fails on a
>10% regression — a codec or wire-layout change that silently grows the
payloads the whole repo exists to shrink.  Wall-time and accuracy fields
are deliberately NOT gated (they are machine- and scale-dependent); only
bytes and bits are, and only where they are scale-invariant:

* ``wire_formats``   — per-codec ``payload_bytes`` / ``accounted_bits`` /
  ``pad_bits`` on the fixed model tree (identical under ``--fast``);
* ``big_model``      — per-device payload bytes per client, compared only
  when the arch/scale markers match (a ``--fast`` run uses a smaller
  model, which is a skip, not a pass);
* ``downlink``       — per-ROUND uplink/downlink/total Mbits (fast and
  full runs differ in rounds, so totals are normalized before comparing);
* ``population_scale`` — per-round host-spool MB and uplink Mbits, both
  cohort-sized and hence population-invariant (a 100k ``--fast`` smoke
  gates against the committed million-client artifact), plus a perf
  tripwire on ``us_per_round``: the one deliberate wall-time gate, with
  a wide 1.5× tolerance (``TOLERANCE_OVERRIDES``) so CI-host jitter
  passes but losing the §12 pipeline/sampler win (a >2× regression)
  fails.

Fresh side: ``<name>.partial.json`` when present (what a CI ``--fast``
smoke just wrote), else ``<name>.json``.  Baseline side: the committed
``<name>.json`` at ``--baseline-ref`` (default HEAD).  A baseline that
does not exist yet (first PR adding an artifact) is a skip.  Exit 1 on
any regression, with a row-by-row report either way.

Usable locally exactly as CI runs it:

    PYTHONPATH=src python -m benchmarks.check_artifacts
    PYTHONPATH=src python -m benchmarks.check_artifacts --tolerance 0.05
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts"
REPO = ART.parent.parent

# artifact -> (row-set accessor, gated fields, per-round-normalized fields)
SPECS = {
    "wire_formats": (("rows",), ("payload_bytes", "accounted_bits",
                                 "pad_bits"), ()),
    "big_model": (("sweep",), ("per_device_payload_bytes_per_client",),
                  ()),
    "downlink": (("rows",), (), ("total_mbits", "uplink_mbits",
                                 "downlink_mbits")),
    # cohort-sized fields are population-invariant: a --fast 100k smoke
    # gates against the committed 1M artifact (markers are cohort/model)
    "population_scale": (("rows",),
                         ("host_spool_mb_per_round", "us_per_round"),
                         ("uplink_mbits",)),
}
# per-(artifact, field) tolerance overrides: wall-time tripwires need a
# wider band than payload bytes (CI hosts jitter; a real pipeline loss
# blows well past 1.5×)
TOLERANCE_OVERRIDES = {
    ("population_scale", "us_per_round"): 0.50,
}
# top-level markers that must match for an artifact's rows to be
# comparable at all (scale/arch guards)
SCALE_MARKERS = ("arch", "scale", "n_params", "seq_len")


def _load_fresh(name: str):
    for p in (ART / f"{name}.partial.json", ART / f"{name}.json"):
        if p.exists():
            return json.loads(p.read_text()), p
    return None, None


def _load_baseline(name: str, ref: str):
    rel = f"benchmarks/artifacts/{name}.json"
    try:
        out = subprocess.run(["git", "show", f"{ref}:{rel}"], cwd=REPO,
                             capture_output=True, text=True, check=True)
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    return json.loads(out.stdout)


def _rows(doc, keys):
    if doc is None:
        return {}
    node = doc
    for k in keys:
        if not isinstance(node, dict) or k not in node:
            return {}
        node = node[k]
    return {r["name"]: r for r in node
            if isinstance(r, dict) and "name" in r}


def _markers(doc):
    return {k: doc.get(k) for k in SCALE_MARKERS if isinstance(doc, dict)}


def check(name: str, tolerance: float, ref: str) -> list[str]:
    accessor, fields, per_round_fields = SPECS[name]
    fresh_doc, fresh_path = _load_fresh(name)
    base_doc = _load_baseline(name, ref)
    if fresh_doc is None:
        print(f"  {name}: no fresh artifact — skip")
        return []
    if base_doc is None:
        print(f"  {name}: no committed baseline at {ref} — skip")
        return []
    if _markers(fresh_doc) != _markers(base_doc):
        print(f"  {name}: scale markers differ "
              f"({_markers(fresh_doc)} vs {_markers(base_doc)}) — skip")
        return []
    fresh, base = _rows(fresh_doc, accessor), _rows(base_doc, accessor)
    failures, compared = [], 0
    for rname, brow in base.items():
        frow = fresh.get(rname)
        if frow is None:
            failures.append(f"{name}: baseline row '{rname}' missing "
                            f"from {fresh_path.name}")
            continue
        for field in fields:
            if field not in brow or field not in frow:
                continue
            tol = TOLERANCE_OVERRIDES.get((name, field), tolerance)
            b, f = float(brow[field]), float(frow[field])
            compared += 1
            if f > b * (1 + tol) + 1e-9:
                failures.append(
                    f"{name}/{rname}.{field}: {b:g} -> {f:g} "
                    f"(+{(f / max(b, 1e-12) - 1) * 100:.1f}%)")
        for field in per_round_fields:
            if field not in brow or field not in frow:
                continue
            br, fr = brow.get("rounds"), frow.get("rounds")
            if not br or not fr:
                continue
            b, f = float(brow[field]) / br, float(frow[field]) / fr
            compared += 1
            if f > b * (1 + tolerance) + 1e-9:
                failures.append(
                    f"{name}/{rname}.{field}/round: {b:g} -> {f:g} "
                    f"(+{(f / max(b, 1e-12) - 1) * 100:.1f}%)")
    status = "FAIL" if failures else "ok"
    print(f"  {name}: {compared} field(s) compared "
          f"({fresh_path.name} vs {ref}) — {status}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(
        description="fail on >tolerance payload-size regressions vs the "
                    "committed benchmark artifacts")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative growth (default 0.10)")
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref holding the committed artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {sorted(SPECS)}")
    args = ap.parse_args()

    names = args.only if args.only else list(SPECS)
    print(f"artifact regression check (tolerance {args.tolerance:.0%}, "
          f"baseline {args.baseline_ref}):")
    failures = []
    for name in names:
        failures += check(name, args.tolerance, args.baseline_ref)
    if failures:
        print("\npayload-size regressions:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("no payload-size regressions.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
