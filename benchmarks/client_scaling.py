"""Client-axis scaling sweep (DESIGN.md §6, EXPERIMENTS.md §ClientScaling).

Measures fused round throughput of the `shard_map` client-sharded engine
against the single-device round at a paper-scale sample (64 clients per
round), sweeping every shard count the host's devices allow.  Force more
host devices than cores exist with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI benchmark
leg does) — physical speedup then caps at the core count, which is exactly
what the sweep should show.

Writes the sweep (per-shard-count throughput, speedup vs 1 device, exact
per-round bits) to ``benchmarks/artifacts/client_scaling.json`` — the seed
of the BENCH trajectory for this axis — in addition to returning runner
rows.  The sharded rounds are metric-bit-identical to the unsharded ones
(tests/test_distributed.py), so the bits column doubles as a cross-device
consistency check: every shard count must report the same wire cost.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.compress import TopK
from repro.core import fed_data
from repro.core.distributed import usable_shard_counts
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.data import dirichlet, synthetic
from repro.launch.mesh import make_client_mesh
from repro.models import small

N_CLIENTS = 64            # sampled in full: the parallel axis under test
DENSITY = 0.1

ART = Path(__file__).resolve().parent / "artifacts"


def _setup():
    ds = synthetic.make_mnist_like(n_train=8000, n_test=100, seed=0)
    parts = dirichlet.dirichlet_partition(ds.y_train, N_CLIENTS, 0.7, seed=0)
    data = fed_data.from_numpy_partition(ds.x_train, ds.y_train, parts)
    model = small.MLP(784, 64, 10)
    return data, model, small.cross_entropy_loss(model.apply)


def _time_rounds(alg, p0, rounds: int, reps: int = 3) -> tuple[float, dict]:
    """Best-of-``reps`` seconds per fused round (compile excluded) + the
    first timed rep's metrics.  Min-of-reps because the quantity under test
    is the compute cost, not the host's scheduling noise (2-core CI boxes
    jitter a lot)."""
    state = alg.init(p0)
    state, _ = alg.run_rounds(state, jax.random.PRNGKey(1), rounds)
    jax.block_until_ready(state.x)            # warm: compile + first chunk
    best, metrics = float("inf"), None
    for rep in range(reps):
        t0 = time.time()
        state, m = alg.run_rounds(state, jax.random.PRNGKey(2 + rep), rounds)
        jax.block_until_ready(state.x)
        best = min(best, (time.time() - t0) / rounds)
        metrics = m if metrics is None else metrics
    return best, metrics


def run(fast: bool = False):
    rounds = 3 if fast else 6
    data, model, loss_fn = _setup()
    p0 = model.init(jax.random.PRNGKey(0))
    sweep = []
    bit_trajectories = []
    base_s_per_round = None
    for n_shards in usable_shard_counts(N_CLIENTS):
        cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=N_CLIENTS,
                              clients_per_round=N_CLIENTS, batch_size=32,
                              variant="com")
        alg = FedComLoc(loss_fn, data, cfg, TopK(density=DENSITY))
        alg.use_mesh(make_client_mesh(n_shards))
        s_per_round, metrics = _time_rounds(alg, p0, rounds)
        bit_trajectories.append(np.asarray(metrics["uplink_bits"]))
        if base_s_per_round is None:
            base_s_per_round = s_per_round
        sweep.append({
            "name": f"client_scaling/shards{n_shards}",
            "n_shards": n_shards,
            "n_clients": N_CLIENTS,
            "rounds": rounds,
            "us_per_round": round(s_per_round * 1e6, 1),
            "rounds_per_s": round(1.0 / s_per_round, 3),
            "speedup_vs_1shard": round(base_s_per_round / s_per_round, 3),
            "uplink_mbits_per_round": round(
                float(np.asarray(metrics["uplink_bits"]).mean()) / 1e6, 3),
            "sim_time_per_round": round(
                float(np.asarray(metrics["sim_time"]).mean()), 2),
            "useful": round(base_s_per_round / s_per_round, 3),
        })
    # every shard count must report the same exact per-round wire cost
    # (§6 contract) — compared raw and bit-for-bit, not via rounded means
    ref = bit_trajectories[0]
    for n_shards, traj in zip([r["n_shards"] for r in sweep],
                              bit_trajectories):
        if not np.array_equal(ref, traj):
            raise AssertionError(
                f"client sharding changed the bits accounting at "
                f"{n_shards} shards: {ref} != {traj}")
    best = max(sweep, key=lambda r: r["speedup_vs_1shard"])
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "client_scaling.json").write_text(json.dumps({
        "devices": len(jax.devices()),
        "platform": jax.devices()[0].platform,
        "best_speedup": best["speedup_vs_1shard"],
        "best_n_shards": best["n_shards"],
        "sweep": sweep,
    }, indent=2))
    return sweep
