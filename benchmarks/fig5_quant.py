"""Paper Figure 5 / B.2 — Q_r quantization, r in {4, 8, 16, 32}."""

from repro.compress import Identity, QuantQr
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.mnist_setup()
    rows = []
    for r_bits in (4, 8, 16, 32):
        comp = QuantQr(r=r_bits)
        cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                              clients_per_round=5, batch_size=32,
                              variant="com")
        alg = FedComLoc(loss_fn, data, cfg, comp)
        rows.append(common.run_fl(f"fig5/quant_r{r_bits}", alg, model,
                                  eval_fn, rounds, extra={"r": r_bits}))
    # uncompressed reference
    cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                          clients_per_round=5, batch_size=32,
                          variant="none")
    alg = FedComLoc(loss_fn, data, cfg, Identity())
    rows.append(common.run_fl("fig5/dense", alg, model, eval_fn, rounds,
                              extra={"r": 32}))
    return rows
