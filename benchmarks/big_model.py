"""Federated big-model sweep: composed clients x model meshes (DESIGN.md §9,
EXPERIMENTS.md §BigModel).

Trains a real transformer architecture federated end-to-end — FedAvg +
TopK, ``wire="packed"`` — on composed ``(clients, data, model)`` meshes,
sweeping the model-shard factor ``m``.  The sweep demonstrates the §9
sharded wire path: per-round metrics stay allclose at every ``m`` (the
GSPMD round graph is the unsharded one), bits/bytes accounting comes from
psum'd integer nnz (identical up to threshold-tie flips when the float
trajectories diverge in the last ulp), and the per-device share of the
packed uplink shrinks ~1/m while total wire bytes are conserved.

Scales:

* ``--fast`` (the CI smoke): ``reduced(qwen2-0.5b)`` — the real qwen2
  topology (GQA, tied embeddings, qkv bias) at CI-sized dims;
* default: same topology, more rounds and longer sequences;
* ``BIG_MODEL_FULL=1``: the full qwen2-0.5b config (0.5B params — needs a
  real accelerator mesh; gated so host runs stay feasible).

Run on 8 host devices (the CI leg does)::

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.run --fast --only big_model

Writes ``benchmarks/artifacts/big_model.json``.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import TopK, wire
from repro.configs import get_spec, reduced
from repro.core import fed_data
from repro.core.baselines import FedAvg, FedConfig
from repro.core.clients import RoundPlan
from repro.core.distributed import ModelShardCtx
from repro.launch.mesh import make_client_mesh
from repro.models import transformer as tfm
from repro.sharding import specs as sspecs

ART = Path(__file__).resolve().parent / "artifacts"

ARCH = "qwen2-0.5b"
DENSITY = 0.05
CLIENTS_PER_ROUND = 4
N_CLIENTS = 8


def _arch_spec(fast: bool):
    spec = get_spec(ARCH)
    if os.environ.get("BIG_MODEL_FULL"):
        return spec, "full"
    return reduced(spec), "reduced"


def _model_shard_sweep(n_devices: int, spec) -> list[int]:
    """Model-shard factors realisable on this host: divisors of the device
    count whose complement leaves a clients axis dividing the round."""
    out = []
    for m in (1, 2, 4, 8):
        if m > n_devices or n_devices % m:
            continue
        clients = min(n_devices // m, CLIENTS_PER_ROUND)
        if CLIENTS_PER_ROUND % clients:
            continue
        if m > 1:
            cfg = spec.model
            dims = (cfg.n_heads * cfg.head_dim,
                    cfg.n_kv_heads * cfg.head_dim, cfg.d_ff, cfg.vocab)
            if any(d % m for d in dims):
                continue
        out.append(m)
    return out


def _make_data(cfg_m, seq_len: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    per = 8
    x = rng.integers(0, cfg_m.vocab,
                     (N_CLIENTS * per, seq_len)).astype(np.int32)
    y = np.zeros((N_CLIENTS * per,), np.float32)
    parts = [np.arange(i * per, (i + 1) * per) for i in range(N_CLIENTS)]
    return fed_data.from_numpy_partition(x, y, parts)


def _time_encode(mesh, spec_arch, comp, stacked, reps: int = 3) -> float:
    """Best-of-reps seconds for one jitted sharded encode of ``stacked``
    (the fixed per-client innovation tree) — the per-device pack cost."""
    ctx = ModelShardCtx(mesh)
    s = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    plan = RoundPlan(steps=jnp.ones((s,), jnp.int32),
                     participating=jnp.ones((s,), bool),
                     speed=jnp.ones((s,)), bandwidth=jnp.ones((s,)),
                     comp_overrides={})
    fn = jax.jit(lambda t: ctx.encode_payload(comp, plan, t))
    payload, _ = fn(stacked)
    jax.block_until_ready(jax.tree_util.tree_leaves(payload.data))
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        payload, _ = fn(stacked)
        jax.block_until_ready(jax.tree_util.tree_leaves(payload.data))
        best = min(best, time.time() - t0)
    return best, payload.spec


def run(fast: bool = False):
    spec, scale = _arch_spec(fast)
    cfg_m = spec.model
    rounds = 2 if fast else 4
    seq_len = 16 if fast else 64
    n_dev = len(jax.devices())

    params0 = tfm.init_params(jax.random.PRNGKey(0), cfg_m)
    n_params = int(sum(x.size for x in jax.tree_util.tree_leaves(params0)))
    data = _make_data(cfg_m, seq_len)
    loss_fn = lambda p, xb, yb: tfm.loss(p, cfg_m, xb, loss_chunk=seq_len)
    fcfg = FedConfig(gamma=0.05, local_steps=2, n_clients=N_CLIENTS,
                     clients_per_round=CLIENTS_PER_ROUND, batch_size=2)
    comp = TopK(DENSITY)

    # fixed innovation tree for the isolated encode timing (same input at
    # every m, so the timing sweep measures the per-device pack cost only)
    leaves, treedef = jax.tree_util.tree_flatten(params0)
    ks = jax.random.split(jax.random.PRNGKey(7), len(leaves))
    innov = jax.tree_util.tree_unflatten(treedef, [
        jax.random.normal(k, (CLIENTS_PER_ROUND,) + l.shape, jnp.float32)
        for k, l in zip(ks, leaves)])

    sweep, traj = [], {}
    for m in _model_shard_sweep(n_dev, spec):
        clients = min(n_dev // m, CLIENTS_PER_ROUND)
        mesh = (make_client_mesh(clients) if m == 1 else
                make_client_mesh(clients, model=m, config=spec))
        alg = FedAvg(loss_fn, data, fcfg, comp, wire="packed")
        alg.use_mesh(mesh)
        p0 = params0
        if m > 1:
            p0 = jax.device_put(params0,
                                sspecs.param_shardings(params0, mesh))
        state = alg.init(p0)
        t0 = time.time()
        state, ms = alg.run_rounds(state, jax.random.PRNGKey(3), rounds)
        jax.block_until_ready(state.x)
        total = time.time() - t0
        t1 = time.time()
        state, ms2 = alg.run_rounds(state, jax.random.PRNGKey(4), rounds)
        jax.block_until_ready(state.x)
        timed = time.time() - t1

        encode_s, wspec = _time_encode(mesh, spec, comp, innov)
        per_dev = wire.per_device_payload_nbytes(wspec)
        traj[m] = {k: np.asarray(v) for k, v in ms.items()}
        sweep.append({
            "name": f"big_model/m{m}",
            "model_shards": m,
            "clients_axis": clients,
            "rounds": rounds,
            "us_per_round": round(timed / rounds * 1e6, 1),
            "compile_plus_first_s": round(total, 2),
            "encode_us_per_call": round(encode_s * 1e6, 1),
            "uplink_bits_per_round": float(
                np.asarray(ms["uplink_bits"]).mean()),
            "payload_bytes_per_round": float(
                np.asarray(ms["uplink_payload_bytes"]).mean()),
            "per_device_payload_bytes_per_client": per_dev,
            "train_loss": [round(float(x), 5)
                           for x in np.asarray(ms["train_loss"])]
            if "train_loss" in ms else None,
            "useful": per_dev,
        })

    # -- §9 consistency checks across the sweep --------------------------- #
    ms1 = traj.get(1)
    checks = {"bits_max_rel_delta": 0.0, "loss_max_rel_delta": 0.0}
    failures = []
    if ms1 is not None:
        for m, msm in traj.items():
            if m == 1:
                continue
            b1, bm = ms1["uplink_bits"], msm["uplink_bits"]
            # identical up to ties: different mesh layouts reorder float
            # reductions, so trajectories diverge in the last ulp and an
            # exact 32-bit magnitude tie at the TopK threshold can flip a
            # handful of slots (64 bits each) either way
            rel = float(np.max(np.abs(bm - b1) / np.maximum(b1, 1.0)))
            checks["bits_max_rel_delta"] = max(
                checks["bits_max_rel_delta"], rel)
            if rel > 1e-4:
                failures.append(
                    f"m={m} bits accounting diverged beyond tie noise: "
                    f"{b1} vs {bm}")
            if "train_loss" in ms1:
                l1 = np.asarray(ms1["train_loss"], np.float64)
                lm = np.asarray(msm["train_loss"], np.float64)
                lrel = float(np.max(np.abs(lm - l1) / np.maximum(
                    np.abs(l1), 1e-6)))
                checks["loss_max_rel_delta"] = max(
                    checks["loss_max_rel_delta"], lrel)
                if lrel > 0.05:
                    failures.append(
                        f"m={m} training trajectory diverged: {l1} vs {lm}")
        # per-device uplink bytes must shrink with the model-shard factor
        by_m = {r["model_shards"]: r["per_device_payload_bytes_per_client"]
                for r in sweep}
        for m in sorted(by_m):
            if m > 1 and not by_m[m] < by_m[1]:
                failures.append(
                    f"per-device payload did not shrink: m=1 {by_m[1]}B "
                    f"vs m={m} {by_m[m]}B")
    checks["failures"] = failures

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "big_model.json").write_text(json.dumps({
        "devices": n_dev,
        "platform": jax.devices()[0].platform,
        "arch": ARCH,
        "scale": scale,
        "n_params": n_params,
        "seq_len": seq_len,
        "rounds": rounds,
        "density": DENSITY,
        "checks": checks,
        "sweep": sweep,
    }, indent=2))
    if failures:                     # after the artifact, so evidence lands
        raise AssertionError("; ".join(failures))
    return sweep
