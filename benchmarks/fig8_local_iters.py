"""Paper Figure 8 — number of local iterations (communication prob p).

Total cost = rounds x (1 + tau / p) with tau = 0.01 (paper's cost model:
a communication round costs 1, a local step costs tau)."""

from repro.compress import TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.mnist_setup()
    rows = []
    tau = 0.01
    for p in (0.05, 0.1, 0.2, 0.3, 0.5):
        cfg = FedComLocConfig(gamma=0.1, p=p, n_clients=20,
                              clients_per_round=5, batch_size=32,
                              variant="com")
        alg = FedComLoc(loss_fn, data, cfg, TopK(density=0.3))
        row = common.run_fl(f"fig8/p{p}", alg, model, eval_fn, rounds,
                            extra={"p": p})
        row["total_cost"] = round(rounds * (1 + tau / p), 2)
        rows.append(row)
    return rows
