"""Paper Table 2 / Figure 2 — heterogeneity (Dirichlet alpha) x sparsity."""

from repro.compress import Identity, TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    alphas = (0.1, 0.7) if fast else (0.1, 0.3, 0.7, 1.0)
    rows = []
    for alpha in alphas:
        data, model, loss_fn, eval_fn = common.mnist_setup(alpha=alpha)
        for density in (0.1, 0.5, 1.0):
            comp = Identity() if density >= 1.0 else TopK(density=density)
            cfg = FedComLocConfig(
                gamma=0.1, p=0.1, n_clients=20, clients_per_round=5,
                batch_size=32,
                variant="com" if density < 1.0 else "none")
            alg = FedComLoc(loss_fn, data, cfg, comp)
            rows.append(common.run_fl(
                f"table2/alpha{alpha}_k{int(density*100)}",
                alg, model, eval_fn, rounds,
                extra={"alpha": alpha, "density": density}))
    return rows
