"""Paper Table 1 / Figure 1 — test accuracy across TopK density ratios on
FedMNIST (synthetic stand-in), FedComLoc-Com."""

from repro.compress import Identity, TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig

from benchmarks import common


def run(fast: bool = False):
    rounds = common.FAST_ROUNDS if fast else common.FULL_ROUNDS
    data, model, loss_fn, eval_fn = common.mnist_setup()
    rows = []
    for density in (1.0, 0.1, 0.3, 0.5, 0.7, 0.9):
        comp = Identity() if density >= 1.0 else TopK(density=density)
        cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                              clients_per_round=5, batch_size=32,
                              variant="com" if density < 1.0 else "none")
        alg = FedComLoc(loss_fn, data, cfg, comp)
        rows.append(common.run_fl(f"table1/topk_{int(density*100)}pct",
                                  alg, model, eval_fn, rounds,
                                  extra={"density": density}))
    base = next(r for r in rows if r["density"] == 1.0)["best_acc"]
    for r in rows:
        r["acc_drop_pct"] = round(100 * (base - r["best_acc"]), 2)
    return rows
