"""FedComLoc as a multi-pod LLM training feature (DESIGN.md §2).

Runs REAL federated rounds of a reduced qwen2-family LM on a host-device
(pod, data, model) mesh — each "pod" is one federated client; the only
cross-pod traffic is the compressed per-round parameter sync.  The same
``build_fed_round`` lowers the full-size architectures on the 2x16x16
production mesh (see launch/dryrun.py --fed).

  PYTHONPATH=src python examples/fed_multipod.py --pods 2 --rounds 6
"""

import os

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--compressor", default="topk",
                    choices=["topk", "quant", "none"])
    args = ap.parse_args()

    # placeholder devices BEFORE jax init (pods x 1 x 1 host mesh)
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.pods}")

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.configs import get_spec
    from repro.configs.base import SHAPES, reduced
    from repro.data import synthetic
    from repro.launch import fed_train
    from repro.models import transformer as tfm

    spec = reduced(get_spec("qwen2-0.5b"))
    m = dataclasses.replace(spec.model, n_layers=2, d_model=128, d_ff=256,
                            vocab=256, n_heads=4, n_kv_heads=2, head_dim=32,
                            dtype=jnp.float32)
    spec = dataclasses.replace(spec, model=m)

    devs = np.array(jax.devices()[:args.pods]).reshape(args.pods, 1, 1)
    mesh = Mesh(devs, ("pod", "data", "model"))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128,
                                global_batch=2 * args.pods)
    fed = fed_train.FedTrainConfig(
        gamma=0.2, p=1.0 / args.local_steps,
        local_steps=args.local_steps, compressor=args.compressor,
        density=0.2, quant_bits=8)
    bundle = fed_train.build_fed_round(spec, shape, mesh, fed)

    params = tfm.init_params(jax.random.PRNGKey(0), m)
    stack = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (args.pods,) + x.shape), t)
    params_s = stack(params)
    h_s = stack(jax.tree_util.tree_map(jnp.zeros_like, params))

    toks = jnp.asarray(synthetic.make_lm_tokens(
        m.vocab, 2 * args.pods, shape.seq_len, seed=0)).reshape(
        args.pods, 2, shape.seq_len)

    from repro.compress import dense_bits

    with mesh:
        step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       out_shardings=bundle.out_shardings)
        key = jax.random.PRNGKey(1)
        total_bits = 0.0
        for r in range(args.rounds):
            key, sub = jax.random.split(key)
            params_s, h_s, loss, comm_bits = step(
                params_s, h_s, {"tokens": toks}, sub)
            total_bits += float(comm_bits)
            print(f"round {r + 1}: loss {float(loss):.4f}  "
                  f"cross-pod Mbits so far {total_bits / 1e6:.1f} "
                  f"({fed.compressor})")
    bits_per_round = total_bits / max(args.rounds, 1)
    dense = args.pods * dense_bits(params)
    print(f"\nper-round cross-pod traffic (measured in-graph): "
          f"{bits_per_round / 1e6:.1f} Mb vs {dense / 1e6:.1f} Mb dense "
          f"({dense / max(bits_per_round, 1):.1f}x reduction)")


if __name__ == "__main__":
    main()
