"""Quickstart — FedComLoc in ~40 lines.

Trains the paper's 3-layer MLP on synthetic FedMNIST with TopK-compressed
uplinks (FedComLoc-Com, the paper's default), printing accuracy and the
communicated bits after every few rounds.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import fed_data, server
from repro.compress import TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.data import dirichlet, synthetic
from repro.models import small


def main() -> None:
    # 1. federated data: Dirichlet(0.7)-heterogeneous shards over 20 clients
    ds = synthetic.make_mnist_like(n_train=8000, n_test=1000)
    parts = dirichlet.dirichlet_partition(ds.y_train, n_clients=20,
                                          alpha=0.7, seed=0)
    data = fed_data.from_numpy_partition(ds.x_train, ds.y_train, parts)

    # 2. the paper's FedMNIST model + loss
    model = small.MLP(784, 64, 10)
    loss_fn = small.cross_entropy_loss(model.apply)

    # 3. FedComLoc-Com: TopK(30%) uplink compression, p = 0.1
    #    (expected 10 local steps per communication round)
    cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=20,
                          clients_per_round=5, batch_size=32,
                          variant="com")
    alg = FedComLoc(loss_fn, data, cfg, TopK(density=0.3))

    # 4. run 40 rounds with centralized eval
    eval_fn = server.make_eval_fn(model.apply, jnp.asarray(ds.x_test),
                                  jnp.asarray(ds.y_test))
    hist = server.run_federated(alg, model.init(jax.random.PRNGKey(0)),
                                num_rounds=40, key=jax.random.PRNGKey(1),
                                eval_fn=eval_fn, eval_every=5, log_every=5)
    print(f"\nbest accuracy {hist.best_acc:.4f} "
          f"after {alg.meter.total_bits / 1e6:.0f} Mbits "
          f"({alg.meter.uplink_bits / 1e6:.0f} up / "
          f"{alg.meter.downlink_bits / 1e6:.0f} down)")


if __name__ == "__main__":
    main()
