"""End-to-end driver (deliverable (b)): the paper's full FedMNIST pipeline.

Trains the paper's MLP for a few hundred communication rounds with
FedComLoc-Com at several compression settings, checkpointing the server
model each 50 rounds and writing the metric histories to JSON — a reduced
but complete version of the paper's Table 1 / Figure 1 experiment.

  PYTHONPATH=src python examples/fedmnist_e2e.py [--rounds 200]
"""

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint
from repro.core import fed_data, server
from repro.compress import Identity, QuantQr, TopK
from repro.core.fedcomloc import FedComLoc, FedComLocConfig
from repro.data import dirichlet, synthetic
from repro.models import small

OUT = Path(__file__).resolve().parent / "out"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--alpha", type=float, default=0.7)
    args = ap.parse_args()

    ds = synthetic.make_mnist_like(n_train=20_000, n_test=2000)
    parts = dirichlet.dirichlet_partition(ds.y_train, args.clients,
                                          args.alpha, seed=0)
    data = fed_data.from_numpy_partition(ds.x_train, ds.y_train, parts)
    model = small.MLP(784, 128, 10)
    loss_fn = small.cross_entropy_loss(model.apply)
    eval_fn = server.make_eval_fn(model.apply, jnp.asarray(ds.x_test),
                                  jnp.asarray(ds.y_test))
    OUT.mkdir(exist_ok=True)

    settings = {
        "dense": (Identity(), "none"),
        "topk30": (TopK(density=0.3), "com"),
        "quant8": (QuantQr(r=8), "com"),
    }
    results = {}
    for tag, (comp, variant) in settings.items():
        print(f"\n=== {tag} ===")
        cfg = FedComLocConfig(gamma=0.1, p=0.1, n_clients=args.clients,
                              clients_per_round=10, batch_size=32,
                              variant=variant)
        alg = FedComLoc(loss_fn, data, cfg, comp)
        params0 = model.init(jax.random.PRNGKey(0))
        state = alg.init(params0)
        hist = server.History()
        key = jax.random.PRNGKey(1)
        import time
        t0 = time.time()
        for r in range(args.rounds):
            key, sub = jax.random.split(key)
            state, metrics = alg.round(state, sub)
            if r % 10 == 0 or r == args.rounds - 1:
                tl, ta = eval_fn(state.x)
                hist.rounds.append(r + 1)
                hist.train_loss.append(metrics["train_loss"])
                hist.test_acc.append(float(ta))
                hist.test_loss.append(float(tl))
                hist.total_bits.append(alg.meter.total_bits)
                hist.uplink_bits.append(alg.meter.uplink_bits)
                hist.wall_s.append(time.time() - t0)
                print(f"round {r + 1:4d}  acc {float(ta):.4f}  "
                      f"Mbits {alg.meter.total_bits / 1e6:8.1f}")
            if (r + 1) % 50 == 0:
                checkpoint.save(OUT / f"{tag}_round{r + 1}.npz", state.x,
                                meta={"round": r + 1, "tag": tag})
        results[tag] = hist.as_dict()

    (OUT / "fedmnist_e2e.json").write_text(json.dumps(results, indent=2))
    print(f"\nwrote {OUT / 'fedmnist_e2e.json'}")
    for tag, h in results.items():
        print(f"{tag:8s} best acc {max(h['test_acc']):.4f}  "
              f"bits-to-0.9 "
              f"{next((b for a, b in zip(h['test_acc'], h['total_bits']) if a >= 0.9), float('nan')) / 1e6:.0f} Mb")


if __name__ == "__main__":
    main()
