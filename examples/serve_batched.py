"""Batched serving example: prefill a batch of prompts on a reduced
architecture from the assigned pool, then decode with the KV-cache /
recurrent-state machinery the dry-run lowers at 32k/500k scale.

  PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-3b
  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_spec
from repro.configs.base import reduced
from repro.data import synthetic
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    spec = reduced(get_spec(args.arch))
    m = spec.model
    key = jax.random.PRNGKey(0)
    max_len = args.prompt_len + args.gen + 1
    toks = jnp.asarray(synthetic.make_lm_tokens(
        m.vocab, args.batch, args.prompt_len, seed=1))

    t0 = time.time()
    if spec.is_encdec:
        params = encdec_mod.init_params(key, m)
        src = jax.random.normal(jax.random.PRNGKey(2),
                                (args.batch, args.prompt_len, m.d_model))
        logits, state = encdec_mod.prefill(params, m, src, toks[:, :4],
                                           max_len=max_len)
        decode = jax.jit(
            lambda p, t, s: encdec_mod.decode_step(p, m, t, s))
    else:
        params = tfm.init_params(key, m)
        logits, state = tfm.prefill(params, m, toks, max_len=max_len)
        decode = jax.jit(lambda p, t, s: tfm.decode_step(p, m, t, s))
    print(f"[{args.arch}] prefill({args.batch}x{args.prompt_len}) "
          f"in {time.time() - t0:.1f}s")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen):
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.stack(out, 1)
    print(f"decoded {args.gen} tokens x {args.batch} "
          f"({args.gen * args.batch / dt:.1f} tok/s incl. compile)")
    print("sample continuation ids:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
