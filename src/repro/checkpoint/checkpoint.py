"""Pytree checkpointing without external deps (.npz + JSON treedef).

Round-resumable: ``save(path, tree, meta)`` / ``load(path)`` round-trips any
nested dict/tuple/NamedTuple-free pytree of arrays (FL states are plain
dicts + arrays).  Writes atomically (tmp + rename).
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class CheckpointStructureError(ValueError):
    """``load(like=)`` template does not match the stored leaf count."""


class CheckpointDtypeError(ValueError):
    """Extension-dtype leaves saved without manifest dtype names."""


def _flatten(tree: PyTree) -> Tuple[dict, list]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    return arrays, treedef


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including the ml_dtypes extension types
    (bfloat16, float8_*) numpy's npz format round-trips as raw void bytes."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def save(path: str | Path, tree: PyTree, meta: Optional[dict] = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays, treedef = _flatten(tree)
    payload = {
        "treedef": str(treedef),
        "meta": meta or {},
        "n_leaves": len(arrays),
        # npz stores extension dtypes (bfloat16, ...) as opaque |V bytes;
        # the manifest keeps the real names so load can view-cast back
        "dtypes": [str(arrays[f"leaf_{i}"].dtype)
                   for i in range(len(arrays))],
    }
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __manifest__=json.dumps(payload), **arrays)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp,
                   path)
    finally:
        for t in (tmp, tmp + ".npz"):
            if os.path.exists(t):
                os.remove(t)


def load(path: str | Path, like: Optional[PyTree] = None
         ) -> Tuple[PyTree, dict]:
    """Load a checkpoint.  ``like`` supplies the treedef (required unless the
    tree is reconstructed by caller from the flat leaves).

    Raises :class:`CheckpointStructureError` when ``like``'s structure does
    not match the stored leaf count, and :class:`CheckpointDtypeError` when
    an extension-dtype leaf (bfloat16, float8_*) was saved by a writer too
    old to record real dtype names — both previously surfaced as opaque
    downstream failures (``tree_unflatten`` internals / raw void-byte
    leaves flowing into jnp ops).
    """
    path = Path(path)
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["__manifest__"]))
        dtypes = manifest.get("dtypes")          # absent in old checkpoints
        leaves = []
        for i in range(manifest["n_leaves"]):
            raw = z[f"leaf_{i}"]
            if raw.dtype.kind == "V":
                # npz stored this leaf as opaque void bytes (an extension
                # dtype); without the manifest's dtype names there is no
                # way to recover what it was — fail loudly, not with a
                # raw |V8 array that breaks far from here.
                if dtypes is None:
                    raise CheckpointDtypeError(
                        f"checkpoint {path} leaf_{i} has extension-dtype "
                        f"data ({raw.dtype}) but its manifest predates the "
                        "'dtypes' field; re-save it with a current writer "
                        "(old writers lost bfloat16/float8 dtype names)")
                raw = raw.view(_np_dtype(dtypes[i]))
            leaves.append(jnp.asarray(raw))
    if like is not None:
        treedef = jax.tree_util.tree_structure(like)
        if treedef.num_leaves != len(leaves):
            raise CheckpointStructureError(
                f"checkpoint {path} stores {len(leaves)} leaves but "
                f"like= has {treedef.num_leaves}; the template tree does "
                "not match what was saved (wrong algorithm/config — e.g. "
                "a state built under a different downlink/store mode)")
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"]
    return leaves, manifest["meta"]
