"""Wire codec layer: real packed payloads for compressed trees (DESIGN.md §8).

The operators in :mod:`repro.compress.compressors` are *transforms* — they
return a dense pytree whose zeros/levels merely *represent* the compressed
message, plus a :class:`BitsReport` stating what the payload would cost.
This module is the second layer the tentpole splits out: a **wire codec**
whose ``encode(comp, tree, rng) -> (Payload, BitsReport)`` produces the
physically packed buffers a collective actually moves, and whose
``decode(payload)`` reconstructs the transform's output on the server side.

Codecs (one per supported operator; ``check_supported`` names the mapping):

* ``dense``   — ``Identity`` (and ``TopK(density >= 1)``): raw values at the
  leaf dtype's width.
* ``topk``    — ``TopK(impl="select")``: per unit, a static-capacity
  ``cap = k(density)`` array of ``uint32`` indices plus ``cap`` values at
  the leaf dtype.  Empty slots (input support smaller than ``cap``, e.g.
  error-feedback innovations) carry the sentinel index ``n`` and are
  dropped by the decode scatter.  The static-capacity rule is what keeps
  payload shapes jit-stable inside the fused ``lax.scan``; magnitude ties
  beyond ``cap`` (measure-zero for continuous data) keep the lowest-index
  ``cap`` and drop the rest.
* ``qr``      — ``QuantQr``: one (1+r)-bit code per scalar — sign bit plus
  r level bits — bit-plane packed into ``uint32`` words by the
  :mod:`repro.kernels` pack kernels, plus one fp32 norm per unit.  The top
  level ``2**r`` (reachable only when one coordinate holds > ``(1-2^-r)²``
  of the unit's energy) saturates to ``2**r - 1`` — the same rule
  ``Int8Sync`` applies at 127; everywhere else the decode is bit-identical
  to the transform.
* ``topk_qr`` — ``Compose(TopK, QuantQr)``: indices as in ``topk``, the
  survivors' quantizer codes packed as in ``qr``, one norm per unit.
* ``int8``    — ``Int8Sync``: its existing int8-level + per-tensor-scale
  format, expressed on this API (the launch layer consumes it here).

``scope="tensor"`` codecs emit one *unit* per leaf; ``scope="global"``
flattens the tree to a single unit first (packing at the promoted dtype —
on mixed-dtype trees this is an extra, undocumented-elsewhere slack
source vs the per-leaf-width accounting; single-dtype trees are exact),
exactly mirroring the transforms.  The returned ``BitsReport`` is computed
the same way the transform computes it, so account-only and wire rounds
see identical bit metrics; ``Payload.nbytes`` is the *measured* packed
size, and ``padding_bits`` exposes the (documented, bounded) slack between
the two: empty sparse slots at ``(INDEX_BITS + value width)`` each, plus
``< 32 * (1+r)`` bits of word padding per packed-code unit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compress.compressors import (
    Compose, Compressor, Identity, Int8Sync, QuantQr, TopK)
from repro.compress.report import (
    FLOAT_BITS, INDEX_BITS, BitsReport, dense_report, leaf_value_bits)
from repro.kernels import ops as kops

PyTree = Any

#: Widest supported quantizer: codes must stay float32-exact integers and
#: fit a uint32 word with their sign bit.
MAX_R = 16


# --------------------------------------------------------------------------- #
# Payload
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Static (hashable) description of a packed payload — everything the
    decoder needs: codec, tree structure, per-leaf shapes/dtypes, the
    static sparse capacities, and the per-client packed byte count."""

    codec: str                       # dense | topk | qr | topk_qr | int8
    scope: str                       # tensor | global
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    caps: Tuple[int, ...] = ()       # per-unit sparse capacity (topk codecs)
    r: int = 0                       # level bits (qr / topk_qr / int8)
    nbytes: int = 0                  # packed payload bytes per client
    # Sharded wire path (§9): >1 when the payload was encoded shard-local
    # over a model mesh axis.  ``model_dims[i]`` is leaf i's sharded
    # dimension index (None = replicated leaf); ``caps`` are then
    # *per-shard* capacities for sharded units, and ``shapes`` stay the
    # GLOBAL leaf shapes.  Buffers of sharded units concatenate the shards
    # along their slot/word axis in an opaque, shard-local layout — only
    # ``decode_shard_local`` (under the same shard_map) interprets them.
    model_shards: int = 1
    model_dims: Tuple[Optional[int], ...] = ()


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Payload:
    """Packed wire buffers: ``data[unit]`` is that unit's buffer tuple in
    codec-defined order.  A registered pytree (spec is static aux), so
    payloads flow through ``jit`` / ``vmap`` / ``lax.scan`` / ``shard_map``
    collectives like any array tree — a vmapped ``encode`` yields buffers
    with a leading client axis."""

    data: Tuple[Tuple[jax.Array, ...], ...]
    spec: WireSpec

    def tree_flatten(self):
        return (self.data,), self.spec

    @classmethod
    def tree_unflatten(cls, spec, children):
        return cls(children[0], spec)

    @property
    def nbytes(self) -> int:
        """Static packed size in bytes (per client — excludes any vmap
        client axis, which multiplies buffers but not the spec)."""
        return self.spec.nbytes


def _buffers_nbytes(data) -> int:
    return int(sum(b.size * jnp.dtype(b.dtype).itemsize
                   for unit in data for b in unit))


def measured_bits(payload: Payload):
    """The packed payload's wire cost in bits (static scalar)."""
    return float(payload.nbytes) * 8.0


def padding_bits(payload: Payload, report: BitsReport):
    """In-graph slack between measured and accounted bits.

    Equals (a) ``(cap - nnz) * (INDEX_BITS + value width)`` for each
    sparse unit whose support underfills its static capacity and (b)
    ``< 32 * (1 + r)`` word-padding bits per packed-code unit; buffers are
    byte-granular, so dense/int8 payloads have zero slack.  The §8
    reconcile tests pin both closed forms.  Two edge cases can perturb the
    sign/size: TopK threshold ties beyond ``cap`` (the transform's report
    counts every tie but only ``cap`` slots ship, a *negative*
    contribution — measure-zero for continuous data, reachable with
    constant-valued tensors) and ``scope="global"`` over mixed-dtype
    trees (values pack at the promoted dtype while the report accounts
    each leaf at its own width).
    """
    return measured_bits(payload) - report.total_bits


# --------------------------------------------------------------------------- #
# codec resolution
# --------------------------------------------------------------------------- #

def check_supported(comp: Optional[Compressor]) -> str:
    """Return the wire codec name for ``comp``, or raise ``ValueError``.

    The static-capacity rule needs an exact-k support, so
    ``TopK(impl="quantile")`` (approximate k) is rejected; ``Compose`` is
    supported for the TopK -> QuantQr composition with matching scopes.
    """
    if comp is None or isinstance(comp, Identity):
        return "dense"
    if isinstance(comp, TopK):
        if comp.density >= 1.0:
            return "dense"
        if comp.impl != "select":
            raise ValueError(
                'wire codecs need the exact-k support: TopK(impl="select") '
                f'(got impl={comp.impl!r} — quantile keeps a data-dependent '
                f'count, which has no static capacity)')
        return "topk"
    if isinstance(comp, QuantQr):
        if comp.r > MAX_R:
            raise ValueError(f"wire codec supports r <= {MAX_R}, "
                             f"got r={comp.r}")
        return "qr"
    if isinstance(comp, Int8Sync):
        return "int8"
    if isinstance(comp, Compose):
        if not (isinstance(comp.first, TopK)
                and isinstance(comp.second, QuantQr)):
            raise ValueError(
                f"wire codec supports Compose(TopK, QuantQr) only, got "
                f"{type(comp.first).__name__}->{type(comp.second).__name__}")
        if comp.first.scope != comp.second.scope:
            raise ValueError(
                f"wire Compose needs matching scopes, got "
                f"{comp.first.scope!r} -> {comp.second.scope!r}")
        if comp.second.r > MAX_R:
            raise ValueError(f"wire codec supports r <= {MAX_R}, "
                             f"got r={comp.second.r}")
        if comp.first.impl != "select":
            raise ValueError('wire Compose needs TopK(impl="select")')
        if comp.first.density >= 1.0:
            return "qr"           # dense support: pure packed-code payload
        return "topk_qr"
    raise ValueError(
        f"no wire codec for {type(comp).__name__}; supported: Identity, "
        f"TopK(select), QuantQr, Compose(TopK, QuantQr), Int8Sync")


def _scope_of(comp, codec: str) -> str:
    if codec in ("dense", "int8"):
        return "tensor" if not isinstance(comp, TopK) else comp.scope
    if isinstance(comp, Compose):
        return comp.first.scope
    return comp.scope


# --------------------------------------------------------------------------- #
# unit plumbing (scope="tensor": one unit per leaf; "global": one flat unit)
# --------------------------------------------------------------------------- #

def _tree_units(tree: PyTree, scope: str):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if scope == "global":
        units = [jnp.concatenate([l.reshape(-1) for l in leaves])]
    else:
        units = [l.reshape(-1) for l in leaves]
    return leaves, treedef, units


def _units_to_tree(units, spec: WireSpec) -> PyTree:
    shapes, dtypes = spec.shapes, spec.dtypes
    if spec.scope == "global":
        flat, parts, off = units[0], [], 0
        for shp, dt in zip(shapes, dtypes):
            size = 1
            for s in shp:
                size *= s
            parts.append(flat[off:off + size].reshape(shp).astype(dt))
            off += size
    else:
        parts = [u.reshape(shp).astype(dt)
                 for u, shp, dt in zip(units, shapes, dtypes)]
    return jax.tree_util.tree_unflatten(spec.treedef, parts)


# --------------------------------------------------------------------------- #
# sparse (index, value) slots — static capacity, sentinel-padded
# --------------------------------------------------------------------------- #
#
# Slot *extraction* lives in the fused kernels now
# (``kops.topk_slots`` / ``kops.topk_qr_slots``: threshold select +
# streaming compaction, no sort and no n-sized cumsum on the Pallas
# backends); this module keeps only the decode-side scatter.

def _scatter_units(entries, unit_sizes, dtype):
    """Decode-side placement: one masked scatter for the whole payload.

    ``entries`` is one ``(idx, vals)`` pair per sparse unit (sentinel-``n``
    indices mark empty slots).  Unit indices are offset into a single
    concatenated index space — sentinels map to ``total`` so one
    ``mode="drop"`` scatter places every unit's survivors at once (one
    XLA scatter instead of one per leaf), then the flat result is split
    back into units."""
    total = sum(unit_sizes)
    offs, off = [], 0
    for n in unit_sizes:
        offs.append(off)
        off += n
    idx_all = jnp.concatenate([
        jnp.where(idx < n, idx.astype(jnp.int32) + off, total)
        for (idx, _), n, off in zip(entries, unit_sizes, offs)])
    val_all = jnp.concatenate([v.astype(dtype) for _, v in entries])
    flat = jnp.zeros((total,), dtype).at[idx_all].set(val_all, mode="drop")
    return [flat[off:off + n] for off, n in zip(offs, unit_sizes)]


def _sparse_report_from_support(leaves, supports, scope: str) -> BitsReport:
    """The TopK transform's bit accounting, from the fused kernels' support
    masks — per leaf and in the leaf order, replicating
    ``compressors._sparse_report`` exactly (same accumulation order, nnz
    from the same kept-support set) so account-only and wire rounds see
    identical bit metrics without materialising the masked tree."""
    if scope == "global":
        segs, off = [], 0
        for leaf in leaves:
            segs.append(supports[0][off:off + leaf.size])
            off += leaf.size
    else:
        segs = supports
    vb = ib = 0.0
    for leaf, seg in zip(leaves, segs):
        nnz = jnp.sum(seg).astype(jnp.float32)
        vb = vb + nnz * leaf_value_bits(leaf)
        ib = ib + nnz * INDEX_BITS
    return BitsReport(value_bits=vb, index_bits=ib)


def _qr_values(codes: jax.Array, norm: jax.Array, r: int) -> jax.Array:
    """Decode (1+r)-bit codes back to float values (fp32)."""
    levels = jnp.asarray(2 ** r, jnp.float32)
    m = (codes & jnp.uint32(2 ** r - 1)).astype(jnp.float32)
    sgn = jnp.where((codes >> r) & jnp.uint32(1), -1.0, 1.0)
    out = norm * sgn * (m / levels)
    return jnp.where(norm > 0, out, jnp.zeros_like(out))


# --------------------------------------------------------------------------- #
# encode / decode
# --------------------------------------------------------------------------- #

def encode(comp: Optional[Compressor], tree: PyTree,
           rng: Optional[jax.Array] = None
           ) -> Tuple[Payload, BitsReport]:
    """Pack ``tree`` into the wire format of ``comp``.

    Returns ``(payload, report)`` where ``report`` is computed exactly as
    the transform computes it (account-only and wire rounds see identical
    bit metrics) and ``decode(payload)`` reconstructs what
    ``comp.compress(tree, rng)`` would have returned.  The rng contract
    (split structure per leaf) matches the transforms', so wire and
    account modes consume the same key chain.
    """
    codec = check_supported(comp)
    scope = _scope_of(comp, codec)
    leaves, treedef, units = _tree_units(tree, scope)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype).name for l in leaves)

    def mkspec(data, **kw):
        return WireSpec(codec=codec, scope=scope, treedef=treedef,
                        shapes=shapes, dtypes=dtypes,
                        nbytes=_buffers_nbytes(data), **kw)

    if codec == "dense":
        # Identity or TopK(density >= 1): raw values, leaf-dtype width.
        data = tuple((u,) for u in units)
        return Payload(data, mkspec(data)), dense_report(tree)

    if codec == "topk":
        # Fused select+pack: per unit, one threshold select + one streaming
        # compaction emits the (idx, vals) slots directly — the masked tree
        # is never materialised; the report comes from the support masks.
        caps, data, sups = [], [], []
        for u in units:
            cap = comp._k(u.size)
            idx, vals, support = kops.topk_slots(u, cap, cap)
            data.append((idx, vals))
            caps.append(cap)
            sups.append(support)
        data = tuple(data)
        report = _sparse_report_from_support(leaves, sups, scope)
        return Payload(data, mkspec(data, caps=tuple(caps))), report

    if codec == "qr":
        # QuantQr — or Compose(TopK(density>=1), QuantQr), whose rng chain
        # first burns the compose split.
        if rng is None:
            raise ValueError("quantizer codecs need an rng key")
        if isinstance(comp, Compose):
            _, rng = jax.random.split(rng)
            r = comp.second.r
        else:
            r = comp.r
        keys = jax.random.split(rng, len(leaves))
        data = []
        for i, u in enumerate(units):
            words, norm = kops.quantize_pack(u, r, keys[min(i, len(leaves) - 1)])
            data.append((words, norm))
        data = tuple(data)
        n = sum(u.size for u in units)
        report = BitsReport(
            value_bits=jnp.asarray(float(n) * (1 + r), jnp.float32),
            meta_bits=jnp.asarray(float(len(units)) * FLOAT_BITS))
        return Payload(data, mkspec(data, r=r)), report

    if codec == "topk_qr":
        if rng is None:
            raise ValueError("quantizer codecs need an rng key")
        _, k2 = jax.random.split(rng)            # compose's (k1, k2) split
        r = comp.second.r
        keys = jax.random.split(k2, len(leaves))
        caps, data, sups = [], [], []
        for i, u in enumerate(units):
            cap = comp.first._k(u.size)
            idx, words, norm, support = kops.topk_qr_slots(
                u, cap, cap, r, keys[min(i, len(leaves) - 1)])
            data.append((idx, words, norm))
            caps.append(cap)
            sups.append(support)
        data = tuple(data)
        rep1 = _sparse_report_from_support(leaves, sups, scope)
        nnz = rep1.index_bits / INDEX_BITS       # the transmitted support
        report = BitsReport(
            value_bits=nnz * (1 + r), index_bits=rep1.index_bits,
            meta_bits=jnp.asarray(float(len(units)) * FLOAT_BITS))
        return Payload(data, mkspec(data, caps=tuple(caps), r=r)), report

    # codec == "int8" (Int8Sync; tensor scope by construction).  Level
    # buffers keep the leaf's shape — byte-granular already, and the launch
    # layer constrains their within-pod sharding like the dense params.
    if rng is None:
        raise ValueError("Int8Sync codec needs an rng key")
    levels, scales = comp.encode(tree, rng)
    lv = jax.tree_util.tree_leaves(levels)
    sc = jax.tree_util.tree_leaves(scales)
    data = tuple((q, s) for q, s in zip(lv, sc))
    return (Payload(data, mkspec(data, r=comp.magnitude_bits)),
            comp.report(tree))


def decode(payload: Payload) -> PyTree:
    """Unpack a :class:`Payload` back to the transform-output pytree."""
    spec = payload.spec
    sizes = []
    for shp in spec.shapes:
        size = 1
        for s in shp:
            size *= s
        sizes.append(size)
    unit_sizes = [sum(sizes)] if spec.scope == "global" else sizes

    if spec.codec in ("topk", "topk_qr"):
        # One masked scatter for the whole payload: unit slots concatenate
        # into a single offset index space (sentinels drop), so the decode
        # issues one XLA scatter instead of one ``.at[].set`` per unit.
        entries = []
        for i, bufs in enumerate(payload.data):
            if spec.codec == "topk":
                idx, vals = bufs
            else:
                idx, words, norm = bufs
                codes = kops.unpack_codes(words, 1 + spec.r, spec.caps[i])
                vals = _qr_values(codes, norm, spec.r)
            entries.append((idx, vals))
        vtype = jnp.result_type(*[v.dtype for _, v in entries])
        units = _scatter_units(entries, unit_sizes, vtype)
        return _units_to_tree(units, spec)

    units = []
    for bufs, n in zip(payload.data, unit_sizes):
        if spec.codec == "dense":
            units.append(bufs[0])
        elif spec.codec == "qr":
            words, norm = bufs
            codes = kops.unpack_codes(words, 1 + spec.r, n)
            units.append(_qr_values(codes, norm, spec.r))
        elif spec.codec == "int8":
            q, s = bufs                       # q keeps the leaf's shape
            units.append((q.astype(jnp.float32) * s).reshape(-1))
        else:  # pragma: no cover - spec constructed by encode only
            raise ValueError(f"unknown codec {spec.codec!r}")
    return _units_to_tree(units, spec)


# --------------------------------------------------------------------------- #
# sharded wire path (§9): shard-local encode/decode over a model mesh axis
# --------------------------------------------------------------------------- #
#
# When clients are composed with a model axis, each model shard packs the
# slots of ITS slice of every sharded leaf — against the exact *global*
# TopK threshold (per-pass psum of the radix walk's counts, no gather of
# magnitudes) or the exact *global* l2 norm (one psum'd sum of squares).
# The gathered uplink then moves per-shard packed buffers, so both encode
# work and gather volume scale with ``1/model_shards``.  Replicated leaves
# (biases, norms — anything ``param_shardings`` leaves unsharded) are
# packed identically on every shard and counted/shipped once.

def shard_cap(k_global: int, model_shards: int, n_local: int) -> int:
    """Static per-shard slot capacity for a sharded sparse unit.

    The global TopK support splits across shards hypergeometrically —
    ``k/m`` expected slots per shard — so each shard gets ``ceil(k/m)``
    plus ``max(64, ceil(4*sqrt(k/m)))`` slack (≈4σ of the binomial
    fluctuation, floored so small units get absolute headroom).  Whenever
    ``cap >= k_global`` overflow is impossible; beyond that, a shard whose
    local support exceeds its capacity keeps the lowest-index ``cap``
    (the §8 static-capacity ties rule, applied per shard) — the bit
    *accounting* stays exact either way, since it counts the psum'd
    support, not the slots.
    """
    base = -(-int(k_global) // int(model_shards))
    slack = max(64, math.ceil(4.0 * math.sqrt(max(base, 1))))
    return int(min(int(n_local), base + slack))


def check_sharded_supported(comp: Optional[Compressor],
                            model_shards: int) -> str:
    """``check_supported`` plus the shard-local feasibility rules.

    ``dense``, ``topk`` and ``qr`` have shard-local formats (elementwise,
    psum'd threshold, psum'd norm).  ``topk_qr`` does not (the survivor
    quantizer's norm is the *masked* vector's, which would need the global
    support before any shard can code), nor does ``int8`` (its scales come
    from ``Compressor.encode`` on whole leaves), nor ``scope="global"``
    (one flat unit cannot straddle sharded and replicated leaves).  Those
    raise with the workaround spelled out.
    """
    codec = check_supported(comp)
    if model_shards <= 1:
        return codec
    if isinstance(comp, Compose) or codec in ("topk_qr", "int8"):
        raise ValueError(
            f"codec {codec!r} has no shard-local wire format (survivor "
            f"quantization / int8 scales need whole leaves before coding); "
            f"run wire='account' or a model=1 mesh, or use TopK(select) / "
            f"QuantQr / dense on the sharded path")
    if _scope_of(comp, codec) != "tensor":
        raise ValueError(
            'scope="global" flattens the tree to one unit, which cannot '
            "straddle model-sharded and replicated leaves; use "
            'scope="tensor" (or wire="account" / a model=1 mesh)')
    return codec


def sharded_wire_spec(comp: Optional[Compressor], tree: PyTree,
                      model_dims: Tuple[Optional[int], ...],
                      model_shards: int) -> WireSpec:
    """Build the static :class:`WireSpec` for a shard-local payload.

    ``tree`` carries the GLOBAL leaf shapes (arrays or ShapeDtypeStructs —
    built in the outer, model-auto region where leaves are logically
    global); ``model_dims[i]`` names leaf i's sharded dimension (None =
    replicated; the dimension size must divide ``model_shards``).
    Capacities are per shard for sharded units and the full ``k`` for
    replicated ones; ``nbytes`` is the true global wire size — sharded
    buffers counted ``model_shards`` times, replicated buffers (and qr
    norms, which every shard computes identically) once.  Everything here
    is static, so construction is trace-time only.
    """
    m = int(model_shards)
    codec = check_sharded_supported(comp, m)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if len(model_dims) != len(leaves):
        raise ValueError(f"model_dims has {len(model_dims)} entries for "
                         f"{len(leaves)} leaves")
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype).name for l in leaves)
    r = 0
    if codec == "qr":
        r = comp.r
    caps, nbytes = [], 0
    for shp, dt, mdim in zip(shapes, dtypes, model_dims):
        n_glob = 1
        for s in shp:
            n_glob *= s
        itemsize = jnp.dtype(dt).itemsize
        if mdim is not None:
            if not (0 <= mdim < len(shp)) or shp[mdim] % m:
                raise ValueError(
                    f"leaf shape {shp}: model dim {mdim} does not divide "
                    f"into {m} shards")
            n_loc = n_glob // m
        else:
            n_loc = n_glob
        if codec == "dense":
            nbytes += n_glob * itemsize       # sharded or not: global bytes
        elif codec == "topk":
            k_glob = comp._k(n_glob)
            if mdim is not None:
                cap = shard_cap(k_glob, m, n_loc)
                nbytes += m * cap * (INDEX_BITS // 8 + itemsize)
            else:
                cap = k_glob
                nbytes += cap * (INDEX_BITS // 8 + itemsize)
            caps.append(cap)
        else:                                 # qr
            words = -(-n_loc // 32) * (1 + r)
            copies = m if mdim is not None else 1
            nbytes += copies * words * 4 + FLOAT_BITS // 8
    return WireSpec(codec=codec, scope="tensor", treedef=treedef,
                    shapes=shapes, dtypes=dtypes, caps=tuple(caps), r=r,
                    nbytes=int(nbytes), model_shards=m,
                    model_dims=tuple(model_dims))


def per_device_payload_nbytes(spec: WireSpec) -> int:
    """One model shard's share of one client's packed payload, in bytes.

    This is what a single device physically ships per client on the §9
    sharded uplink: sharded units contribute their per-shard buffers only,
    replicated units (and qr norms) ride along in full on every shard.
    For an unsharded spec this is exactly ``spec.nbytes``; across the
    model axis, ``model_shards * (sharded part) + replicated part ==
    spec.nbytes``, so total wire bytes are conserved while per-device
    bytes shrink ~1/m.
    """
    if spec.model_shards <= 1:
        return spec.nbytes
    m = spec.model_shards
    total = 0
    ci = 0
    for shp, dt, mdim in zip(spec.shapes, spec.dtypes, spec.model_dims):
        n_glob = _prod(shp)
        n_loc = n_glob // m if mdim is not None else n_glob
        itemsize = jnp.dtype(dt).itemsize
        if spec.codec == "dense":
            total += n_loc * itemsize
        elif spec.codec == "topk":
            total += spec.caps[ci] * (INDEX_BITS // 8 + itemsize)
            ci += 1
        else:                                 # qr
            total += -(-n_loc // 32) * (1 + spec.r) * 4 + FLOAT_BITS // 8
    return int(total)


def _local_sizes(spec: WireSpec):
    """Per-leaf local flat sizes under ``spec``'s sharding."""
    sizes = []
    for shp, mdim in zip(spec.shapes, spec.model_dims):
        n = 1
        for s in shp:
            n *= s
        sizes.append(n // spec.model_shards if mdim is not None else n)
    return sizes


def _local_shape(shp, mdim, m):
    if mdim is None:
        return shp
    return tuple(s // m if d == mdim else s for d, s in enumerate(shp))


def encode_shard_local(comp: Optional[Compressor], tree_loc: PyTree,
                       spec: WireSpec, axis: str,
                       rng: Optional[jax.Array] = None):
    """One client's shard-local encode, inside ``shard_map`` manual over
    mesh axis ``axis`` (callers vmap the client dimension outside).

    ``tree_loc`` holds this shard's slices of the leaves named sharded in
    ``spec`` (replicated leaves arrive whole).  Returns ``(data, report)``:
    ``data`` matches ``spec``'s unit structure with this shard's buffers,
    and ``report`` is the *global* :class:`BitsReport` — sparse counts are
    psum'd int32 nnz per leaf, accumulated in leaf order exactly like
    ``_sparse_report_from_support``, so the accounting is bit-identical to
    the unsharded encode at every shard count.
    """
    leaves, _ = jax.tree_util.tree_flatten(tree_loc)
    units = [l.reshape(-1) for l in leaves]

    if spec.codec == "dense":
        data = tuple((u,) for u in units)
        vb = float(sum(
            _prod(shp) * jnp.dtype(dt).itemsize * 8
            for shp, dt in zip(spec.shapes, spec.dtypes)))
        return data, BitsReport(value_bits=vb)

    if spec.codec == "topk":
        data, vb, ib = [], 0.0, 0.0
        for i, u in enumerate(units):
            n_glob = _prod(spec.shapes[i])
            if spec.model_dims[i] is not None:
                k_glob = comp._k(n_glob)
                idx, vals, support = kops.topk_slots_sharded(
                    u, k_glob, spec.caps[i], axis, n_glob)
                nnz = jax.lax.psum(
                    jnp.sum(support.astype(jnp.int32)), axis)
            else:
                cap = spec.caps[i]
                idx, vals, support = kops.topk_slots(u, cap, cap)
                nnz = jnp.sum(support.astype(jnp.int32))
            data.append((idx, vals))
            nnzf = nnz.astype(jnp.float32)
            vb = vb + nnzf * (jnp.dtype(spec.dtypes[i]).itemsize * 8)
            ib = ib + nnzf * INDEX_BITS
        return tuple(data), BitsReport(value_bits=vb, index_bits=ib)

    # codec == "qr"
    if rng is None:
        raise ValueError("quantizer codecs need an rng key")
    keys = jax.random.split(rng, len(leaves))
    data = []
    for i, u in enumerate(units):
        xf = u.astype(jnp.float32)
        ss = jnp.sum(xf * xf)
        if spec.model_dims[i] is not None:
            # Global norm from one psum'd sum of squares; each shard's
            # rounding uniforms come from its own fold_in'd key (draws
            # differ from the unsharded run — same quantizer, different
            # dither; bits accounting is width-static either way).
            ss = jax.lax.psum(ss, axis)
            key = jax.random.fold_in(keys[i], jax.lax.axis_index(axis))
        else:
            key = keys[i]
        norm = jnp.sqrt(ss)
        u_draw = jax.random.uniform(key, u.shape, dtype=jnp.float32)
        words = kops.quantize_pack_global_norm(u, spec.r, u_draw, norm)
        data.append((words, norm))
    n = sum(_prod(s) for s in spec.shapes)
    report = BitsReport(
        value_bits=jnp.asarray(float(n) * (1 + spec.r), jnp.float32),
        meta_bits=jnp.asarray(float(len(units)) * FLOAT_BITS))
    return tuple(data), report


def _prod(shp) -> int:
    n = 1
    for s in shp:
        n *= s
    return n


def decode_shard_local(data, spec: WireSpec) -> PyTree:
    """Decode one client's shard-local buffers back to the local tree.

    The inverse of :func:`encode_shard_local` for the same shard: sparse
    indices are local, so the scatter lands in this shard's flat slice;
    leaves come back at their LOCAL shapes (global shape with the model
    dimension divided by ``model_shards``) and the caller's ``out_specs``
    place them into the global tree.
    """
    sizes = _local_sizes(spec)
    if spec.codec == "topk":
        entries = list(data)
        vtype = jnp.result_type(*[v.dtype for _, v in entries])
        units = _scatter_units(entries, sizes, vtype)
    elif spec.codec == "qr":
        units = []
        for (words, norm), n in zip(data, sizes):
            codes = kops.unpack_codes(words, 1 + spec.r, n)
            units.append(_qr_values(codes, norm, spec.r))
    else:                                     # dense
        units = [bufs[0] for bufs in data]
    parts = [
        u.reshape(_local_shape(shp, mdim, spec.model_shards)).astype(dt)
        for u, shp, dt, mdim in zip(units, spec.shapes, spec.dtypes,
                                    spec.model_dims)]
    return jax.tree_util.tree_unflatten(spec.treedef, parts)


_NBYTES_CACHE: dict = {}


def _static_wire_key(comp: Optional[Compressor], tree: PyTree):
    """The static tuple packed sizes depend on:
    ``(codec, scope, shapes, dtypes, caps, r)``."""
    codec = check_supported(comp)
    scope = _scope_of(comp, codec)
    leaves = jax.tree_util.tree_leaves(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype).name for l in leaves)
    sizes = [l.size for l in leaves]
    unit_sizes = [sum(sizes)] if scope == "global" else sizes
    if codec == "topk":
        caps = tuple(comp._k(n) for n in unit_sizes)
    elif codec == "topk_qr":
        caps = tuple(comp.first._k(n) for n in unit_sizes)
    else:
        caps = ()
    if codec == "qr":
        r = comp.second.r if isinstance(comp, Compose) else comp.r
    elif codec == "topk_qr":
        r = comp.second.r
    elif codec == "int8":
        r = comp.magnitude_bits
    else:
        r = 0
    return (codec, scope, shapes, dtypes, caps, r)


def payload_nbytes(comp: Optional[Compressor], tree: PyTree) -> int:
    """Static packed bytes of ``comp``'s wire format for ``tree`` — the
    planning-side counterpart of ``Compressor.expected_bits`` (exact, since
    packed shapes are static).

    Memoized on ``(codec, scope, shapes, dtypes, caps, r)``: schedule
    builders query this per round, and the abstract ``jax.eval_shape``
    trace of ``encode`` only runs on the first sighting of a
    configuration — every later call is a dict lookup."""
    key = _static_wire_key(comp, tree)
    nbytes = _NBYTES_CACHE.get(key)
    if nbytes is None:
        struct = jax.eval_shape(
            lambda t: encode(comp, t, jax.random.PRNGKey(0))[0], tree)
        nbytes = _NBYTES_CACHE[key] = struct.spec.nbytes
    return nbytes
