"""Compressor registry — the extension point new scenarios plug into.

``make_compressor("topk", density=0.3)`` builds from a name;
``register("my-comp", MyCompressor)`` adds an entry (DP-noised, per-client
budgeted, ... compressors register here without touching consumers).
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.compress.compressors import (
    Compose, Compressor, Identity, Int8Sync, QuantQr, TopK)

_REGISTRY: Dict[str, Callable[..., Compressor]] = {}


def register(name: str, ctor: Callable[..., Compressor],
             *, overwrite: bool = False) -> None:
    key = name.lower()
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"compressor {name!r} already registered")
    _REGISTRY[key] = ctor


def available() -> list[str]:
    return sorted(_REGISTRY)


def make_compressor(name: str, **kwargs) -> Compressor:
    """Factory: ``make_compressor("topk", density=0.3)``."""
    try:
        ctor = _REGISTRY[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown compressor {name!r}; have {available()}") from None
    return ctor(**kwargs)


for _name, _ctor in [
    ("identity", Identity),
    ("none", Identity),
    ("topk", TopK),
    ("quant", QuantQr),
    ("qr", QuantQr),
    ("topk+quant", Compose),
    ("double", Compose),
    ("int8", Int8Sync),
    ("int8-sync", Int8Sync),
]:
    register(_name, _ctor)
