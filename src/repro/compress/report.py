"""Exact wire-cost accounting for compressed payloads (DESIGN.md §3).

A :class:`BitsReport` is returned by every ``Compressor.compress`` call and
states the bits needed to transmit *that payload* — computed in-graph from
the actual compressed tree (nnz from the TopK mask, per-tensor norms for
Q_r), not estimated host-side from the dense model.  It is a registered
pytree, so reports flow through ``jit`` / ``vmap`` / ``lax.scan`` unchanged:
a vmapped compress yields a report whose leaves carry the client axis, and
``reduce_sum`` collapses it back to per-link totals.

The three buckets mirror the paper's accounting (§3.1 / comm.py):

* ``value_bits`` — the numeric payload (fp32 values, sign+level codes, int8
  levels);
* ``index_bits`` — coordinate indices for sparse (value, index) encodings;
* ``meta_bits``  — side information: per-tensor norms / scales.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Union

import jax
import jax.numpy as jnp

Scalar = Union[float, jax.Array]

FLOAT_BITS = 32  # uncompressed fp32 scalar payload, as accounted in the paper
INDEX_BITS = 32  # index payload for sparse (value, index) encoding


def leaf_value_bits(x: Any) -> int:
    """Wire bits of one raw scalar of ``x``'s dtype (bf16 -> 16, fp32 -> 32).

    Dense and TopK payloads transmit values at the leaf's own width; the
    fp32 default is :data:`FLOAT_BITS`.  Accepts anything with a ``dtype``
    (arrays and ShapeDtypeStructs alike).
    """
    return jnp.dtype(x.dtype).itemsize * 8


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitsReport:
    value_bits: Scalar = 0.0
    index_bits: Scalar = 0.0
    meta_bits: Scalar = 0.0

    # -- pytree protocol ------------------------------------------------- #

    def tree_flatten(self):
        return (self.value_bits, self.index_bits, self.meta_bits), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- arithmetic ------------------------------------------------------ #

    @property
    def total_bits(self) -> Scalar:
        return self.value_bits + self.index_bits + self.meta_bits

    def __add__(self, other) -> "BitsReport":
        if isinstance(other, (int, float)) and other == 0:
            return self                      # so built-in sum() works
        if not isinstance(other, BitsReport):
            return NotImplemented
        return BitsReport(self.value_bits + other.value_bits,
                          self.index_bits + other.index_bits,
                          self.meta_bits + other.meta_bits)

    __radd__ = __add__

    def scale(self, factor: Scalar) -> "BitsReport":
        """Report for ``factor`` identical transmissions (e.g. a broadcast)."""
        return BitsReport(self.value_bits * factor,
                          self.index_bits * factor,
                          self.meta_bits * factor)

    def reduce_sum(self) -> "BitsReport":
        """Collapse batched leaves (e.g. a vmapped client axis) to totals."""
        return BitsReport(*(jnp.sum(jnp.asarray(c)) for c in (
            self.value_bits, self.index_bits, self.meta_bits)))

    def as_floats(self) -> "BitsReport":
        """Host-side snapshot (forces device sync)."""
        return BitsReport(float(self.value_bits), float(self.index_bits),
                          float(self.meta_bits))


def zero_report() -> BitsReport:
    return BitsReport(jnp.zeros(()), jnp.zeros(()), jnp.zeros(()))


def dense_report(tree: Any) -> BitsReport:
    """Bits to send ``tree`` uncompressed: the leaf dtype's width per
    scalar (``leaf_value_bits`` — 32 for fp32, 16 for bf16)."""
    return BitsReport(value_bits=float(
        sum(x.size * leaf_value_bits(x)
            for x in jax.tree_util.tree_leaves(tree))))


def dense_bits(tree: Any) -> float:
    """Host-side scalar shortcut for ``dense_report(tree).total_bits``."""
    return float(sum(x.size * leaf_value_bits(x)
                     for x in jax.tree_util.tree_leaves(tree)))
