"""Compression operators (paper §3.1) with exact in-graph bit accounting.

This is the single compression implementation in the repo (DESIGN.md §3);
``core``, ``launch`` and ``benchmarks`` all consume it.  Semantics follow
the paper:

* ``TopK`` (Definition 3.1) — keep the ``density`` fraction of
  largest-magnitude entries, zero the rest.  Biased.  Two threshold
  finders: ``impl="select"`` (exact k-th magnitude via the radix-select /
  ``lax.top_k`` path in :mod:`repro.kernels`) and ``impl="quantile"``
  (``jnp.quantile`` on |x| — the billion-parameter launch path, identical
  threshold semantics, approximate k).
* ``QuantQr`` (Definition 3.2) — QSGD-style binary quantization with ``r``
  bits: x -> ||x||_2 * sgn(x_i) * xi_i.  Unbiased.
* ``Compose`` (Appendix B.3) — TopK then quantization of the survivors
  ("double compression").
* ``Identity`` — no-op; FedComLoc with Identity is exactly Scaffnew.
* ``Int8Sync`` — the sharding-aware launch-layer entry: ``encode`` emits an
  int8 level payload + per-tensor scales so a cross-pod collective moves
  one byte per scalar on the wire (see launch/fed_train.py).

``compress(tree, rng) -> (compressed_tree, BitsReport)``: the report is
computed **from the payload actually produced** — nnz counted from the TopK
mask (so error-feedback innovations and per-client-varying sparsity are
accounted exactly), per-tensor norm/scale overheads for quantizers,
composition-aware for double compression.  ``expected_bits`` gives the
host-side planning estimate (the paper's closed-form formulas).

Two granularities: ``scope="tensor"`` (default; per-leaf TopK / norms —
what practical FL systems do) and ``scope="global"`` (flatten the pytree
first, matching Definition 3.1 over x in R^d exactly).

Hot inner ops route through :mod:`repro.kernels.ops`, which dispatches to
the Pallas TPU kernels on TPU and the jnp reference elsewhere.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.report import (
    FLOAT_BITS, INDEX_BITS, BitsReport, dense_bits, dense_report,
    leaf_value_bits)
from repro.kernels import ops as kops

PyTree = Any


def _tree_size(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def _nnz(tree: PyTree) -> jax.Array:
    """In-graph nonzero count over all leaves (the transmitted support)."""
    return sum(jnp.sum(x != 0).astype(jnp.float32)
               for x in jax.tree_util.tree_leaves(tree))


def _sparse_report(out: PyTree) -> BitsReport:
    """(value + index) bits of a sparse payload, per leaf and in-graph:
    each kept coordinate costs the leaf dtype's width (bf16 values ship 16
    bits, fp32 ship 32) plus INDEX_BITS, nnz counted from the actual mask."""
    vb = ib = 0.0
    for x in jax.tree_util.tree_leaves(out):
        nnz = jnp.sum(x != 0).astype(jnp.float32)
        vb = vb + nnz * leaf_value_bits(x)
        ib = ib + nnz * INDEX_BITS
    return BitsReport(value_bits=vb, index_bits=ib)


def _map_flat_global(tree: PyTree, fn) -> PyTree:
    """Apply ``fn`` to the concatenation of all leaves, then re-split."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    out = fn(flat)
    parts, off = [], 0
    for l in leaves:
        parts.append(out[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree_util.tree_unflatten(treedef, parts)


class Compressor:
    """Base class.  Subclasses implement ``compress`` and ``expected_bits``.

    ``compress(tree, rng, **overrides) -> (compressed_tree, BitsReport)``
    with the report computed in-graph from the actual payload; ``apply``
    discards the report (for call sites like FedComLoc-Local where nothing
    hits the wire).

    ``overrides`` are per-call parameter overrides (DESIGN.md §5): operators
    that support them (``TopK.density``, ``QuantQr.r``, ``Compose`` forwards
    both) accept *traced* scalars, so a ``vmap`` over clients with a
    parameter array batches the compression with per-client settings while
    the ``BitsReport`` still counts each client's actual payload.
    ``param_overrides()`` names the keys an operator accepts, letting
    schedulers route a profile's arrays without knowing the operator type.
    """

    #: True if E[C(x)] = x.
    unbiased: bool = False

    def compress(self, tree: PyTree,
                 rng: Optional[jax.Array] = None,
                 **overrides) -> Tuple[PyTree, BitsReport]:
        raise NotImplementedError

    def apply(self, tree: PyTree, rng: Optional[jax.Array] = None,
              **overrides) -> PyTree:
        return self.compress(tree, rng, **overrides)[0]

    def param_overrides(self) -> Tuple[str, ...]:
        """Override keys ``compress`` accepts as traced per-call values."""
        return ()

    def validate_override(self, name: str, values) -> None:
        """Host-side range check for override *values* (traced overrides
        bypass ``__post_init__``); schedulers call this once at build time."""

    def expected_bits(self, tree: PyTree) -> float:
        """Host-side closed-form estimate of ``compress(tree)`` bits."""
        raise NotImplementedError

    def __call__(self, tree: PyTree,
                 rng: Optional[jax.Array] = None,
                 **overrides) -> Tuple[PyTree, BitsReport]:
        return self.compress(tree, rng, **overrides)


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    unbiased = True

    def compress(self, tree: PyTree, rng=None):
        return tree, dense_report(tree)

    def expected_bits(self, tree: PyTree) -> float:
        return dense_bits(tree)


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Keep the ``density`` fraction of largest-|.| entries (Def. 3.1).

    Bits: (leaf dtype width + INDEX_BITS) per coordinate of the *actual*
    support — counted in-graph from the mask, so ties kept by threshold
    semantics and already-zero inputs (error-feedback innovations) are
    accounted exactly, and bf16 leaves ship 16-bit values (fp32 the
    FLOAT_BITS default).  At ``density >= 1`` the payload is dense and no
    indices are sent.
    """

    density: float = 0.1
    scope: str = "tensor"      # "tensor" | "global"
    impl: str = "select"       # "select" (exact k-th) | "quantile"

    def __post_init__(self):
        if not (0.0 < self.density <= 1.0):
            raise ValueError(f"density must be in (0, 1], got {self.density}")
        if self.scope not in ("tensor", "global"):
            raise ValueError(f"unknown scope {self.scope!r}")
        if self.impl not in ("select", "quantile"):
            raise ValueError(f"unknown impl {self.impl!r}")

    def _k(self, size: int) -> int:
        return max(1, min(size, int(round(self.density * size))))

    def _mask_one(self, x: jax.Array) -> jax.Array:
        if self.impl == "quantile":
            mag = jnp.abs(x.astype(jnp.float32))
            thr = jnp.quantile(mag.reshape(-1), 1.0 - self.density)
            return jnp.where(mag >= thr, x, jnp.zeros_like(x))
        return (kops.topk_mask(x.reshape(-1), self._k(x.size))
                .reshape(x.shape).astype(x.dtype))

    def _mask_one_dyn(self, x: jax.Array, d: jax.Array) -> jax.Array:
        """Threshold with a traced density (per-client values under vmap)."""
        if self.impl == "quantile":
            mag = jnp.abs(x.astype(jnp.float32))
            thr = jnp.quantile(mag.reshape(-1), jnp.clip(1.0 - d, 0.0, 1.0))
            return jnp.where(mag >= thr, x, jnp.zeros_like(x))
        k = jnp.round(d * x.size).astype(jnp.int32)
        return (kops.topk_mask(x.reshape(-1), k)
                .reshape(x.shape).astype(x.dtype))

    def param_overrides(self):
        return ("density",)

    def validate_override(self, name, values):
        if name == "density":
            v = np.asarray(values)
            if not ((v > 0.0) & (v <= 1.0)).all():
                raise ValueError(
                    f"density override values must be in (0, 1], got "
                    f"range [{v.min()}, {v.max()}]")

    def compress(self, tree: PyTree, rng=None, *, density=None):
        if density is None:
            if self.density >= 1.0:
                return tree, dense_report(tree)
            if self.scope == "global":
                out = _map_flat_global(tree, self._mask_one)
            else:
                out = jax.tree_util.tree_map(self._mask_one, tree)
            return out, _sparse_report(out)
        # Traced density (DESIGN.md §5): same threshold semantics, but the
        # k / quantile is a traced function of ``density``, so one vmapped
        # compress batches per-client settings.  Bits stay exact per call:
        # nnz from the actual mask; at density >= 1 the payload is dense and
        # the index bits vanish in-graph.
        d = jnp.asarray(density, jnp.float32)
        mask = lambda x: self._mask_one_dyn(x, d)
        if self.scope == "global":
            out = _map_flat_global(tree, mask)
        else:
            out = jax.tree_util.tree_map(mask, tree)
        sparse = _sparse_report(out)
        return out, BitsReport(
            value_bits=jnp.where(d >= 1.0, dense_bits(tree),
                                 sparse.value_bits),
            index_bits=jnp.where(d >= 1.0, 0.0, sparse.index_bits))

    def expected_bits(self, tree: PyTree) -> float:
        if self.density >= 1.0:
            return dense_bits(tree)
        if self.scope == "global":
            # where the k survivors land is data-dependent; estimate value
            # width with the size-weighted mean leaf width (exact for
            # single-dtype trees)
            n = _tree_size(tree)
            avg_vb = dense_bits(tree) / n
            return float(self._k(n)) * (avg_vb + INDEX_BITS)
        return float(sum(self._k(x.size) * (leaf_value_bits(x) + INDEX_BITS)
                         for x in jax.tree_util.tree_leaves(tree)))


@dataclasses.dataclass(frozen=True)
class QuantQr(Compressor):
    """QSGD binary quantization with ``r`` bits (Def. 3.2).  Unbiased.

    Bits: sign + r-bit level per scalar, plus one fp32 norm per tensor
    (``scope="tensor"``) or one overall (``scope="global"``).
    """

    r: int = 8
    scope: str = "tensor"

    unbiased = True

    def __post_init__(self):
        if self.r <= 0:
            raise ValueError("r must be positive")

    def param_overrides(self):
        return ("r",)

    def validate_override(self, name, values):
        if name == "r":
            v = np.asarray(values)
            if not np.issubdtype(v.dtype, np.integer) or not (v >= 1).all():
                raise ValueError(
                    f"r override values must be integers >= 1, got dtype "
                    f"{v.dtype}, min {v.min()}")

    def compress(self, tree: PyTree, rng: Optional[jax.Array] = None, *,
                 r=None):
        if rng is None:
            raise ValueError("QuantQr requires an rng key (stochastic rounding)")
        # ``r`` may be a traced scalar (per-client bit widths under vmap);
        # the jnp quantizer keeps 2**r in-graph and the (1+r)·n payload
        # formula is exact either way.
        rr = self.r if r is None else r
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(rng, len(leaves))
        if self.scope == "global":
            out = _map_flat_global(
                tree, lambda flat: kops.quantize_qr(flat, rr, keys[0]))
            n_norms = 1
        else:
            new = [kops.quantize_qr(l.reshape(-1), rr, k)
                   .reshape(l.shape).astype(l.dtype)
                   for l, k in zip(leaves, keys)]
            out = jax.tree_util.tree_unflatten(treedef, new)
            n_norms = len(leaves)
        n = _tree_size(tree)
        return out, BitsReport(
            value_bits=jnp.asarray(float(n) * (1 + rr), jnp.float32),
            meta_bits=jnp.asarray(float(n_norms) * FLOAT_BITS))

    def expected_bits(self, tree: PyTree) -> float:
        n_norms = (1 if self.scope == "global"
                   else len(jax.tree_util.tree_leaves(tree)))
        return (float(_tree_size(tree)) * (1 + self.r)
                + n_norms * FLOAT_BITS)


@dataclasses.dataclass(frozen=True)
class Compose(Compressor):
    """Apply ``first`` then ``second`` (paper Appendix B.3: TopK -> Q_r).

    For the sparsifier -> quantizer composition the report is exact and
    support-aware: nnz indices + (1 + r) bits per *kept* coordinate + the
    quantizer's norm overhead.  Other compositions fall back to the second
    stage's (dense-size) report plus any first-stage index bits — correct
    but conservative.
    """

    first: Compressor = dataclasses.field(default_factory=lambda: TopK(0.25))
    second: Compressor = dataclasses.field(default_factory=lambda: QuantQr(4))

    def param_overrides(self):
        return tuple(self.first.param_overrides()
                     + self.second.param_overrides())

    def validate_override(self, name, values):
        if name in self.first.param_overrides():
            self.first.validate_override(name, values)
        if name in self.second.param_overrides():
            self.second.validate_override(name, values)

    def compress(self, tree: PyTree, rng: Optional[jax.Array] = None,
                 **overrides):
        if rng is not None:
            k1, k2 = jax.random.split(rng)
        else:
            k1 = k2 = None
        ov1 = {k: v for k, v in overrides.items()
               if k in self.first.param_overrides()}
        ov2 = {k: v for k, v in overrides.items()
               if k in self.second.param_overrides()}
        unknown = set(overrides) - set(ov1) - set(ov2)
        if unknown:
            raise TypeError(f"unknown override(s) {sorted(unknown)} for "
                            f"{type(self.first).__name__}->"
                            f"{type(self.second).__name__}")
        mid, rep1 = self.first.compress(tree, k1, **ov1)
        out, rep2 = self.second.compress(mid, k2, **ov2)
        if isinstance(self.first, TopK) and isinstance(self.second, QuantQr):
            # The transmitted support is fixed by the sparsifier; count the
            # quantized payload over that support only.  With a traced
            # density the dense case (density >= 1) is gated in-graph —
            # then the payload is the quantizer's dense report.
            d = overrides.get("density", self.first.density)
            rr = overrides.get("r", self.second.r)
            nnz = rep1.index_bits / INDEX_BITS
            rep = BitsReport(
                value_bits=jnp.where(jnp.asarray(d) >= 1.0, rep2.value_bits,
                                     nnz * (1 + rr)),
                index_bits=rep1.index_bits,
                meta_bits=rep2.meta_bits)
        else:
            rep = BitsReport(value_bits=rep2.value_bits,
                             index_bits=rep1.index_bits + rep2.index_bits,
                             meta_bits=rep2.meta_bits)
        return out, rep

    def expected_bits(self, tree: PyTree) -> float:
        if (isinstance(self.first, TopK) and isinstance(self.second, QuantQr)
                and self.first.density < 1.0):
            if self.first.scope == "global":
                k = self.first._k(_tree_size(tree))
                return float(k) * (INDEX_BITS + 1 + self.second.r) + FLOAT_BITS
            total = 0.0
            for x in jax.tree_util.tree_leaves(tree):
                k = self.first._k(x.size)
                total += k * (INDEX_BITS + 1 + self.second.r) + FLOAT_BITS
            return total
        return min(self.first.expected_bits(tree),
                   self.second.expected_bits(tree))


@dataclasses.dataclass(frozen=True)
class Int8Sync(Compressor):
    """Sharding-aware int8 payload codec (launch/fed_train sync_mode).

    ``encode`` emits (int8 level*sign payload, per-tensor fp32 scale) so a
    cross-pod collective moves one byte per scalar on the wire; ``decode``
    dequantizes.  ``compress`` = decode(encode(.)) for simulator use.  The
    rounding is the same unbiased Q_r scheme with ``magnitude_bits`` level
    bits (<= 7 so level * sign fits int8).

    Bits: 8 per scalar payload + one fp32 scale per tensor.
    """

    magnitude_bits: int = 7

    unbiased = True

    def __post_init__(self):
        if not (0 < self.magnitude_bits <= 7):
            raise ValueError("magnitude_bits must be in [1, 7] to fit int8")

    def encode(self, tree: PyTree, rng: jax.Array):
        levels = float(2 ** self.magnitude_bits)
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(rng, len(leaves))
        payload, scales = [], []
        for leaf, k in zip(leaves, keys):
            xf = leaf.astype(jnp.float32)
            norm = jnp.sqrt(jnp.sum(xf * xf))
            safe = jnp.where(norm > 0, norm, 1.0)
            y = jnp.abs(xf) / safe
            lo = jnp.floor(levels * y)
            frac = levels * y - lo
            u = jax.random.uniform(k, leaf.shape, jnp.float32)
            q = (lo + (u < frac)) * jnp.sign(xf)
            payload.append(jnp.clip(q, -127, 127).astype(jnp.int8))
            scales.append(norm / levels)
        return (jax.tree_util.tree_unflatten(treedef, payload),
                jax.tree_util.tree_unflatten(treedef, scales))

    def decode(self, payload: PyTree, scales: PyTree,
               dtype_like: Optional[PyTree] = None) -> PyTree:
        ref = dtype_like if dtype_like is not None else payload
        return jax.tree_util.tree_map(
            lambda q, s, r_: (q.astype(jnp.float32) * s).astype(
                r_.dtype if hasattr(r_, "dtype") else jnp.float32),
            payload, scales, ref)

    def report(self, tree: PyTree) -> BitsReport:
        n = _tree_size(tree)
        n_scales = len(jax.tree_util.tree_leaves(tree))
        return BitsReport(value_bits=jnp.asarray(float(n) * 8.0),
                          meta_bits=jnp.asarray(float(n_scales) * FLOAT_BITS))

    def compress(self, tree: PyTree, rng: Optional[jax.Array] = None):
        if rng is None:
            raise ValueError("Int8Sync requires an rng key (stochastic rounding)")
        payload, scales = self.encode(tree, rng)
        return self.decode(payload, scales, tree), self.report(tree)

    def expected_bits(self, tree: PyTree) -> float:
        n_scales = len(jax.tree_util.tree_leaves(tree))
        return float(_tree_size(tree)) * 8.0 + n_scales * FLOAT_BITS
