"""Unified compression subsystem (DESIGN.md §3).

The single home for every compression operator in the repo and for the
exact, in-graph bit accounting behind the paper's communicated-bits axes:

    comp = make_compressor("topk", density=0.1)
    compressed, report = comp.compress(tree, rng)   # report: BitsReport
    total = report.total_bits                       # jnp scalar, in-graph

``core`` (FedComLoc / baselines), ``launch`` (multi-pod fed_train) and
``benchmarks`` all import from here; kernels dispatch (Pallas on TPU, jnp
reference elsewhere) happens underneath via :mod:`repro.kernels.ops`.
"""

from repro.compress.compressors import (
    Compose, Compressor, Identity, Int8Sync, QuantQr, TopK)
from repro.compress.registry import available, make_compressor, register
from repro.compress.report import (
    FLOAT_BITS, INDEX_BITS, BitsReport, dense_bits, dense_report,
    leaf_value_bits, zero_report)
from repro.compress import wire

__all__ = [
    "BitsReport", "Compose", "Compressor", "FLOAT_BITS", "INDEX_BITS",
    "Identity", "Int8Sync", "QuantQr", "TopK", "available", "dense_bits",
    "dense_report", "leaf_value_bits", "make_compressor", "register",
    "wire", "zero_report",
]
