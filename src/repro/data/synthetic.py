"""Synthetic structured datasets (offline stand-ins for MNIST / CIFAR10).

The container has no dataset downloads, so FedMNIST / FedCIFAR10 are replaced
by *learnable* synthetic sets with the same shapes and class counts:

* each class c gets an anchor in a latent space; samples are
  anchor + noise, pushed through a fixed random nonlinear "renderer" into
  the image space (784 flat for mnist-like, 32x32x3 for cifar-like);
* "cifar-like" uses a lower signal-to-noise ratio and a deeper renderer so a
  linear model cannot saturate it — mirroring the MLP-easy / CNN-hard gap
  between MNIST and CIFAR10.

Class structure + Dirichlet partitioning reproduce the paper's heterogeneity
mechanics exactly; absolute accuracies differ from the paper's
(EXPERIMENTS.md reports trends against these baselines).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dataset:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    n_classes: int

    @property
    def input_shape(self):
        return self.x_train.shape[1:]


def _render(z: np.ndarray, rng: np.random.Generator, out_dim: int,
            depth: int) -> np.ndarray:
    h = z
    for _ in range(depth):
        w = rng.normal(size=(h.shape[1], h.shape[1])) / np.sqrt(h.shape[1])
        h = np.tanh(h @ w)
    w_out = rng.normal(size=(h.shape[1], out_dim)) / np.sqrt(h.shape[1])
    return h @ w_out


def make_mnist_like(n_train: int = 60_000, n_test: int = 10_000,
                    seed: int = 0, noise: float = 0.35) -> Dataset:
    """10-class, 784-dim, high SNR — an MLP should reach >0.9 accuracy."""
    return _make(n_train, n_test, seed, latent=32, out_dim=784,
                 depth=1, noise=noise, n_classes=10, image=False)


def make_cifar_like(n_train: int = 50_000, n_test: int = 10_000,
                    seed: int = 1, noise: float = 0.9) -> Dataset:
    """10-class, 32x32x3, low SNR + deeper renderer — harder task."""
    return _make(n_train, n_test, seed, latent=48, out_dim=32 * 32 * 3,
                 depth=3, noise=noise, n_classes=10, image=True)


def _make(n_train, n_test, seed, *, latent, out_dim, depth, noise,
          n_classes, image) -> Dataset:
    rng = np.random.default_rng(seed)
    anchors = rng.normal(size=(n_classes, latent))
    anchors *= 2.0 / np.linalg.norm(anchors, axis=1, keepdims=True)

    def sample(n, rng_):
        y = rng_.integers(0, n_classes, size=n)
        z = anchors[y] + noise * rng_.normal(size=(n, latent))
        return z, y

    n_total = n_train + n_test
    z, y = sample(n_total, rng)
    render_rng = np.random.default_rng(seed + 1)
    x = _render(z, render_rng, out_dim, depth).astype(np.float32)
    x = (x - x.mean(axis=0)) / (x.std(axis=0) + 1e-6)
    if image:
        x = x.reshape(-1, 32, 32, 3)
    return Dataset(
        x_train=x[:n_train], y_train=y[:n_train].astype(np.int32),
        x_test=x[n_train:], y_test=y[n_train:].astype(np.int32),
        n_classes=n_classes)


def make_lm_tokens(vocab: int, n_seqs: int, seq_len: int,
                   seed: int = 0) -> np.ndarray:
    """Synthetic token streams with Markov structure for LM training demos."""
    rng = np.random.default_rng(seed)
    # sparse bigram transition structure: each token prefers a few successors
    n_next = 8
    succ = rng.integers(0, vocab, size=(vocab, n_next))
    out = np.empty((n_seqs, seq_len), dtype=np.int32)
    tok = rng.integers(0, vocab, size=n_seqs)
    for t in range(seq_len):
        out[:, t] = tok
        explore = rng.random(n_seqs) < 0.1
        nxt = succ[tok, rng.integers(0, n_next, size=n_seqs)]
        tok = np.where(explore, rng.integers(0, vocab, size=n_seqs), nxt)
    return out
