"""Dirichlet(alpha) heterogeneous partitioning (paper §4, Appendix B.1).

Each client draws a class-preference vector from Dir(alpha); labels/images
are assigned per those preferences until all data is distributed — lower
alpha = more heterogeneous shards (alpha -> 0: single-class clients;
alpha -> inf: IID).  Mirrors the FedLab partitioner the paper uses.
"""

from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 1) -> list[np.ndarray]:
    """Return per-client global-index lists."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    by_class = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for idx in by_class:
        rng.shuffle(idx)

    while True:
        parts: list[list[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx = by_class[c]
            props = rng.dirichlet(np.full(n_clients, alpha))
            # split this class's samples proportionally
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for i, chunk in enumerate(np.split(idx, cuts)):
                parts[i].extend(chunk.tolist())
        sizes = np.array([len(p) for p in parts])
        if sizes.min() >= min_size:
            break
        seed += 1
        rng = np.random.default_rng(seed)
    return [np.asarray(sorted(p), dtype=np.int64) for p in parts]


def partition_stats(parts: list[np.ndarray], labels: np.ndarray) -> dict:
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    mat = np.zeros((len(parts), n_classes), dtype=np.int64)
    for i, p in enumerate(parts):
        for c in range(n_classes):
            mat[i, c] = int((labels[p] == c).sum())
    return {
        "sizes": mat.sum(axis=1).tolist(),
        "class_matrix": mat.tolist(),
        "max_class_share": float((mat.max(axis=1) / np.maximum(
            mat.sum(axis=1), 1)).mean()),
    }
