"""Minimal pure-pytree optimizers (no external deps).

``make(name, lr, **kw) -> (init_fn, update_fn)`` with
``update_fn(grads, opt_state, params) -> (new_params, new_opt_state)``.

* ``sgd``      — stateless; the choice for the 400B MoE (no optimizer
  memory; FedComLoc's local steps are plain SGD corrected by the control
  variate anyway).
* ``momentum`` — bf16 momentum buffer.
* ``adam``     — fp32 m/v; for the <=10B architectures.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
OptPair = Tuple[Callable, Callable]


def _tmap(f, *ts):
    return jax.tree_util.tree_map(f, *ts)


def sgd(lr: float) -> OptPair:
    def init(params):
        return ()

    def update(grads, state, params):
        new = _tmap(lambda p, g: (p - lr * g.astype(jnp.float32)
                                  ).astype(p.dtype), params, grads)
        return new, state

    return init, update


def momentum(lr: float, beta: float = 0.9) -> OptPair:
    def init(params):
        return {"m": _tmap(lambda p: jnp.zeros_like(p), params)}

    def update(grads, state, params):
        m = _tmap(lambda m_, g: beta * m_ + g.astype(m_.dtype),
                  state["m"], grads)
        new = _tmap(lambda p, m_: (p - lr * m_.astype(jnp.float32)
                                   ).astype(p.dtype), params, m)
        return new, {"m": m}

    return init, update


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> OptPair:
    def init(params):
        return {
            "m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2)
                  * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = _tmap(
            lambda p, m_, v_: (p - lr * (m_ / bc1)
                               / (jnp.sqrt(v_ / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return init, update


_REGISTRY = {"sgd": sgd, "momentum": momentum, "adam": adam}


def make(name: str, lr: float, **kw) -> OptPair:
    return _REGISTRY[name](lr, **kw)
