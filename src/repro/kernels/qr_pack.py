"""Pallas TPU kernel for fused Q_r quantize + bit-plane pack (DESIGN.md §8).

The ``qr`` wire codec ships one (1+r)-bit code per scalar: a sign bit plus
the quantizer's stochastic level.  PR 5 materialised the dense uint32 code
array and re-read it in a second pack pass; this kernel computes the codes
*and* packs them into uint32 words in one VMEM pass per (8, 128) block —
the dense code array never touches HBM, so the encode streams ~(1 + b/32)d
words instead of (2 + b/32)d.

Code arithmetic matches :func:`repro.kernels.ref.qr_codes_with_uniforms`
(same saturation: the top level ``2**r`` clamps to ``2**r - 1``); the word
layout matches :func:`repro.kernels.ref.pack_codes` bit-for-bit (codes
grouped 32 per lane-group, word ``j*b + t`` holding bit ``t`` of group
``j``'s codes).  Uniforms and the norm are computed outside and streamed
in, exactly like :mod:`repro.kernels.quantize` — same rng chain, and the
norm can come from the sum-of-squares kernel so transform and wire agree
bit-for-bit on every backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 8
_BLOCK_COLS = 128
_BLOCK = _BLOCK_ROWS * _BLOCK_COLS
_GROUPS = _BLOCK_COLS // 32      # lane-groups of 32 per sublane row


def _qr_pack_kernel(x_ref, u_ref, norm_ref, out_ref, *, levels: float, b: int):
    x = x_ref[...]                                       # (8, 128) float32
    norm = norm_ref[0, 0]
    y = jnp.abs(x) / jnp.where(norm > 0, norm, 1.0)
    scaled = levels * y
    lo = jnp.floor(scaled)
    code = lo + (u_ref[...] < scaled - lo).astype(jnp.float32)
    code = jnp.minimum(code, levels - 1.0)               # saturate top level
    c = code.astype(jnp.uint32) | jnp.where(
        x < 0, jnp.uint32(levels), jnp.uint32(0))        # sign bit << r
    lane = jax.lax.broadcasted_iota(jnp.uint32, (_BLOCK_ROWS, 32), 1)
    cols = []
    for g in range(_GROUPS):
        seg = c[:, g * 32:(g + 1) * 32]                  # (8, 32)
        for t in range(b):
            bits = ((seg >> jnp.uint32(t)) & jnp.uint32(1)) << lane
            cols.append(jnp.sum(bits, axis=1))           # (8,)
    out_ref[...] = jnp.stack(cols, axis=1)               # (8, 4*b)


def _pad_to_block(x: jax.Array):
    n = x.size
    padded = pl.cdiv(n, _BLOCK) * _BLOCK
    return jnp.pad(x, (0, padded - n)).reshape(-1, _BLOCK_COLS)


@functools.partial(jax.jit, static_argnames=("r", "interpret"))
def quantize_pack_with_uniforms(x: jax.Array, r: int, u: jax.Array,
                                norm: jax.Array, *,
                                interpret: bool = False) -> jax.Array:
    """Packed (1+r)-bit Q_r codes of the 1-D vector ``x``: fused quantize +
    bit-plane pack, ``ceil(n/32) * (1+r)`` uint32 words.

    Bit-identical to ``ref.pack_codes(ref.qr_codes_with_uniforms(x, r, u,
    norm), 1 + r)`` for the same uniforms and norm (padding codes are 0 in
    both: padded x and u are 0, so floor + bernoulli lands on level 0).
    """
    if x.ndim != 1:
        raise ValueError(f"expects 1-D input, got {x.shape}")
    r = int(r)
    b = 1 + r
    n = x.size
    n32 = pl.cdiv(n, 32)
    x2d = _pad_to_block(x.astype(jnp.float32))
    u2d = _pad_to_block(u.astype(jnp.float32))
    rows = x2d.shape[0]
    words2d = pl.pallas_call(
        functools.partial(_qr_pack_kernel, levels=float(2 ** r), b=b),
        grid=(rows // _BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i: (i, 0)),
                  pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, _GROUPS * b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _GROUPS * b), jnp.uint32),
        interpret=interpret,
    )(x2d, u2d, jnp.asarray(norm, jnp.float32).reshape(1, 1))
    return words2d.reshape(-1)[: n32 * b]
