"""Pallas TPU kernels for fused select -> slot compaction (DESIGN.md §8).

The wire codec's sparse payload is a static-capacity array of
``(uint32 index, value)`` slots.  PR 5 built it from the TopK transform
output with an n-sized cumsum + ``searchsorted`` + gathers; the kernels
here emit the slots directly from the threshold in one streaming pass:

  1. the k-th-magnitude threshold ``t`` comes from the radix walk
     (:func:`repro.kernels.topk_compress.threshold_bits`) — 4 histogram
     passes, shared with the TopK transform path;
  2. a single compaction pass tiles x through VMEM; each (8, 128) block
     computes its survivors' block-local prefix sum (two triangular-matrix
     dots: inclusive lane prefix per sublane row, then row offsets), adds
     the running survivor count carried across the sequential grid, and
     one-hot accumulates ``(index, payload)`` into the revisited
     ``(1, cap_pad)`` output slabs.

Survivors are assigned slots in index order and the carried count is
monotone, so tie overflow beyond ``cap`` keeps the lowest-index ``cap`` —
exactly the searchsorted semantics.  Empty slots keep their sentinel-``n``
init (index) and 0 (payload).

Two payload flavours share the machinery: ``compact_slots`` carries the
values themselves (the ``topk`` codec), ``compact_code_slots`` fuses the
Q_r code computation (sign + stochastic level, saturated) into the block
body and compacts the *codes* (the ``topk_qr`` codec), so the dense code
array never exists — survivors leave VMEM already quantized.

Counts and prefix sums accumulate in float32 (exact below 2^24, the same
envelope as the histogram kernel); one ``cap_pad``-wide one-hot per
sublane row bounds the block temporaries to ``128 * cap_pad`` lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 8
_BLOCK_COLS = 128
_BLOCK = _BLOCK_ROWS * _BLOCK_COLS


def _pad_to_block(x: jax.Array):
    n = x.size
    padded = pl.cdiv(n, _BLOCK) * _BLOCK
    return jnp.pad(x, (0, padded - n)).reshape(-1, _BLOCK_COLS)


def _block_spec():
    return pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i: (i, 0))


_SCALAR_SPEC = pl.BlockSpec((1, 1), lambda i: (0, 0))


def _block_positions(keep):
    """Global slot position assignment for a block's survivors.

    Returns the (8, 128) inclusive prefix sum of ``keep`` in row-major
    order, as float32.  Two MXU-friendly triangular dots instead of an
    in-kernel cumsum: lane-prefix within each sublane row, then each row
    offset by the full rows above it.
    """
    kf = keep.astype(jnp.float32)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (_BLOCK_COLS, _BLOCK_COLS), 0)
           <= jax.lax.broadcasted_iota(
               jnp.int32, (_BLOCK_COLS, _BLOCK_COLS), 1)).astype(jnp.float32)
    row_incl = jax.lax.dot(kf, tri)                    # (8, 128) lane prefix
    row_tot = row_incl[:, _BLOCK_COLS - 1:]            # (8, 1) row sums
    strict = (jax.lax.broadcasted_iota(jnp.int32, (_BLOCK_ROWS, _BLOCK_ROWS), 1)
              < jax.lax.broadcasted_iota(
                  jnp.int32, (_BLOCK_ROWS, _BLOCK_ROWS), 0)).astype(jnp.float32)
    row_off = jax.lax.dot(strict, row_tot)             # (8, 1) rows above
    return row_incl + row_off


def _scatter_rows(pos, keep, gidx, payload, idx_ref, pay_ref, *,
                  n: int, cap_pad: int):
    """One-hot accumulate (index, payload) into the revisited output slabs,
    one sublane row at a time to bound the (128, cap_pad) temporaries."""
    slot = jax.lax.broadcasted_iota(jnp.int32, (_BLOCK_COLS, cap_pad), 1)
    for rr in range(_BLOCK_ROWS):
        hit = (pos[rr][:, None] == slot) & keep[rr][:, None]
        # sentinel-n init + (g - n) contribution = g for the filled slot
        idx_ref[...] += jnp.sum(
            jnp.where(hit, (gidx[rr] - n)[:, None].astype(jnp.float32), 0.0),
            axis=0, keepdims=True)
        pay_ref[...] += jnp.sum(
            jnp.where(hit, payload[rr][:, None], 0.0),
            axis=0, keepdims=True)


def _compact_kernel(bits_ref, pay_ref, valid_ref, thr_ref,
                    idx_ref, out_ref, cnt_ref, *, n: int, cap_pad: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        idx_ref[...] = jnp.full_like(idx_ref, float(n))
        out_ref[...] = jnp.zeros_like(out_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    bits = bits_ref[...]
    t = thr_ref[0, 0]
    keep = (bits >= t) & (bits != jnp.uint32(0)) & (valid_ref[...] != 0)
    base = cnt_ref[0, 0]
    pos = (base + _block_positions(keep) - 1.0).astype(jnp.int32)
    gidx = (step * _BLOCK
            + jax.lax.broadcasted_iota(jnp.int32, keep.shape, 0) * _BLOCK_COLS
            + jax.lax.broadcasted_iota(jnp.int32, keep.shape, 1))
    _scatter_rows(pos, keep, gidx, pay_ref[...], idx_ref, out_ref,
                  n=n, cap_pad=cap_pad)
    cnt_ref[0, 0] = base + jnp.sum(keep.astype(jnp.float32))


def _compact_code_kernel(bits_ref, x_ref, u_ref, valid_ref, thr_ref, norm_ref,
                         idx_ref, out_ref, cnt_ref, *,
                         levels: float, n: int, cap_pad: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        idx_ref[...] = jnp.full_like(idx_ref, float(n))
        out_ref[...] = jnp.zeros_like(out_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    bits = bits_ref[...]
    t = thr_ref[0, 0]
    valid = valid_ref[...] != 0
    mask = (bits >= t) & valid                   # the TopK-masked support
    keep = mask & (bits != jnp.uint32(0))        # minus already-zero entries
    # Q_r codes of the masked block (ref.qr_codes_with_uniforms arithmetic).
    x = jnp.where(mask, x_ref[...], 0.0)
    norm = norm_ref[0, 0]
    y = jnp.abs(x) / jnp.where(norm > 0, norm, 1.0)
    scaled = levels * y
    lo = jnp.floor(scaled)
    code = lo + (u_ref[...] < scaled - lo).astype(jnp.float32)
    code = jnp.minimum(code, levels - 1.0)       # saturate top level
    code = code + jnp.where(x < 0, levels, 0.0)  # sign bit << r
    base = cnt_ref[0, 0]
    pos = (base + _block_positions(keep) - 1.0).astype(jnp.int32)
    gidx = (step * _BLOCK
            + jax.lax.broadcasted_iota(jnp.int32, keep.shape, 0) * _BLOCK_COLS
            + jax.lax.broadcasted_iota(jnp.int32, keep.shape, 1))
    _scatter_rows(pos, keep, gidx, code, idx_ref, out_ref,
                  n=n, cap_pad=cap_pad)
    cnt_ref[0, 0] = base + jnp.sum(keep.astype(jnp.float32))


def _run_compact(kernel, operands, n: int, cap: int, interpret: bool):
    cap_pad = pl.cdiv(cap, _BLOCK_COLS) * _BLOCK_COLS
    grid = operands[0].shape[0] // _BLOCK_ROWS
    out_spec = pl.BlockSpec((1, cap_pad), lambda i: (0, 0))
    idx2d, pay2d, _ = pl.pallas_call(
        functools.partial(kernel, n=n, cap_pad=cap_pad),
        grid=(grid,),
        in_specs=[_SCALAR_SPEC if op.shape == (1, 1) else _block_spec()
                  for op in operands],
        out_specs=(out_spec, out_spec, _SCALAR_SPEC),
        out_shape=(jax.ShapeDtypeStruct((1, cap_pad), jnp.float32),
                   jax.ShapeDtypeStruct((1, cap_pad), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)),
        interpret=interpret,
    )(*operands)
    idx = idx2d.reshape(-1)[:cap].astype(jnp.int32)
    return idx, pay2d.reshape(-1)[:cap]


def _prep(x: jax.Array):
    n = x.size
    xf = x.astype(jnp.float32)
    bits2d = _pad_to_block(jnp.abs(xf).view(jnp.uint32))
    x2d = _pad_to_block(xf)
    rows = bits2d.shape[0]
    idx = (jax.lax.broadcasted_iota(jnp.int32, (rows, _BLOCK_COLS), 0)
           * _BLOCK_COLS
           + jax.lax.broadcasted_iota(jnp.int32, (rows, _BLOCK_COLS), 1))
    valid = (idx < n).astype(jnp.int32)
    return bits2d, x2d, valid


@functools.partial(jax.jit, static_argnames=("cap", "interpret"))
def compact_slots(x: jax.Array, thr: jax.Array, cap: int, *,
                  interpret: bool = False):
    """Slots of ``x``'s kept support given threshold bit pattern ``thr``.

    Returns ``(idx, vals)``: ``cap`` int32 indices (sentinel ``n``) and the
    float32 survivor values (0 in empty slots), lowest index first.
    """
    if x.ndim != 1:
        raise ValueError(f"expects 1-D input, got {x.shape}")
    bits2d, x2d, valid = _prep(x)
    return _run_compact(
        _compact_kernel,
        (bits2d, x2d, valid, thr.reshape(1, 1)),
        x.size, int(cap), interpret)


@functools.partial(jax.jit, static_argnames=("r", "cap", "interpret"))
def compact_code_slots(x: jax.Array, u: jax.Array, norm: jax.Array,
                       thr: jax.Array, r: int, cap: int, *,
                       interpret: bool = False):
    """Fused Q_r-code + compaction for the ``topk_qr`` codec.

    Returns ``(idx, codes)``: slot indices as above and the survivors'
    (1+r)-bit codes (uint32; 0 in empty slots), computed in-block from the
    masked values, uniforms ``u`` and the masked-vector ``norm``.
    """
    if x.ndim != 1:
        raise ValueError(f"expects 1-D input, got {x.shape}")
    bits2d, x2d, valid = _prep(x)
    u2d = _pad_to_block(u.astype(jnp.float32))
    idx, codes = _run_compact(
        functools.partial(_compact_code_kernel, levels=float(2 ** int(r))),
        (bits2d, x2d, u2d, valid, thr.reshape(1, 1),
         jnp.asarray(norm, jnp.float32).reshape(1, 1)),
        x.size, int(cap), interpret)
    return idx, codes.astype(jnp.uint32)
