"""Pallas TPU kernel for the RG-LRU recurrence (RecurrentGemma).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t      (elementwise over D)

Sequential scans are latency-bound on TPU; the kernel keeps the hidden state
resident in VMEM scratch and streams (x, a) time-blocks through VMEM:

* grid = (B, D/bd, T/bt) — the time axis is the last (sequential) grid axis,
  so the carried state h persists in scratch between time blocks;
* inside a block the bt-step recurrence runs as an unrolled fori_loop on
  VMEM-resident rows (bt x bd), amortising HBM traffic over bt steps;
* channel blocks bd are lane-aligned (multiples of 128).

Oracle: :func:`repro.kernels.ref.rglru_scan`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 8
DEFAULT_BD = 128


def _rglru_kernel(x_ref, a_ref, y_ref, hout_ref, h_scratch, *,
                  bt: int, num_tb: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)      # (bt, bd)
    a = a_ref[0].astype(jnp.float32)      # (bt, bd)
    gx = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x

    def step(t, carry):
        h, ys = carry
        h = a[t] * h + gx[t]
        ys = jax.lax.dynamic_update_index_in_dim(ys, h, t, 0)
        return h, ys

    h0 = h_scratch[0]
    h, ys = jax.lax.fori_loop(0, bt, step, (h0, jnp.zeros_like(x)))
    y_ref[0] = ys.astype(y_ref.dtype)
    h_scratch[0] = h

    @pl.when(ti == num_tb - 1)
    def _final():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bt", "bd", "interpret"))
def rglru_scan(x: jax.Array, a: jax.Array, *,
               bt: int = DEFAULT_BT, bd: int = DEFAULT_BD,
               interpret: bool = False):
    """x, a: (B, T, D), a in (0,1). Returns (y (B,T,D), h_T (B,D))."""
    b, t, d = x.shape
    bt = min(bt, t)
    bd = min(bd, d)
    if t % bt or d % bd:
        raise ValueError(f"T={t}, D={d} must divide bt={bt}, bd={bd}")
    num_tb = t // bt

    y, h = pl.pallas_call(
        functools.partial(_rglru_kernel, bt=bt, num_tb=num_tb),
        grid=(b, d // bd, num_tb),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b_, di, ti: (b_, ti, di)),
            pl.BlockSpec((1, bt, bd), lambda b_, di, ti: (b_, ti, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bd), lambda b_, di, ti: (b_, ti, di)),
            pl.BlockSpec((1, bd), lambda b_, di, ti: (b_, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, d), x.dtype),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(x, a)
    return y, h
