"""Pallas TPU kernel for QSGD binary quantization Q_r (paper Definition 3.2).

Two streaming passes, both VMEM-tiled:

  1. sum-of-squares reduction (for the per-vector l2 norm), accumulated
     across the sequential TPU grid;
  2. elementwise stochastic rounding onto the 2^r-level grid:
     out_i = ||x|| * sgn(x_i) * (floor(L*y_i) + [u_i < frac]) / L,
     y_i = |x_i| / ||x||, L = 2^r.

Randomness (uniforms ``u``) is generated *outside* the kernel and streamed in
— this keeps the kernel pure and bit-identical to the jnp oracle
(:func:`repro.kernels.ref.quantize_qr_with_uniforms`) for the same ``u``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 8
_BLOCK_COLS = 128
_BLOCK = _BLOCK_ROWS * _BLOCK_COLS


def _sumsq_kernel(x_ref, valid_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    sel = valid_ref[...] != 0
    out_ref[0, 0] += jnp.sum(jnp.where(sel, x * x, 0.0))


def _quant_kernel(x_ref, u_ref, norm_ref, out_ref, *, levels: float):
    x = x_ref[...]
    norm = norm_ref[0, 0]
    safe = jnp.where(norm > 0, norm, 1.0)
    y = jnp.abs(x) / safe
    scaled = levels * y
    lo = jnp.floor(scaled)
    frac = scaled - lo
    xi = (lo + (u_ref[...] < frac).astype(jnp.float32)) / levels
    out = norm * jnp.sign(x) * xi
    out_ref[...] = jnp.where(norm > 0, out, jnp.zeros_like(out))


def _pad_to_block(x: jax.Array):
    n = x.size
    padded = pl.cdiv(n, _BLOCK) * _BLOCK
    return jnp.pad(x, (0, padded - n)).reshape(-1, _BLOCK_COLS)


def _block_spec():
    return pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i: (i, 0))


_SCALAR_SPEC = pl.BlockSpec((1, 1), lambda i: (0, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def l2_norm(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """l2 norm of a 1-D vector via the streaming sum-of-squares kernel.

    The quantizer's scale.  Exposed so the fused wire-encode ops
    (:mod:`repro.kernels.ops`) compute the *same* grid-accumulated
    reduction the transform kernel uses — bit-identical norms between
    transform and wire payload on the Pallas backends.
    """
    if x.ndim != 1:
        raise ValueError(f"expects 1-D input, got {x.shape}")
    n = x.size
    x2d = _pad_to_block(x.astype(jnp.float32))
    rows = x2d.shape[0]
    idx = (jax.lax.broadcasted_iota(jnp.int32, (rows, _BLOCK_COLS), 0)
           * _BLOCK_COLS
           + jax.lax.broadcasted_iota(jnp.int32, (rows, _BLOCK_COLS), 1))
    valid = (idx < n).astype(jnp.int32)
    sumsq = pl.pallas_call(
        _sumsq_kernel,
        grid=(rows // _BLOCK_ROWS,),
        in_specs=[_block_spec(), _block_spec()],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x2d, valid)
    return jnp.sqrt(sumsq)[0, 0]


@functools.partial(jax.jit, static_argnames=("r", "interpret"))
def quantize_qr_with_uniforms(
    x: jax.Array, r: int, u: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """Q_r(x) on a 1-D vector with uniforms ``u`` in [0,1) of the same shape."""
    if x.ndim != 1:
        raise ValueError(f"expects 1-D input, got {x.shape}")
    orig_dtype = x.dtype
    n = x.size
    xf = x.astype(jnp.float32)
    x2d = _pad_to_block(xf)
    u2d = _pad_to_block(u.astype(jnp.float32))
    rows = x2d.shape[0]
    grid = rows // _BLOCK_ROWS
    norm = l2_norm(x, interpret=interpret).reshape(1, 1)

    out2d = pl.pallas_call(
        functools.partial(_quant_kernel, levels=float(2 ** r)),
        grid=(grid,),
        in_specs=[_block_spec(), _block_spec(), _SCALAR_SPEC],
        out_specs=_block_spec(),
        out_shape=jax.ShapeDtypeStruct((rows, _BLOCK_COLS), jnp.float32),
        interpret=interpret,
    )(x2d, u2d, norm)
    return out2d.reshape(-1)[:n].astype(orig_dtype)


def quantize_qr(x: jax.Array, r: int, key: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    return quantize_qr_with_uniforms(x, r, u, interpret=interpret)
