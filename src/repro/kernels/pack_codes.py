"""Pallas TPU kernels for sub-byte code packing (wire formats, DESIGN.md §8).

The wire codec stores one ``b``-bit code per scalar (sign + level for Q_r).
Packing uses a *bit-plane* layout: codes are grouped 32 at a time and group
``j`` emits ``b`` consecutive uint32 words, word ``j*b + t`` holding bit
``t`` of each of the group's 32 codes (code ``j*32 + l`` at bit ``l``).  No
code ever straddles a word boundary, so both directions are pure
elementwise shift/mask/reduce streams — VPU-only, one read + one write of
~``b/32`` the dense traffic, i.e. genuinely memory-bound (the roofline the
ISSUE's uplink path needs).  Matches :func:`repro.kernels.ref.pack_codes`
bit-for-bit.

Tiling: codes stream through VMEM in (8, 128) blocks = 4 lane-groups of 32
per sublane row; the word block is the matching (8, 4*b) slab, so the
flattened output is word-index-major exactly like the reference layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 8
_BLOCK_COLS = 128
_BLOCK = _BLOCK_ROWS * _BLOCK_COLS
_GROUPS = _BLOCK_COLS // 32      # lane-groups of 32 per sublane row


def _pack_kernel(codes_ref, out_ref, *, b: int):
    c = codes_ref[...]                                   # (8, 128) uint32
    lane = jax.lax.broadcasted_iota(jnp.uint32, (_BLOCK_ROWS, 32), 1)
    cols = []
    for g in range(_GROUPS):
        seg = c[:, g * 32:(g + 1) * 32]                  # (8, 32)
        for t in range(b):
            bits = ((seg >> jnp.uint32(t)) & jnp.uint32(1)) << lane
            cols.append(jnp.sum(bits, axis=1))           # (8,)
    out_ref[...] = jnp.stack(cols, axis=1)               # (8, 4*b)


def _unpack_kernel(words_ref, out_ref, *, b: int):
    w = words_ref[...]                                   # (8, 4*b) uint32
    lane = jax.lax.broadcasted_iota(jnp.uint32, (_BLOCK_ROWS, 32), 1)
    segs = []
    for g in range(_GROUPS):
        acc = jnp.zeros((_BLOCK_ROWS, 32), jnp.uint32)
        for t in range(b):
            word = w[:, g * b + t][:, None]              # (8, 1)
            acc += ((word >> lane) & jnp.uint32(1)) << jnp.uint32(t)
        segs.append(acc)
    out_ref[...] = jnp.concatenate(segs, axis=1)         # (8, 128)


def _rows_for(n: int) -> int:
    return pl.cdiv(max(int(n), 1), _BLOCK) * _BLOCK_ROWS


@functools.partial(jax.jit, static_argnames=("b", "interpret"))
def pack_codes(codes: jax.Array, b: int, *,
               interpret: bool = False) -> jax.Array:
    """Pack ``n`` b-bit codes into ``ceil(n/32) * b`` uint32 words."""
    if codes.ndim != 1:
        raise ValueError(f"expects 1-D input, got {codes.shape}")
    b = int(b)
    if not (1 <= b <= 32):
        raise ValueError(f"code width must be in [1, 32], got {b}")
    n = codes.size
    n32 = pl.cdiv(n, 32)
    rows = _rows_for(n)
    c2d = jnp.pad(codes.astype(jnp.uint32),
                  (0, rows * _BLOCK_COLS - n)).reshape(rows, _BLOCK_COLS)
    words2d = pl.pallas_call(
        functools.partial(_pack_kernel, b=b),
        grid=(rows // _BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, _GROUPS * b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _GROUPS * b), jnp.uint32),
        interpret=interpret,
    )(c2d)
    return words2d.reshape(-1)[: n32 * b]


@functools.partial(jax.jit, static_argnames=("b", "n", "interpret"))
def unpack_codes(words: jax.Array, b: int, n: int, *,
                 interpret: bool = False) -> jax.Array:
    """Inverse of :func:`pack_codes`: recover ``n`` b-bit codes (uint32)."""
    if words.ndim != 1:
        raise ValueError(f"expects 1-D input, got {words.shape}")
    b, n = int(b), int(n)
    n32 = pl.cdiv(n, 32)
    if words.size != n32 * b:
        raise ValueError(
            f"expected {n32 * b} words for n={n}, b={b}, got {words.size}")
    rows = _rows_for(n)
    w2d = jnp.pad(words.astype(jnp.uint32),
                  (0, rows * _GROUPS * b - words.size)
                  ).reshape(rows, _GROUPS * b)
    codes2d = pl.pallas_call(
        functools.partial(_unpack_kernel, b=b),
        grid=(rows // _BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((_BLOCK_ROWS, _GROUPS * b), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _BLOCK_COLS), jnp.uint32),
        interpret=interpret,
    )(w2d)
    return codes2d.reshape(-1)[:n]
