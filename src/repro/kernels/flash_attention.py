"""Pallas TPU flash attention (forward) — the TPU compute hot-spot kernel.

Canonical blockwise online-softmax attention, adapted for the TPU memory
hierarchy:

* grid = (batch, q_heads, Tq/bq, Tk/bk); the last axis is sequential on TPU,
  so the running max / denominator / output accumulator live in VMEM scratch
  and persist across k-blocks;
* BlockSpec tiles: q (1,1,bq,dh), k/v (1,1,bk,dh) — dh and block sizes are
  multiples of 128 where the head dim allows, keeping MXU matmuls aligned;
* GQA folds the kv-head index in the BlockSpec index_map (kv = qh // group),
  so no repeated KV materialisation in HBM;
* causal masking, sliding-window masking and gemma-style logit softcap are
  applied on the logits tile in VMEM.

Oracle: :func:`repro.kernels.ref.mha_attention`.  Forward-only by design —
training paths use the differentiable jnp scan in
:mod:`repro.models.attention`; this kernel is the serving/prefill TPU target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  q_offset: int, softcap: float | None,
                  bq: int, bk: int, num_kb: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = (q_offset + qi * bq
            + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                             # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                          # (bq, bk)
    correction = jnp.exp(m_prev - m_new)            # (bq, 1)
    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * correction
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finalize():
        denom = jnp.where(l_ref[...] > 0, l_ref[...], 1.0)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "softcap",
                     "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,            # (B, Hq, Tq, Dh)
    k: jax.Array,            # (B, Hkv, Tk, Dh)
    v: jax.Array,            # (B, Hkv, Tk, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    softcap: float | None = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    b, hq, tq, dh = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq = min(bq, tq)
    bk = min(bk, tk)
    if tq % bq or tk % bk:
        raise ValueError(f"Tq={tq} / Tk={tk} must be divisible by bq={bq}/bk={bk}")
    num_qb, num_kb = tq // bq, tk // bk
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, softcap=softcap, bq=bq, bk=bk, num_kb=num_kb)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
