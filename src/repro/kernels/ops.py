"""Backend dispatcher for the kernels package.

Every hot op has three executable forms:

* Pallas TPU kernel (``<name>.py``) — the production target, compiled with
  explicit BlockSpec VMEM tiling on TPU;
* the same kernel under ``interpret=True`` — used by the correctness tests on
  CPU (executes the kernel body with jnp semantics);
* the pure-jnp oracle (``ref.py``) — used on non-TPU backends for real runs
  (FL simulation, smoke tests, dry-run lowering) where compiling Mosaic is
  impossible, and as the allclose ground truth everywhere.

``set_backend`` overrides dispatch globally (tests use it to force
``interpret``).
"""

from __future__ import annotations

from typing import Literal, Optional

import jax
import numpy as np

import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import pack_codes as _pack
from repro.kernels import qr_pack as _qr_pack
from repro.kernels import quantize as _quant
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import select_slots as _sel
from repro.kernels import topk_compress as _topk
from repro.kernels import wkv6 as _wkv

Backend = Literal["auto", "pallas", "interpret", "ref"]
_BACKEND: Backend = "auto"


def set_backend(backend: Backend) -> None:
    global _BACKEND
    if backend not in ("auto", "pallas", "interpret", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    _BACKEND = backend


def get_backend() -> Backend:
    return _BACKEND


def _resolve() -> str:
    if _BACKEND != "auto":
        return _BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _is_traced(v) -> bool:
    """True for jax arrays / tracers (per-client parameters under vmap);
    host scalars — python or numpy — stay on the static kernel path."""
    return not isinstance(v, (int, float, np.integer, np.floating))


def topk_mask(x: jax.Array, k) -> jax.Array:
    mode = _resolve()
    if _is_traced(k):
        # Traced k (per-client density): the static-k radix-select kernel
        # cannot specialise, so every backend takes the sort-based dynamic
        # path (identical threshold semantics, see ref.topk_mask_dynamic).
        return _ref.topk_mask_dynamic(x, k)
    if mode == "ref":
        return _ref.topk_mask(x, k)
    return _topk.topk_mask(x, int(k), interpret=(mode == "interpret"))


def quantize_qr(x: jax.Array, r, key: jax.Array) -> jax.Array:
    mode = _resolve()
    if mode == "ref" or _is_traced(r):
        # The jnp oracle handles traced r (2**r stays in-graph); the Pallas
        # kernel needs a static level count.
        return _ref.quantize_qr(x, r, key)
    return _quant.quantize_qr(x, int(r), key, interpret=(mode == "interpret"))


def topk_slots(x: jax.Array, k, cap: int):
    """Fused TopK select + slot extraction (the ``topk`` wire codec).

    Returns ``(idx, vals, support)``: ``cap`` uint32 slot indices (sentinel
    ``x.size`` in empty slots), the gathered values at ``x.dtype``, and the
    n-sized kept-support mask the bit accounting counts.  Pallas backends
    run the radix threshold + the streaming compaction kernel; traced ``k``
    (per-client densities) falls back to the jnp oracle, whose binary-search
    threshold keeps ``k`` in-graph.
    """
    mode = _resolve()
    if mode == "ref" or _is_traced(k):
        return _ref.topk_slots(x, k, int(cap))
    interp = mode == "interpret"
    t = _topk.threshold_bits(x, int(k), interpret=interp)
    bits = _ref._mag_bits(x)
    support = (bits >= t) & (bits != jnp.uint32(0))
    idx, vals = _sel.compact_slots(x, t, int(cap), interpret=interp)
    return idx.astype(jnp.uint32), vals.astype(x.dtype), support


def topk_slots_sharded(x: jax.Array, k_global, cap: int, axis: str,
                       n_total: int):
    """Shard-local slots of the exact global TopK, inside ``shard_map``.

    ``x`` is one model shard of a unit of global size ``n_total``; the
    threshold walk psums its per-pass counts over mesh axis ``axis`` so the
    union of local supports is the exact global-TopK support without
    gathering magnitudes (DESIGN.md §9).  Digit width is picked per
    backend: 8-bit psum'd histograms on TPU (4 collective rounds), the
    scatter-free 1-bit walk on CPU where jnp scatter histograms lose to
    compare+reduce (EXPERIMENTS.md §Perf).  Always the jnp path — the op
    runs inside a manual shard_map region, where the collective is part of
    the op itself.
    """
    digit_bits = 8 if jax.default_backend() == "tpu" else 1
    return _ref.topk_slots_sharded(x, k_global, int(cap), axis,
                                   int(n_total), digit_bits=digit_bits)


def quantize_pack(x: jax.Array, r: int, key: jax.Array):
    """Fused Q_r quantize + bit-plane pack (the ``qr`` wire codec).

    Returns ``(words, norm)``: the (1+r)-bit sign+level codes packed into
    ``ceil(n/32) * (1+r)`` uint32 words, and the l2 norm (the quantizer's
    scale).  Uniforms come from ``key`` exactly as ``quantize_qr`` draws
    them, and each backend computes the norm the way its transform path
    does (jnp sum on ref, the grid-accumulated sum-of-squares kernel on
    Pallas), so ``decode(encode(x))`` is bit-identical to the transform on
    every backend.  ``r`` must be static (the pack width is a shape).
    """
    mode = _resolve()
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    if mode == "ref":
        xf = x.astype(jnp.float32)
        norm = jnp.sqrt(jnp.sum(xf * xf))
        return _ref.quantize_pack_with_uniforms(x, int(r), u, norm), norm
    interp = mode == "interpret"
    norm = _quant.l2_norm(x, interpret=interp)
    words = _qr_pack.quantize_pack_with_uniforms(
        x, int(r), u, norm, interpret=interp)
    return words, norm


def quantize_pack_global_norm(x: jax.Array, r: int, u: jax.Array,
                              norm: jax.Array):
    """``quantize_pack`` with the norm (and uniforms) supplied externally.

    The sharded qr path computes the *global* l2 norm by psum-ing local
    sums of squares across the model axis, then packs each shard's slice
    against that shared scale; uniforms are drawn by the caller (per-shard
    ``fold_in`` keys) so each shard's rounding draws are independent.
    """
    mode = _resolve()
    if mode == "ref":
        return _ref.quantize_pack_with_uniforms(x, int(r), u, norm)
    return _qr_pack.quantize_pack_with_uniforms(
        x, int(r), u, norm, interpret=(mode == "interpret"))


def topk_qr_slots(x: jax.Array, k, cap: int, r: int, key: jax.Array):
    """Fused TopK -> Q_r -> packed slots (the ``topk_qr`` wire codec).

    Returns ``(idx, words, norm, support)`` — see
    :func:`repro.kernels.ref.topk_qr_slots`.  On Pallas backends the
    survivor codes are computed and compacted in one kernel pass
    (:func:`repro.kernels.select_slots.compact_code_slots`) and packed at
    the static capacity; the norm is the masked vector's, via the same
    reduction as the transform's quantizer.
    """
    mode = _resolve()
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    if mode == "ref" or _is_traced(k):
        return _ref.topk_qr_slots(x, k, int(cap), int(r), u)
    interp = mode == "interpret"
    k, cap, r = int(k), int(cap), int(r)
    t = _topk.threshold_bits(x, k, interpret=interp)
    bits = _ref._mag_bits(x)
    keep = bits >= t
    support = keep & (bits != jnp.uint32(0))
    masked = jnp.where(keep, x.astype(jnp.float32), 0.0)
    norm = _quant.l2_norm(masked, interpret=interp)
    idx, codes = _sel.compact_code_slots(x, u, norm, t, r, cap,
                                         interpret=interp)
    words = _pack.pack_codes(codes, 1 + r, interpret=interp)
    return idx.astype(jnp.uint32), words, norm, support


def pack_codes(codes: jax.Array, b: int) -> jax.Array:
    """Bit-plane pack b-bit codes into uint32 words (wire formats, §8)."""
    mode = _resolve()
    if mode == "ref":
        return _ref.pack_codes(codes, int(b))
    return _pack.pack_codes(codes, int(b), interpret=(mode == "interpret"))


def unpack_codes(words: jax.Array, b: int, n: int) -> jax.Array:
    """Inverse of :func:`pack_codes` — recover ``n`` b-bit codes."""
    mode = _resolve()
    if mode == "ref":
        return _ref.unpack_codes(words, int(b), int(n))
    return _pack.unpack_codes(words, int(b), int(n),
                              interpret=(mode == "interpret"))


def mha_attention(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None, q_offset: int = 0,
                  softcap: Optional[float] = None) -> jax.Array:
    mode = _resolve()
    if mode == "ref":
        return _ref.mha_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, softcap=softcap)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, softcap=softcap,
                               interpret=(mode == "interpret"))


def rglru_scan(x, a):
    mode = _resolve()
    if mode == "ref":
        return _ref.rglru_scan(x, a)
    return _rg.rglru_scan(x, a, interpret=(mode == "interpret"))


def wkv6_scan(r, k, v, w, u):
    mode = _resolve()
    if mode == "ref":
        return _ref.wkv6_scan(r, k, v, w, u)
    return _wkv.wkv6_scan(r, k, v, w, u, interpret=(mode == "interpret"))
