"""Backend dispatcher for the kernels package.

Every hot op has three executable forms:

* Pallas TPU kernel (``<name>.py``) — the production target, compiled with
  explicit BlockSpec VMEM tiling on TPU;
* the same kernel under ``interpret=True`` — used by the correctness tests on
  CPU (executes the kernel body with jnp semantics);
* the pure-jnp oracle (``ref.py``) — used on non-TPU backends for real runs
  (FL simulation, smoke tests, dry-run lowering) where compiling Mosaic is
  impossible, and as the allclose ground truth everywhere.

``set_backend`` overrides dispatch globally (tests use it to force
``interpret``).
"""

from __future__ import annotations

from typing import Literal, Optional

import jax
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import pack_codes as _pack
from repro.kernels import quantize as _quant
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import topk_compress as _topk
from repro.kernels import wkv6 as _wkv

Backend = Literal["auto", "pallas", "interpret", "ref"]
_BACKEND: Backend = "auto"


def set_backend(backend: Backend) -> None:
    global _BACKEND
    if backend not in ("auto", "pallas", "interpret", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    _BACKEND = backend


def get_backend() -> Backend:
    return _BACKEND


def _resolve() -> str:
    if _BACKEND != "auto":
        return _BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _is_traced(v) -> bool:
    """True for jax arrays / tracers (per-client parameters under vmap);
    host scalars — python or numpy — stay on the static kernel path."""
    return not isinstance(v, (int, float, np.integer, np.floating))


def topk_mask(x: jax.Array, k) -> jax.Array:
    mode = _resolve()
    if _is_traced(k):
        # Traced k (per-client density): the static-k radix-select kernel
        # cannot specialise, so every backend takes the sort-based dynamic
        # path (identical threshold semantics, see ref.topk_mask_dynamic).
        return _ref.topk_mask_dynamic(x, k)
    if mode == "ref":
        return _ref.topk_mask(x, k)
    return _topk.topk_mask(x, int(k), interpret=(mode == "interpret"))


def quantize_qr(x: jax.Array, r, key: jax.Array) -> jax.Array:
    mode = _resolve()
    if mode == "ref" or _is_traced(r):
        # The jnp oracle handles traced r (2**r stays in-graph); the Pallas
        # kernel needs a static level count.
        return _ref.quantize_qr(x, r, key)
    return _quant.quantize_qr(x, int(r), key, interpret=(mode == "interpret"))


def pack_codes(codes: jax.Array, b: int) -> jax.Array:
    """Bit-plane pack b-bit codes into uint32 words (wire formats, §8)."""
    mode = _resolve()
    if mode == "ref":
        return _ref.pack_codes(codes, int(b))
    return _pack.pack_codes(codes, int(b), interpret=(mode == "interpret"))


def unpack_codes(words: jax.Array, b: int, n: int) -> jax.Array:
    """Inverse of :func:`pack_codes` — recover ``n`` b-bit codes."""
    mode = _resolve()
    if mode == "ref":
        return _ref.unpack_codes(words, int(b), int(n))
    return _pack.unpack_codes(words, int(b), int(n),
                              interpret=(mode == "interpret"))


def mha_attention(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None, q_offset: int = 0,
                  softcap: Optional[float] = None) -> jax.Array:
    mode = _resolve()
    if mode == "ref":
        return _ref.mha_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, softcap=softcap)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, softcap=softcap,
                               interpret=(mode == "interpret"))


def rglru_scan(x, a):
    mode = _resolve()
    if mode == "ref":
        return _ref.rglru_scan(x, a)
    return _rg.rglru_scan(x, a, interpret=(mode == "interpret"))


def wkv6_scan(r, k, v, w, u):
    mode = _resolve()
    if mode == "ref":
        return _ref.wkv6_scan(r, k, v, w, u)
    return _wkv.wkv6_scan(r, k, v, w, u, interpret=(mode == "interpret"))
