"""Pallas TPU kernel for TopK masking (paper Definition 3.1).

GPU implementations use warp-level radix select in shared memory; the
TPU-native adaptation here is a *radix threshold select* over magnitude bit
patterns:

  1. bitcast |x| to uint32 — for finite non-negative floats the integer order
     equals the float order, so the k-th largest magnitude can be found on
     bit patterns;
  2. four sequential 256-bin histogram passes (8 bits per pass, MSB first),
     each a ``pl.pallas_call`` that tiles x through VMEM and accumulates the
     histogram across the (sequential) TPU grid;
  3. the traced driver walks each histogram to fix one radix digit per pass,
     yielding the exact bit pattern t of the k-th largest magnitude;
  4. one elementwise masking pass keeps entries with |x| >= t.

All passes are memory-bound streaming ops: 4 histogram reads + 1 masked
read/write = ~6d traffic versus O(d log d) for a sort.  Histogramming is
VPU-friendly (one-hot compare + reduce, no MXU needed).  Matches the
threshold semantics of :func:`repro.kernels.ref.topk_mask` exactly
(ties at the threshold are kept).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block: 8 sublanes x 128 lanes per grid step.
_BLOCK_ROWS = 8
_BLOCK_COLS = 128
_BLOCK = _BLOCK_ROWS * _BLOCK_COLS
_NBINS = 256


def _hist_kernel(bits_ref, valid_ref, prefix_ref, hist_ref, *, shift: int):
    """Accumulate the 256-bin histogram of the current radix digit.

    bits_ref:   (BLOCK_ROWS, BLOCK_COLS) uint32 magnitude bit patterns
    valid_ref:  (BLOCK_ROWS, BLOCK_COLS) int32 1/0 validity mask (padding)
    prefix_ref: (1, 1) uint32 — radix digits already decided (high bits)
    hist_ref:   (1, NBINS) float32 output, accumulated across the grid
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    bits = bits_ref[...]
    valid = valid_ref[...] != 0
    prefix = prefix_ref[0, 0]
    # Only elements whose already-decided high bits match the prefix count.
    if shift + 8 < 32:
        high_mask = jnp.uint32(0xFFFFFFFF << (shift + 8) & 0xFFFFFFFF)
    else:
        high_mask = jnp.uint32(0)
    in_bucket = (bits & high_mask) == (prefix & high_mask)
    digit = ((bits >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
    sel = in_bucket & valid
    # One-hot accumulate: (BLOCK, 1) digit vs (1, NBINS) bins.
    onehot = (digit.reshape(-1, 1)
              == jax.lax.broadcasted_iota(jnp.int32, (1, _NBINS), 1))
    contrib = jnp.sum(
        jnp.where(sel.reshape(-1, 1), onehot.astype(jnp.float32), 0.0),
        axis=0, keepdims=True)
    hist_ref[...] += contrib


def _mask_kernel(bits_ref, x_ref, thr_ref, out_ref):
    """out = where(bits >= t, x, 0) — the final masking pass."""
    t = thr_ref[0, 0]
    out_ref[...] = jnp.where(bits_ref[...] >= t, x_ref[...],
                             jnp.zeros_like(x_ref[...]))


def _pad_to_block(x: jax.Array):
    n = x.size
    padded = pl.cdiv(n, _BLOCK) * _BLOCK
    return jnp.pad(x, (0, padded - n)).reshape(-1, _BLOCK_COLS)


_SCALAR_SPEC = pl.BlockSpec((1, 1), lambda i: (0, 0))


def _block_spec():
    return pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i: (i, 0))


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def threshold_bits(x: jax.Array, k: int, *,
                   interpret: bool = False) -> jax.Array:
    """uint32 bit pattern of the k-th largest |x_i| via the radix walk.

    Steps 1-3 of the module docstring, exposed on their own so the fused
    select+pack kernels (:mod:`repro.kernels.select_slots`) can reuse the
    threshold without re-deriving it.  Same value as the jnp binary search
    (:func:`repro.kernels.ref.topk_threshold_bits`): the exact bit pattern
    of the k-th largest magnitude, ties included.  ``k >= n`` returns 0
    (every entry compares >= the threshold); ``k == 0`` returns the
    all-ones pattern (empty support).
    """
    if x.ndim != 1:
        raise ValueError(f"expects 1-D input, got {x.shape}")
    k = int(k)
    if k >= x.size:
        return jnp.zeros((), jnp.uint32)
    if k <= 0:
        return jnp.full((), 0xFFFFFFFF, jnp.uint32)
    n = x.size
    xf = x.astype(jnp.float32)
    bits2d = _pad_to_block(jnp.abs(xf).view(jnp.uint32))
    rows = bits2d.shape[0]
    idx = (jax.lax.broadcasted_iota(jnp.int32, (rows, _BLOCK_COLS), 0)
           * _BLOCK_COLS
           + jax.lax.broadcasted_iota(jnp.int32, (rows, _BLOCK_COLS), 1))
    valid = (idx < n).astype(jnp.int32)
    grid = rows // _BLOCK_ROWS

    def run_hist(prefix: jax.Array, shift: int) -> jax.Array:
        return pl.pallas_call(
            functools.partial(_hist_kernel, shift=shift),
            grid=(grid,),
            in_specs=[_block_spec(), _block_spec(), _SCALAR_SPEC],
            out_specs=pl.BlockSpec((1, _NBINS), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, _NBINS), jnp.float32),
            interpret=interpret,
        )(bits2d, valid, prefix.reshape(1, 1))[0]

    prefix = jnp.zeros((), jnp.uint32)
    k_rem = jnp.asarray(k, jnp.float32)
    for shift in (24, 16, 8, 0):
        hist = run_hist(prefix, shift)                       # (256,)
        ge = jnp.cumsum(hist[::-1])[::-1]                    # count(digit >= j)
        # ge is non-increasing; keep the largest digit with ge >= k_rem.
        sel = ge >= k_rem
        digit = jnp.clip(jnp.sum(sel.astype(jnp.int32)) - 1, 0, 255)
        # Elements with a strictly larger digit are all above the threshold.
        gt = jnp.where(digit < 255, ge[jnp.clip(digit + 1, 0, 255)], 0.0)
        k_rem = k_rem - gt
        prefix = prefix | (digit.astype(jnp.uint32) << shift)
    return prefix


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_mask(x: jax.Array, k: int, *, interpret: bool = False) -> jax.Array:
    """Exact TopK masking of a 1-D vector via TPU radix threshold select."""
    if x.ndim != 1:
        raise ValueError(f"expects 1-D input, got {x.shape}")
    k = int(k)
    if k >= x.size:
        return x
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    n = x.size
    bits2d = _pad_to_block(jnp.abs(xf).view(jnp.uint32))
    x2d = _pad_to_block(xf)
    rows = bits2d.shape[0]
    grid = rows // _BLOCK_ROWS
    t = threshold_bits(x, k, interpret=interpret)

    out2d = pl.pallas_call(
        _mask_kernel,
        grid=(grid,),
        in_specs=[_block_spec(), _block_spec(), _SCALAR_SPEC],
        out_specs=_block_spec(),
        out_shape=jax.ShapeDtypeStruct((rows, _BLOCK_COLS), jnp.float32),
        interpret=interpret,
    )(bits2d, x2d, t.reshape(1, 1))
    return out2d.reshape(-1)[:n].astype(orig_dtype)
