"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose tests, and the
implementations actually executed on non-TPU backends (see :mod:`ops`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------- #
# TopK masking (paper Definition 3.1, threshold semantics)
# --------------------------------------------------------------------------- #

def _mag_bits(x: jax.Array) -> jax.Array:
    """|x| as uint32 bit patterns (after an f32 cast).

    For finite non-negative floats the uint32 order equals the float order,
    so magnitude selection runs on integer bit patterns.  The f32 cast is an
    exact order-embedding for bf16/f16 inputs, so masks computed on the cast
    bits equal masks computed on the original dtype.
    """
    xf = x.astype(jnp.float32)
    return jax.lax.bitcast_convert_type(jnp.abs(xf), jnp.uint32)


#: MSB-first 8-bit digit positions of the radix-histogram threshold walk.
RADIX_SHIFTS = (24, 16, 8, 0)


def radix_digit_hist(bits: jax.Array, prefix: jax.Array,
                     shift: int) -> jax.Array:
    """256-bin int32 histogram of the 8-bit digit at ``shift``, counting
    only elements whose already-decided high bits match ``prefix``.

    One O(n) scatter-add pass over the uint32 magnitude bit patterns —
    the jnp mirror of the Pallas histogram kernel in
    :mod:`repro.kernels.topk_compress`.  Integer counts make the histogram
    an *exact* ``psum`` reducend: summing per-shard histograms across a
    model-parallel mesh axis yields bit-for-bit the histogram of the
    concatenated vector, which is how the sharded wire path (DESIGN.md §9)
    gets exact global TopK without gathering magnitudes.
    """
    if shift + 8 < 32:
        high = jnp.uint32((0xFFFFFFFF << (shift + 8)) & 0xFFFFFFFF)
    else:
        high = jnp.uint32(0)
    match = (bits & high) == (prefix & high)
    digit = ((bits >> jnp.uint32(shift)) & jnp.uint32(0xFF)).astype(jnp.int32)
    return jnp.zeros((256,), jnp.int32).at[digit].add(
        match.astype(jnp.int32))


def radix_walk_step(hist: jax.Array, k_rem: jax.Array):
    """Fix one radix digit from a (possibly cross-shard-summed) histogram.

    Picks the largest digit ``d`` that still leaves ``>= k_rem`` elements
    at or above it (``ge`` is non-increasing, so ``d`` is the last index
    with ``ge >= k_rem``) and discounts the strictly-greater bucket from
    ``k_rem``.  Returns ``(digit int32, k_rem')``.
    """
    ge = jnp.cumsum(hist[::-1])[::-1]              # count(digit >= j)
    digit = jnp.clip(jnp.sum((ge >= k_rem).astype(jnp.int32)) - 1, 0, 255)
    gt = jnp.where(digit < 255, ge[jnp.clip(digit + 1, 0, 255)],
                   jnp.zeros((), ge.dtype))
    return digit, k_rem - gt


def topk_threshold_bits(x: jax.Array, k, *, digit_bits: int = 1,
                        psum_axis: str | None = None,
                        n_total: int | None = None) -> jax.Array:
    """uint32 bit pattern of the k-th largest |x_i| (the TopK threshold).

    A radix-histogram walk on the magnitude bit patterns, MSB first: each
    pass fixes the next ``digit_bits`` bits of the threshold by counting
    how many elements sit at or above each candidate digit, and keeps the
    largest digit with ``>= k`` elements above.  The result is the largest
    ``t`` with ``count(bits >= t) >= k`` — exactly the k-th largest
    magnitude's bit pattern, ties included.  Two digit widths:

    * ``digit_bits=1`` (default) — 32 scatter-free compare+reduce passes
      (one O(n) streaming sweep each; the old "binary search" is exactly
      this walk).  Measured fastest on XLA-CPU, where scatter-add
      histograms serialize (EXPERIMENTS.md §Perf: 8-bit digits cost +127%
      on an account-mode round).
    * ``digit_bits=8`` — 4 passes over 256-bin scatter-add histograms
      (:func:`radix_digit_hist`), the jnp twin of the Pallas kernel in
      :mod:`repro.kernels.topk_compress`.

    With ``psum_axis`` (inside ``shard_map``) every per-pass count or
    histogram is ``lax.psum``-ed across that mesh axis, so the walk
    returns the exact *global* threshold of the axis-concatenated vector
    from shard-local magnitudes — integer counts make the reduction exact,
    which is how the §9 sharded wire path gets bit-identical global TopK
    without gathering magnitudes.  ``digit_bits`` then sets the collective
    count per unit: 32 scalar psums at 1-bit digits vs 4 256-lane psums at
    8-bit digits (the right trade on a real multi-host mesh).  Pass
    ``n_total`` (the global size) so ``k`` clips against the logical
    vector, not this shard's slice.

    ``k`` may be traced (clipped to ``[0, n]``; ``k == 0`` yields the
    all-ones pattern, i.e. empty support).
    """
    if x.ndim != 1:
        raise ValueError(
            f"topk_threshold_bits expects 1-D input, got shape {x.shape}")
    if digit_bits not in (1, 8):
        raise ValueError(f"digit_bits must be 1 or 8, got {digit_bits}")
    bits = _mag_bits(x)
    hi = x.size if n_total is None else int(n_total)
    kc = jnp.clip(jnp.asarray(k, jnp.int32), 0, hi)

    if digit_bits == 8:
        k_rem = kc
        prefix = jnp.zeros((), jnp.uint32)
        for shift in RADIX_SHIFTS:
            hist = radix_digit_hist(bits, prefix, shift)
            if psum_axis is not None:
                hist = jax.lax.psum(hist, psum_axis)
            digit, k_rem = radix_walk_step(hist, k_rem)
            prefix = prefix | (digit.astype(jnp.uint32) << shift)
        return prefix

    def body(i, t):
        cand = t | (jnp.uint32(1) << (jnp.uint32(31) - jnp.uint32(i)))
        cnt = jnp.sum((bits >= cand).astype(jnp.int32))
        if psum_axis is not None:
            cnt = jax.lax.psum(cnt, psum_axis)
        return jnp.where(cnt >= kc, cand, t)

    return jax.lax.fori_loop(0, 32, body, jnp.uint32(0))


def topk_mask(x: jax.Array, k: int) -> jax.Array:
    """Zero all but the k largest-magnitude entries of the 1-D vector ``x``.

    Threshold semantics: every entry with |x_i| >= t is kept, where t is the
    k-th largest magnitude.  Ties at t are all kept (Def. 3.1 allows an
    arbitrary minimiser; threshold semantics is the one implementable without
    a data-dependent output shape, and the one the Pallas radix-select kernel
    produces).  The threshold comes from :func:`topk_threshold_bits` — a
    bit-pattern binary search, not a sort.
    """
    if x.ndim != 1:
        raise ValueError(f"topk_mask expects 1-D input, got shape {x.shape}")
    k = int(k)
    if k >= x.size:
        return x
    t = topk_threshold_bits(x, k)
    return jnp.where(_mag_bits(x) >= t, x, jnp.zeros_like(x))


def topk_mask_dynamic(x: jax.Array, k: jax.Array) -> jax.Array:
    """``topk_mask`` with a *traced* k (per-client densities under ``vmap``).

    Same threshold semantics as :func:`topk_mask` via the same bit-pattern
    binary search, so the output shape stays static while k varies per
    trace.  At k >= size every entry is kept (dense payload).
    """
    if x.ndim != 1:
        raise ValueError(
            f"topk_mask_dynamic expects 1-D input, got shape {x.shape}")
    kc = jnp.clip(jnp.asarray(k, jnp.int32), 1, x.size)
    t = topk_threshold_bits(x, kc)
    return jnp.where(_mag_bits(x) >= t, x, jnp.zeros_like(x))


# --------------------------------------------------------------------------- #
# Fused select -> slots (wire uplink, DESIGN.md §8)
# --------------------------------------------------------------------------- #

def support_slots(support: jax.Array, cap: int) -> jax.Array:
    """Indices of the ``cap`` lowest-index True entries of ``support``
    (int32); empty slots carry the sentinel ``n = support.size``.

    Slot ``j`` holds the index of the (j+1)-th True entry, found by binary
    search on the support-count cumsum — one O(n) streaming pass plus
    ``cap`` gathers, no sort and no n-sized scatter.  Queries beyond the
    support return ``n`` for free; overflow beyond ``cap`` keeps the
    lowest-index ``cap``."""
    csum = jnp.cumsum(support.astype(jnp.int32))
    return jnp.searchsorted(
        csum, jnp.arange(1, cap + 1, dtype=jnp.int32),
        side="left").astype(jnp.int32)


def topk_slots(x: jax.Array, k, cap: int):
    """Fused TopK select + slot extraction: the wire codec's sparse payload.

    Returns ``(idx, vals, support)`` where ``idx`` is ``cap`` uint32 slot
    indices (sentinel ``n`` when the support underfills the capacity),
    ``vals`` the gathered values at ``x.dtype`` (0 in empty slots), and
    ``support`` the n-sized kept-support mask — exactly the nonzero set of
    the TopK-masked vector, i.e. ``|x_i| >= t`` *and* ``x_i != 0`` (the
    conjunction matters when the k-th magnitude is 0: already-zero entries,
    e.g. error-feedback innovations, never ship).
    """
    if x.ndim != 1:
        raise ValueError(f"topk_slots expects 1-D input, got shape {x.shape}")
    n = x.size
    bits = _mag_bits(x)
    t = topk_threshold_bits(x, k)    # k >= n: t = min bits, all nonzero kept
    support = (bits >= t) & (bits != 0)
    idx = support_slots(support, cap)
    safe = jnp.clip(idx, 0, n - 1)
    vals = jnp.where(idx < n, x[safe], jnp.zeros((), x.dtype))
    return idx.astype(jnp.uint32), vals, support


def topk_slots_sharded(x: jax.Array, k_global, cap: int, axis: str,
                       n_total: int, digit_bits: int = 1):
    """Shard-local slots of the exact *global* TopK (DESIGN.md §9).

    ``x`` is this shard's slice of a unit whose axis-concatenated global
    size is ``n_total``, inside ``shard_map`` manual over mesh axis
    ``axis``.  The threshold is the global one — the
    :func:`topk_threshold_bits` radix walk with every per-pass count
    psum'd over ``axis`` — so the union of the shards' supports is exactly
    the global-TopK support, ties included, without gathering magnitudes.
    Slots stay local: ``idx`` indexes this shard's own flattening
    (sentinel ``n_local``).  ``cap`` is the per-shard slot capacity; a
    shard whose local support overflows it keeps the lowest-index ``cap``
    (the §8 static-capacity ties rule, applied per shard).
    """
    if x.ndim != 1:
        raise ValueError(
            f"topk_slots_sharded expects 1-D input, got shape {x.shape}")
    n = x.size
    bits = _mag_bits(x)
    t = topk_threshold_bits(x, k_global, digit_bits=digit_bits,
                            psum_axis=axis, n_total=n_total)
    support = (bits >= t) & (bits != jnp.uint32(0))
    idx = support_slots(support, cap)
    safe = jnp.clip(idx, 0, n - 1)
    vals = jnp.where(idx < n, x[safe], jnp.zeros((), x.dtype))
    return idx.astype(jnp.uint32), vals, support


# --------------------------------------------------------------------------- #
# QSGD binary quantization (paper Definition 3.2)
# --------------------------------------------------------------------------- #

def quantize_qr_with_uniforms(x: jax.Array, r: int, u: jax.Array) -> jax.Array:
    """Q_r(x) with externally supplied uniforms ``u`` in [0, 1) (same shape).

    Splitting randomness from arithmetic keeps kernel and oracle bit-identical
    for the same ``u``.
    """
    levels = jnp.asarray(2 ** r, dtype=jnp.float32)
    xf = x.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(xf * xf))
    y = jnp.abs(xf) / jnp.where(norm > 0, norm, 1.0)
    scaled = levels * y
    lo = jnp.floor(scaled)
    frac = scaled - lo
    xi = (lo + (u < frac).astype(jnp.float32)) / levels
    out = norm * jnp.sign(xf) * xi
    return jnp.where(norm > 0, out, jnp.zeros_like(out)).astype(x.dtype)


def quantize_qr(x: jax.Array, r: int, key: jax.Array) -> jax.Array:
    """Q_r(x) (Def. 3.2) on a 1-D vector, stochastic rounding via ``key``."""
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    return quantize_qr_with_uniforms(x, r, u)


# --------------------------------------------------------------------------- #
# Sub-byte code packing (wire formats, DESIGN.md §8)
# --------------------------------------------------------------------------- #

def pack_codes(codes: jax.Array, b: int) -> jax.Array:
    """Bit-plane pack ``n`` b-bit codes into ``ceil(n/32) * b`` uint32 words.

    Layout: codes are grouped 32 at a time; group ``j`` emits ``b``
    consecutive words, and word ``j*b + t`` holds bit ``t`` of each of the
    32 codes in the group, one code per lane bit (code ``j*32 + l`` at bit
    ``l``).  No code ever straddles a word boundary, so pack and unpack are
    pure elementwise shift/mask streams — the memory-bound layout the
    Pallas kernel (:mod:`repro.kernels.pack_codes`) tiles through VMEM.
    Padding slack is bounded: ``(32*ceil(n/32) - n) * b < 32*b`` bits.
    """
    if codes.ndim != 1:
        raise ValueError(f"pack_codes expects 1-D input, got {codes.shape}")
    b = int(b)
    if not (1 <= b <= 32):
        raise ValueError(f"code width must be in [1, 32], got {b}")
    n = codes.size
    n32 = -(-n // 32)
    c = jnp.pad(codes.astype(jnp.uint32), (0, n32 * 32 - n))
    c = c.reshape(n32, 32)
    lanes = jnp.arange(32, dtype=jnp.uint32)[None, :]
    planes = [jnp.sum(((c >> jnp.uint32(t)) & jnp.uint32(1)) << lanes,
                      axis=1, dtype=jnp.uint32)
              for t in range(b)]
    return jnp.stack(planes, axis=1).reshape(n32 * b)


def unpack_codes(words: jax.Array, b: int, n: int) -> jax.Array:
    """Inverse of :func:`pack_codes`: recover ``n`` b-bit codes (uint32)."""
    if words.ndim != 1:
        raise ValueError(f"unpack_codes expects 1-D input, got {words.shape}")
    b = int(b)
    n32 = -(-int(n) // 32)
    if words.size != n32 * b:
        raise ValueError(
            f"expected {n32 * b} words for n={n}, b={b}, got {words.size}")
    w = words.reshape(n32, b)
    lanes = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    bits = (w[:, None, :] >> lanes) & jnp.uint32(1)       # (n32, 32, b)
    shifts = jnp.arange(b, dtype=jnp.uint32)[None, None, :]
    codes = jnp.sum(bits << shifts, axis=2, dtype=jnp.uint32)
    return codes.reshape(n32 * 32)[:n]


# --------------------------------------------------------------------------- #
# Fused quantize -> pack and select -> quantize -> pack (wire uplink, §8)
# --------------------------------------------------------------------------- #

def qr_codes_with_uniforms(x: jax.Array, r: int, u: jax.Array,
                           norm: jax.Array) -> jax.Array:
    """The transform's stochastic Q_r levels as (1+r)-bit integer codes.

    Same uniforms and arithmetic as :func:`quantize_qr_with_uniforms`, but
    keeps the integer level (sign bit ``<< r`` | r level bits) instead of
    the float value.  The top level ``2**r`` saturates to ``2**r - 1`` so
    codes fit their r bits — the wire codec's documented divergence from
    the transform.  ``norm`` is taken as an operand (not recomputed) so
    kernel and oracle stay bit-identical for the same reduction.
    """
    levels = jnp.asarray(2 ** r, jnp.float32)
    xf = x.astype(jnp.float32)
    y = jnp.abs(xf) / jnp.where(norm > 0, norm, 1.0)
    scaled = levels * y
    lo = jnp.floor(scaled)
    code = (lo + (u < scaled - lo)).astype(jnp.uint32)
    code = jnp.minimum(code, jnp.uint32(2 ** r - 1))     # saturate top level
    sign = (xf < 0).astype(jnp.uint32)
    return (sign << r) | code


def quantize_pack_with_uniforms(x: jax.Array, r: int, u: jax.Array,
                                norm: jax.Array) -> jax.Array:
    """Fused Q_r quantize + bit-plane pack: codes straight to uint32 words.

    Oracle for the fused Pallas kernel (:mod:`repro.kernels.qr_pack`),
    which never materialises the dense code array in HBM.
    """
    return pack_codes(qr_codes_with_uniforms(x, r, u, norm), 1 + int(r))


def topk_qr_slots(x: jax.Array, k, cap: int, r: int, u: jax.Array):
    """Fused TopK -> Q_r -> packed slots (the ``topk_qr`` wire codec).

    Returns ``(idx, words, norm, support)``: ``cap`` uint32 slot indices
    (sentinel ``n``), the survivors' (1+r)-bit codes bit-plane packed into
    ``ceil(cap/32) * (1+r)`` uint32 words (code 0 in empty slots), the l2
    norm of the TopK-masked vector (the quantizer's scale, computed over
    the n-sized masked array so the reduction order matches the
    transform's), and the kept-support mask as in :func:`topk_slots`.
    """
    if x.ndim != 1:
        raise ValueError(
            f"topk_qr_slots expects 1-D input, got shape {x.shape}")
    n = x.size
    bits = _mag_bits(x)
    t = topk_threshold_bits(x, k)
    keep = bits >= t
    support = keep & (bits != 0)
    xf = x.astype(jnp.float32)
    masked = jnp.where(keep, xf, 0.0)
    norm = jnp.sqrt(jnp.sum(masked * masked))
    codes = qr_codes_with_uniforms(masked, r, u, norm)
    idx = support_slots(support, cap)
    safe = jnp.clip(idx, 0, n - 1)
    kept = jnp.where(idx < n, codes[safe], jnp.uint32(0))
    words = pack_codes(kept, 1 + int(r))
    return idx.astype(jnp.uint32), words, norm, support


# --------------------------------------------------------------------------- #
# Flash attention (naive oracle)
# --------------------------------------------------------------------------- #

def mha_attention(
    q: jax.Array,           # (B, Hq, Tq, Dh)
    k: jax.Array,           # (B, Hkv, Tk, Dh)
    v: jax.Array,           # (B, Hkv, Tk, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    softcap: float | None = None,
) -> jax.Array:
    """Naive O(Tq*Tk) softmax attention with GQA, causal & sliding window.

    ``q_offset`` is the absolute position of q[0] (for decode: cache length).
    ``window``: attend only to keys within ``window`` positions behind the
    query (sliding-window attention).  ``softcap``: gemma2-style logit
    soft-capping ``softcap * tanh(logits / softcap)``.
    """
    b, hq, tq, dh = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) / jnp.sqrt(dh).astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = q_offset + jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vr).astype(q.dtype)


# --------------------------------------------------------------------------- #
# RG-LRU scan (RecurrentGemma, arXiv:2402.19427)
# --------------------------------------------------------------------------- #

def rglru_scan(x: jax.Array, a: jax.Array, h0: jax.Array | None = None,
               chunk: int = 64):
    """Real-gated linear recurrent unit scan.

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t,  elementwise over channels.

    Two-level scan (outer over T/chunk time blocks, remat'd inner): the
    outer carry holds only chunk-boundary states, which (a) bounds autodiff
    residuals and (b) keeps the loop trip count low — XLA's cost model
    charges a dynamic-slice a full-operand read per trip, so flat T-step
    scans inflate the HLO bytes ~T/chunk-fold (EXPERIMENTS.md §Perf H1).

    x, a: (B, T, D) with a in (0, 1).  Returns (ys (B, T, D), h_T (B, D)).
    """
    b, t, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), dtype=jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - a.astype(jnp.float32) ** 2, 0.0))
    gx = beta * x.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h, inp):
        a_t, gx_t = inp
        h = a_t * h + gx_t
        return h, h

    chunk = min(chunk, t)
    if t % chunk:
        chunk = t
    nchunks = t // chunk

    def tm(z):  # (B, T, D) -> (nchunks, chunk, B, D)
        z = z.swapaxes(0, 1)
        return z.reshape(nchunks, chunk, b, d)

    @jax.checkpoint
    def run_chunk(h, inp):
        return jax.lax.scan(step, h, inp)

    hT, ys = jax.lax.scan(run_chunk, h0, (tm(af), tm(gx)))
    ys = ys.reshape(t, b, d)
    return ys.swapaxes(0, 1).astype(x.dtype), hT


# --------------------------------------------------------------------------- #
# RWKV6 "Finch" WKV recurrence (arXiv:2404.05892)
# --------------------------------------------------------------------------- #

def wkv6_scan(
    r: jax.Array,   # (B, H, T, K)
    k: jax.Array,   # (B, H, T, K)
    v: jax.Array,   # (B, H, T, V)
    w: jax.Array,   # (B, H, T, K)   per-step decay in (0, 1) (already exp'ed)
    u: jax.Array,   # (H, K)         bonus for the current token
    s0: jax.Array | None = None,     # (B, H, K, V)
    chunk: int = 64,
):
    """Data-dependent-decay linear attention recurrence.

    y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

    Two-level scan: the outer scan carries only chunk-boundary states (T/chunk
    of them) and the remat'd inner scan recomputes within-chunk states in the
    backward pass — a flat scan would store the (T, B, H, K, V) state history
    as autodiff residuals (~2.7 GiB/device at train_4k for rwkv6-3b).

    Returns (y (B, H, T, V), S_T (B, H, K, V)).
    """
    b, h, t, kd = r.shape
    vd = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, kd, vd), dtype=jnp.float32)
    rf, kf, vf, wf = (z.astype(jnp.float32) for z in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + uf[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    chunk = min(chunk, t)
    if t % chunk:
        chunk = t  # fall back to a single chunk for ragged lengths
    nchunks = t // chunk

    # (T, B, H, *) time-major, then (nchunks, chunk, B, H, *)
    def tm(z):
        z = z.transpose(2, 0, 1, 3)
        return z.reshape(nchunks, chunk, *z.shape[1:])

    @jax.checkpoint
    def run_chunk(S, inp):
        return jax.lax.scan(step, S, inp)

    sT, ys = jax.lax.scan(run_chunk, s0, (tm(rf), tm(kf), tm(vf), tm(wf)))
    ys = ys.reshape(t, b, h, vd)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), sT
