"""Pallas TPU kernel for the RWKV6 "Finch" WKV recurrence (arXiv:2404.05892).

Per head, with state S in R^{K x V}:

    y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (w_t: data-dependent decay)

TPU mapping: grid = (B, H, T/bt) with the time axis sequential; the K x V
state matrix stays resident in VMEM scratch (64x64 fp32 = 16 KiB for a
standard head), and (r, k, v, w) stream through VMEM in bt-step tiles.  The
inner rank-1 updates are VPU outer products; y_t is a (1 x K)(K x V) matvec.

Oracle: :func:`repro.kernels.ref.wkv6_scan`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 16


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref,
                 s_scratch, *, bt: int, num_tb: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_scratch[...] = jnp.zeros_like(s_scratch)

    r = r_ref[0, 0].astype(jnp.float32)   # (bt, K)
    k = k_ref[0, 0].astype(jnp.float32)   # (bt, K)
    v = v_ref[0, 0].astype(jnp.float32)   # (bt, V)
    w = w_ref[0, 0].astype(jnp.float32)   # (bt, K)
    u = u_ref[0].astype(jnp.float32)      # (K,)

    def step(t, carry):
        S, ys = carry
        kv = k[t][:, None] * v[t][None, :]                  # (K, V)
        y = jnp.sum((S + u[:, None] * kv) * r[t][:, None], axis=0)  # (V,)
        S = w[t][:, None] * S + kv
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, 0)
        return S, ys

    S0 = s_scratch[...]
    S, ys = jax.lax.fori_loop(
        0, bt, step, (S0, jnp.zeros((bt, v.shape[-1]), jnp.float32)))
    y_ref[0, 0] = ys.astype(y_ref.dtype)
    s_scratch[...] = S

    @pl.when(ti == num_tb - 1)
    def _final():
        sout_ref[0, 0] = S.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def wkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, *, bt: int = DEFAULT_BT,
              interpret: bool = False):
    """r,k,w: (B,H,T,K); v: (B,H,T,V); u: (H,K).

    Returns (y (B,H,T,V), S_T (B,H,K,V)).
    """
    b, h, t, kd = r.shape
    vd = v.shape[-1]
    bt = min(bt, t)
    if t % bt:
        raise ValueError(f"T={t} must divide bt={bt}")
    num_tb = t // bt

    y, s = pl.pallas_call(
        functools.partial(_wkv6_kernel, bt=bt, num_tb=num_tb),
        grid=(b, h, num_tb),
        in_specs=[
            pl.BlockSpec((1, 1, bt, kd), lambda b_, h_, ti: (b_, h_, ti, 0)),
            pl.BlockSpec((1, 1, bt, kd), lambda b_, h_, ti: (b_, h_, ti, 0)),
            pl.BlockSpec((1, 1, bt, vd), lambda b_, h_, ti: (b_, h_, ti, 0)),
            pl.BlockSpec((1, 1, bt, kd), lambda b_, h_, ti: (b_, h_, ti, 0)),
            pl.BlockSpec((1, kd), lambda b_, h_, ti: (h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bt, vd), lambda b_, h_, ti: (b_, h_, ti, 0)),
            pl.BlockSpec((1, 1, kd, vd), lambda b_, h_, ti: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, vd), r.dtype),
            jax.ShapeDtypeStruct((b, h, kd, vd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kd, vd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s
