"""Single-program LM trainer (plain, non-federated baseline runtime).

Runs real steps on whatever devices exist (CPU smoke: reduced configs;
TPU: full configs) using the same build_train_step the dry-run lowers.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 20 --batch 4 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_spec
from repro.configs.base import InputShape, reduced as make_reduced
from repro.data import synthetic
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    spec = get_spec(args.arch)
    if args.reduced:
        spec = make_reduced(spec)
    m = spec.model
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    shape = InputShape("custom", args.seq, args.batch, "train")
    bundle = steps_mod.build_train_step(spec, shape, mesh,
                                        optimizer=args.optimizer)

    key = jax.random.PRNGKey(0)
    if spec.is_encdec:
        params = encdec_mod.init_params(key, m)
    else:
        params = tfm.init_params(key, m)
    from repro.optim import optimizers
    opt_name, lr = steps_mod._optimizer_for(spec)
    if args.optimizer:
        opt_name = args.optimizer
    opt_init, _ = optimizers.make(opt_name, lr)
    opt_state = opt_init(params)

    toks = synthetic.make_lm_tokens(min(m.vocab, 4096),
                                    args.batch * 2, args.seq, seed=0)

    with mesh:
        step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       out_shardings=bundle.out_shardings,
                       donate_argnums=bundle.donate_argnums)
        t0 = time.time()
        for i in range(args.steps):
            sl = np.random.default_rng(i).integers(0, toks.shape[0],
                                                   args.batch)
            if spec.is_encdec:
                t_src = args.seq // 2
                batch = {
                    "src_embeds": jnp.asarray(
                        np.random.default_rng(i).normal(
                            size=(args.batch, t_src, m.d_model)),
                        jnp.bfloat16),
                    "tgt_tokens": jnp.asarray(
                        toks[sl][:, :args.seq - t_src]),
                }
            else:
                batch = {"tokens": jnp.asarray(
                    toks[sl][:, :args.seq - spec.n_prefix_tokens])}
                if spec.n_prefix_tokens:
                    batch["prefix_embeds"] = jnp.zeros(
                        (args.batch, spec.n_prefix_tokens, m.d_model),
                        jnp.bfloat16)
            params, opt_state, loss = step(params, opt_state, batch)
            if i % args.log_every == 0:
                print(f"step {i:4d}  loss {float(loss):.4f}  "
                      f"({time.time() - t0:.1f}s)")
    print("done")


if __name__ == "__main__":
    main()
