"""Production mesh construction (deliverable (e)).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain placeholder devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """v5e production mesh: 16x16 per pod; 2 pods when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh for CPU smoke runs of the pjit code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_client_mesh(n_shards: int | None = None, *, data: int = 1,
                     model: int = 1, config=None) -> jax.sharding.Mesh:
    """Mesh whose leading ``clients`` axis shards federated rounds
    (DESIGN.md §6; consumed by ``RoundEngine.use_mesh`` /
    ``server.run_federated(mesh=...)``).

    With ``data``/``model`` left at 1 this is a 1-D ``("clients",)`` mesh
    over ``n_shards`` devices (default: all available — force more host
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    Passing ``data``/``model`` composes the client axis with the existing
    in-model axes: ``(clients, data, model)``, clients outermost so each
    client shard holds a contiguous data/model sub-mesh.

    Pass ``config`` (an ``ArchSpec`` or ``ModelConfig``) with ``model > 1``
    to validate model-axis divisibility against the architecture's
    head/ffn/vocab dims up front — a bad composition otherwise surfaces as
    a deep XLA sharding failure mid-round.
    """
    if n_shards is None:
        n_shards = max(1, len(jax.devices()) // (data * model))
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if data == 1 and model == 1:
        return jax.make_mesh((n_shards,), ("clients",))
    mesh = jax.make_mesh((n_shards, data, model),
                         ("clients", "data", "model"))
    if config is not None and model > 1:
        from repro.core.distributed import validate_model_axis
        validate_model_axis(mesh, config)
    return mesh
