"""Production mesh construction (deliverable (e)).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain placeholder devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """v5e production mesh: 16x16 per pod; 2 pods when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh for CPU smoke runs of the pjit code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
