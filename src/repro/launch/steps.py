"""Step functions + ShapeDtypeStruct input specs per (arch x input shape).

This is the bridge between the model zoo and the launchers: for every
assigned architecture and input shape it builds

* the jit-able step function (``train_step`` / ``prefill_step`` /
  ``serve_step``),
* weak-type-correct ``ShapeDtypeStruct`` stand-ins for every input (the
  dry-run lowers against these; nothing is allocated),
* the matching in/out shardings for the production meshes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, InputShape, SHAPES
from repro.models import attention as attn_mod
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.optim import optimizers
from repro.sharding import specs as sh

PyTree = Any

S = jax.ShapeDtypeStruct


@dataclasses.dataclass
class StepBundle:
    """Everything the launchers need for one (arch, shape) combination."""
    fn: Callable                 # the step function
    args: tuple                  # ShapeDtypeStruct pytree per argument
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


# --------------------------------------------------------------------------- #
# helpers
# --------------------------------------------------------------------------- #

def _optimizer_for(spec: ArchSpec) -> tuple[str, float]:
    # the 400B MoE cannot afford fp32 adam state on 16 GB chips
    if spec.arch_id.startswith("llama4"):
        return "sgd", 1e-3
    return "adam", 1e-4

def adjust_for_shape(spec: ArchSpec, shape_name: str) -> ArchSpec:
    """``long_context_cap`` (global layers capped to a sliding window) only
    applies in long-context mode; every other shape gets true full attention
    on the global layers."""
    if spec.is_encdec or shape_name == "long_500k":
        return spec
    m = spec.model
    if m.long_context_cap is None:
        return spec
    return dataclasses.replace(
        spec, model=dataclasses.replace(m, long_context_cap=None))


def _params_struct(spec: ArchSpec) -> PyTree:
    m = spec.model
    if spec.is_encdec:
        return jax.eval_shape(
            lambda k: encdec_mod.init_params(k, m), jax.random.PRNGKey(0))
    return jax.eval_shape(
        lambda k: tfm.init_params(k, m), jax.random.PRNGKey(0))


def _replicated(mesh: Mesh, tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


def _n_experts(spec: ArchSpec) -> Optional[int]:
    m = spec.model
    return m.moe.n_experts if (not spec.is_encdec and m.moe is not None) \
        else None


# --------------------------------------------------------------------------- #
# step builders
# --------------------------------------------------------------------------- #

def build_train_step(spec: ArchSpec, shape: InputShape, mesh: Mesh,
                     optimizer: Optional[str] = None,
                     loss_chunk: int = 256) -> StepBundle:
    m = spec.model
    opt_name, lr = _optimizer_for(spec)
    if optimizer is not None:
        opt_name = optimizer
    opt_init, opt_update = optimizers.make(opt_name, lr)
    b, t = shape.global_batch, shape.seq_len
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    bspec = NamedSharding(mesh, P(fsdp))

    params_struct = _params_struct(spec)
    pshard = sh.param_shardings(params_struct, mesh,
                                n_experts=_n_experts(spec))
    opt_struct = jax.eval_shape(opt_init, params_struct)
    oshard = sh.param_shardings(opt_struct, mesh,
                                n_experts=_n_experts(spec)) \
        if jax.tree_util.tree_leaves(opt_struct) else ()

    if spec.is_encdec:
        t_src = t // 2
        t_tgt = t - t_src
        batch = {
            "src_embeds": S((b, t_src, m.d_model), jnp.bfloat16),
            "tgt_tokens": S((b, t_tgt), jnp.int32),
        }
        bshard = {"src_embeds": NamedSharding(mesh, P(fsdp, None, None)),
                  "tgt_tokens": bspec}

        def train_step(params, opt_state, batch_):
            def loss_fn(p):
                return encdec_mod.loss(p, m, batch_["src_embeds"],
                                       batch_["tgt_tokens"],
                                       loss_chunk=loss_chunk)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state = opt_update(grads, opt_state, params)
            return params, opt_state, loss
    else:
        npre = spec.n_prefix_tokens
        batch = {"tokens": S((b, t - npre), jnp.int32)}
        bshard = {"tokens": bspec}
        if npre:
            batch["prefix_embeds"] = S((b, npre, m.d_model), jnp.bfloat16)
            bshard["prefix_embeds"] = NamedSharding(mesh, P(fsdp, None, None))

        def train_step(params, opt_state, batch_):
            def loss_fn(p):
                return tfm.loss(p, m, batch_["tokens"],
                                prefix_embeds=batch_.get("prefix_embeds"),
                                loss_chunk=loss_chunk)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state = opt_update(grads, opt_state, params)
            return params, opt_state, loss

    return StepBundle(
        fn=train_step,
        args=(params_struct, opt_struct, batch),
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )


def build_prefill_step(spec: ArchSpec, shape: InputShape, mesh: Mesh,
                       seq_parallel: bool = False) -> StepBundle:
    m = spec.model
    b, t = shape.global_batch, shape.seq_len
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    bspec = NamedSharding(mesh, P(fsdp))
    params_struct = _params_struct(spec)
    pshard = sh.param_shardings(params_struct, mesh,
                                n_experts=_n_experts(spec),
                                seq_parallel=seq_parallel)
    logits_shard = NamedSharding(
        mesh, sh._sanitize(P(fsdp, "model"), (b, m.vocab), mesh))

    if spec.is_encdec:
        t_src, t_tgt = t // 2, t - t // 2
        args = ({"src_embeds": S((b, t_src, m.d_model), jnp.bfloat16),
                 "tgt_tokens": S((b, t_tgt), jnp.int32)},)
        bshard = ({"src_embeds": NamedSharding(mesh, P(fsdp, None, None)),
                   "tgt_tokens": bspec},)

        def prefill_step(params, batch_):
            return encdec_mod.prefill(params, m, batch_["src_embeds"],
                                      batch_["tgt_tokens"], max_len=t_tgt)

        state_struct = jax.eval_shape(prefill_step, params_struct, args[0])[1]
        sshard = sh.state_sharding(state_struct, mesh)
        return StepBundle(fn=prefill_step, args=(params_struct,) + args,
                          in_shardings=(pshard,) + bshard,
                          out_shardings=(logits_shard, sshard))

    npre = spec.n_prefix_tokens
    batch = {"tokens": S((b, t - npre), jnp.int32)}
    bshard = {"tokens": bspec}
    if npre:
        batch["prefix_embeds"] = S((b, npre, m.d_model), jnp.bfloat16)
        bshard["prefix_embeds"] = NamedSharding(mesh, P(fsdp, None, None))

    def prefill_step(params, batch_):
        return tfm.prefill(params, m, batch_["tokens"], max_len=t,
                           prefix_embeds=batch_.get("prefix_embeds"))

    state_struct = jax.eval_shape(prefill_step, params_struct, batch)[1]
    sshard = sh.state_sharding(state_struct, mesh)
    return StepBundle(fn=prefill_step, args=(params_struct, batch),
                      in_shardings=(pshard, bshard),
                      out_shardings=(logits_shard, sshard))


def build_serve_step(spec: ArchSpec, shape: InputShape,
                     mesh: Mesh) -> StepBundle:
    """Decode: ONE new token against a cache of ``shape.seq_len``."""
    m = spec.model
    b, t = shape.global_batch, shape.seq_len
    params_struct = _params_struct(spec)
    pshard = sh.param_shardings(params_struct, mesh,
                                n_experts=_n_experts(spec))
    tok = S((b,), jnp.int32)
    baxis = sh.batch_axis(mesh, b)
    tok_shard = NamedSharding(mesh, P(baxis))
    logits_shard = NamedSharding(
        mesh, sh._sanitize(P(baxis, "model"), (b, m.vocab), mesh))

    if spec.is_encdec:
        # decoder self-cache of seq_len; encoder output of seq_len/8 frames
        enc_len = max(1, t // 8)

        def serve_step(params, token, state):
            return encdec_mod.decode_step(params, m, token, state)

        def make_state():
            caches = {
                f"layer_{i}": attn_mod.KVCache(
                    k=jnp.zeros((b, m.n_kv_heads, t, m.hd), jnp.bfloat16),
                    v=jnp.zeros((b, m.n_kv_heads, t, m.hd), jnp.bfloat16),
                    length=jnp.asarray(t - 1, jnp.int32))
                for i in range(m.n_dec_layers)}
            cross = {
                f"layer_{i}": (
                    jnp.zeros((b, m.n_kv_heads, enc_len, m.hd), jnp.bfloat16),
                    jnp.zeros((b, m.n_kv_heads, enc_len, m.hd), jnp.bfloat16))
                for i in range(m.n_dec_layers)}
            return encdec_mod.EncDecState(
                self_caches=caches, cross_kv=cross,
                enc_len=jnp.asarray(enc_len, jnp.int32))

        state_struct = jax.eval_shape(make_state)
    else:
        def serve_step(params, token, state):
            return tfm.decode_step(params, m, token, state)

        state_struct = jax.eval_shape(
            functools.partial(tfm.init_decode_state, m, b, t))
        # mark caches as partially filled for realism (length traces anyway)
    sshard = sh.state_sharding(state_struct, mesh)
    return StepBundle(fn=serve_step,
                      args=(params_struct, tok, state_struct),
                      in_shardings=(pshard, tok_shard, sshard),
                      out_shardings=(logits_shard, sshard),
                      donate_argnums=(2,))


def build_step(spec: ArchSpec, shape_name: str, mesh: Mesh,
               **kw) -> StepBundle:
    shape = SHAPES[shape_name]
    spec = adjust_for_shape(spec, shape_name)
    if shape.kind == "train":
        return build_train_step(spec, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(spec, shape, mesh)
    return build_serve_step(spec, shape, mesh)
