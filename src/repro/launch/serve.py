"""Batched serving driver: prefill a batch of prompts, then decode tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --prompt-len 64 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_spec
from repro.configs.base import reduced as make_reduced
from repro.data import synthetic
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    spec = get_spec(args.arch)
    if args.reduced:
        spec = make_reduced(spec)
    m = spec.model
    params_key = jax.random.PRNGKey(0)
    max_len = args.prompt_len + args.gen + 1

    toks = jnp.asarray(synthetic.make_lm_tokens(
        min(m.vocab, 4096), args.batch, args.prompt_len, seed=1))

    t0 = time.time()
    if spec.is_encdec:
        params = encdec_mod.init_params(params_key, m)
        src = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, args.prompt_len, m.d_model), jnp.float32)
        logits, state = encdec_mod.prefill(params, m, src, toks[:, :4],
                                           max_len=max_len)
        decode = jax.jit(lambda p, t, s: encdec_mod.decode_step(p, m, t, s))
    else:
        params = tfm.init_params(params_key, m)
        logits, state = tfm.prefill(params, m, toks, max_len=max_len)
        decode = jax.jit(lambda p, t, s: tfm.decode_step(p, m, t, s))
    print(f"prefill done in {time.time() - t0:.1f}s")

    out = []
    key = jax.random.PRNGKey(3)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        out.append(np.asarray(tok))
        logits, state = decode(params, tok, state)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature, axis=-1).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out, axis=1)
    print(f"generated {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s)")
    print("sample token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
