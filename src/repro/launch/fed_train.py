"""FedComLoc as a first-class multi-pod training feature (DESIGN.md §2).

Pod-as-client mapping: each pod of the (pod, data, model) production mesh is
one federated client.  Parameters and control variates carry a leading
``n_clients`` axis sharded over ``pod`` — within a pod they shard FSDP x TP
exactly like the plain trainer.  One *round* is a single jitted function:

  1. L local steps (lax.scan): x_i <- x_i - gamma * (grad_i - h_i), each pod
     touching only its own shard of the batch — **no cross-pod traffic**;
  2. communication (theta = 1): the uplink iterate is compressed (TopK /
     Q_r), the cross-pod mean is one all-reduce over ``pod`` (the mean over
     the leading client axis), and the control variates absorb the skip
     correction h_i += (p/gamma)(x_bar - x^_i).

The only cross-pod collective per round is the (compressed) parameter
average — this is exactly the paper's communication pattern: ProxSkip's
"skip the sync w.p. 1-p" becomes "skip the cross-pod collective", TopK/Q_r
shrink the payload of the one that happens.

Compression comes from the unified subsystem (:mod:`repro.compress`,
DESIGN.md §3) — no local reimplementation.  TopK at 10^9-parameter scale
uses the ``impl="quantile"`` threshold finder (the kth magnitude via
jnp.quantile on |w|) rather than an explicit top_k sort — the Pallas
radix-select kernel implements the same threshold semantics exactly on
TPU; see kernels/topk_compress.py.  The ``sync_mode="int8"`` path rides
the wire codec layer (repro.compress.wire, DESIGN.md §8): ``wire.encode``
emits the packed Payload (leaf-shaped int8 levels + per-tensor f32
scales) whose cross-pod collective moves one byte per scalar, and the
server side is ``wire.decode`` + mean — the same encode/decode API the
simulator's packed rounds use, no hand-rolled encoding here.  Each round
also returns ``comm_bits`` — the exact in-graph wire cost of that round's
cross-pod payload (BitsReport totals).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compress as cx
from repro.configs.base import ArchSpec, InputShape
from repro.launch.steps import StepBundle, _n_experts, _params_struct
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.sharding import specs as sh

PyTree = Any
S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class FedTrainConfig:
    gamma: float = 3e-4
    p: float = 0.1
    local_steps: int = 10           # = round(1/p)
    compressor: str = "topk"        # topk | quant | none
    density: float = 0.1            # topk density
    quant_bits: int = 8
    variant: str = "com"            # com | global | local | none
    # "int8": the cross-pod sync moves an int8 payload (levels) + per-tensor
    # scales — the HLO collective shrinks 4x vs syncing dense f32/bf16
    # (jax dense collectives otherwise move full-width zeros; §Perf H3).
    # Requires compressor="quant" with quant_bits <= 7 magnitude bits.
    sync_mode: str = "dense"        # dense | int8
    # Aggregation policy (DESIGN.md §7).  The pod-as-client round IS one
    # cross-pod collective, so only "sync" is executable here; the
    # event-driven policies (semi_sync / async_buffered) live in the
    # simulator layer (repro.core.aggregation).  Parsed + validated via
    # aggregation_policy() so launch configs fail fast, not at build time.
    aggregation: str = "sync"       # sync | semi_sync | async_buffered
    wait_for: int | None = None     # K (semi_sync)
    buffer_capacity: int | None = None   # buffer size (async_buffered)
    staleness_alpha: float = 0.0    # staleness exponent (async_buffered)

    def aggregation_policy(self):
        """The config's aggregation policy as a validated core object.

        All policy fields are forwarded so the core's cross-field checks
        fire: a knob that doesn't belong to the selected mode (e.g.
        ``wait_for`` under ``aggregation="sync"``) raises instead of being
        silently discarded.
        """
        from repro.core.aggregation import AggregationPolicy
        if self.aggregation not in ("sync", "semi_sync", "async_buffered"):
            raise ValueError(f"unknown aggregation {self.aggregation!r}")
        return AggregationPolicy(
            mode=self.aggregation, wait_for=self.wait_for,
            capacity=self.buffer_capacity, alpha=self.staleness_alpha)


def make_compressor(fed: FedTrainConfig) -> cx.Compressor:
    """Resolve the config to a registry entry (quantile TopK at scale)."""
    if fed.compressor in ("none", "identity"):
        return cx.make_compressor("none")
    if fed.compressor == "topk":
        return cx.make_compressor("topk", density=fed.density,
                                  impl="quantile")
    if fed.compressor == "quant":
        return cx.make_compressor("quant", r=fed.quant_bits)
    raise ValueError(f"unknown compressor {fed.compressor!r}")


# --------------------------------------------------------------------------- #
# the federated round
# --------------------------------------------------------------------------- #

def build_fed_round(spec: ArchSpec, shape: InputShape, mesh: Mesh,
                    fed: FedTrainConfig) -> StepBundle:
    """One FedComLoc round over the pod axis as a single jitted step.

    The bundled fn returns ``(params, h, loss, comm_bits)`` where
    ``comm_bits`` is the exact wire cost of this round's cross-pod payload.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError("fed_train requires a multi-pod mesh")
    if not fed.aggregation_policy().is_sync:
        raise ValueError(
            f'aggregation={fed.aggregation!r}: the pod-as-client round is a '
            f'single cross-pod collective, so only "sync" is executable '
            f'here; run event-driven policies through the simulator '
            f'(repro.core.aggregation, DESIGN.md §7)')
    n_clients = mesh.shape["pod"]
    m = spec.model
    b_local = shape.global_batch // n_clients

    comp = make_compressor(fed)
    if fed.sync_mode == "int8" and fed.compressor != "quant":
        raise ValueError('sync_mode="int8" requires compressor="quant"')
    # Int8Sync itself rejects quant_bits > 7 (level * sign must fit int8).
    int8 = (cx.make_compressor("int8", magnitude_bits=fed.quant_bits)
            if fed.sync_mode == "int8" else None)

    params1 = _params_struct(spec)
    stack = lambda leaf_sh: jax.tree_util.tree_map(
        lambda l: S((n_clients,) + l.shape, l.dtype), leaf_sh)
    params_struct = stack(params1)
    h_struct = stack(params1)

    # shardings: leading client axis over pod, inner dims per the plain rules
    inner = sh.param_shardings(params1, _strip_pod(mesh),
                               n_experts=_n_experts(spec))

    def lift(ns: NamedSharding) -> NamedSharding:
        return NamedSharding(mesh, P("pod", *ns.spec))

    pshard = jax.tree_util.tree_map(lift, inner)

    if spec.is_encdec:
        t_src = shape.seq_len // 2
        t_tgt = shape.seq_len - t_src
        batch = {"src_embeds": S((n_clients, b_local, t_src, m.d_model),
                                 jnp.bfloat16),
                 "tgt_tokens": S((n_clients, b_local, t_tgt), jnp.int32)}
        bshard = {"src_embeds": NamedSharding(
            mesh, P("pod", "data", None, None)),
            "tgt_tokens": NamedSharding(mesh, P("pod", "data", None))}

        def loss_fn(p, batch_):
            return encdec_mod.loss(p, m, batch_["src_embeds"],
                                   batch_["tgt_tokens"], loss_chunk=512)
    else:
        npre = spec.n_prefix_tokens
        batch = {"tokens": S((n_clients, b_local, shape.seq_len - npre),
                             jnp.int32)}
        bshard = {"tokens": NamedSharding(mesh, P("pod", "data", None))}
        if npre:
            batch["prefix_embeds"] = S(
                (n_clients, b_local, npre, m.d_model), jnp.bfloat16)
            bshard["prefix_embeds"] = NamedSharding(
                mesh, P("pod", "data", None, None))

        def loss_fn(p, batch_):
            return tfm.loss(p, m, batch_["tokens"],
                            prefix_embeds=batch_.get("prefix_embeds"),
                            loss_chunk=512)

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    def fed_round(params, h, batch_, key):
        # --- local phase: L steps, zero cross-pod traffic ----------------- #
        def local_step(carry, k_step):
            x, loss_acc = carry
            x_eval = x
            if fed.variant == "local":
                x_eval = jax.vmap(comp.apply)(
                    x, jax.random.split(k_step, n_clients))
            loss, g = grad_fn(x_eval, batch_)
            x = jax.tree_util.tree_map(
                lambda xc, gc, hc: (xc - fed.gamma
                                    * (gc - hc.astype(gc.dtype))
                                    ).astype(xc.dtype), x, g, h)
            return (x, loss_acc + loss.mean()), None

        keys = jax.random.split(key, fed.local_steps + 2)
        (x_hat, loss_sum), _ = jax.lax.scan(
            local_step, (params, jnp.zeros(())), keys[:fed.local_steps])

        # --- communication round (theta = 1) ------------------------------ #
        # Default: the dense cross-pod all-reduce moves every scalar.
        comm_bits = jnp.asarray(cx.dense_bits(x_hat))
        if fed.variant == "com" and fed.sync_mode == "int8":
            # Int8Sync on the unified wire API (DESIGN.md §8): encode emits
            # the packed Payload (leaf-shaped int8 levels + one f32 scale
            # per tensor per client), decode + mean are pod-local.
            up_keys = jax.random.split(keys[-1], n_clients)
            payload, up_rep = jax.vmap(
                lambda t, k: cx.wire.encode(int8, t, k))(x_hat, up_keys)
            # gather over `pod` ONLY (keep within-pod FSDP/TP sharding):
            # the wire collective is an int8 cross-pod all-gather.
            data = tuple(
                (jax.lax.with_sharding_constraint(q_, P(None, *ns.spec[1:])),
                 s_)
                for (q_, s_), ns in zip(payload.data,
                                        jax.tree_util.tree_leaves(pshard)))
            payload = cx.wire.Payload(data, payload.spec)
            x_hat = jax.vmap(cx.wire.decode)(payload)
            # mean in f32 straight from the payload (dequant -> mean -> one
            # cast): per-client bf16 rounding before the mean would change
            # the cross-pod average vs the dense path
            x_bar = jax.tree_util.tree_unflatten(
                payload.spec.treedef,
                [(q_.astype(jnp.float32)
                  * s_.reshape((-1,) + (1,) * (q_.ndim - 1))
                  ).mean(axis=0).astype(dt)
                 for (q_, s_), dt in zip(payload.data, payload.spec.dtypes)])
            # per-client codec report (one scale per tensor per client),
            # summed over the real leading client axis
            comm_bits = up_rep.reduce_sum().total_bits
        else:
            if fed.variant == "com":
                x_hat, up_rep = jax.vmap(comp.compress)(
                    x_hat, jax.random.split(keys[-1], n_clients))
                comm_bits = up_rep.reduce_sum().total_bits
            x_bar = jax.tree_util.tree_map(
                lambda t_: t_.mean(axis=0), x_hat)      # cross-pod all-reduce
        if fed.variant == "global":
            x_bar, down_rep = comp.compress(x_bar, keys[-2])
            # dense all-reduce up + n_clients compressed broadcasts down
            comm_bits = comm_bits + n_clients * down_rep.total_bits
        h_new = jax.tree_util.tree_map(
            lambda hc, xh, xb: (hc + (fed.p / fed.gamma)
                                * (xb[None] - xh).astype(hc.dtype)),
            h, x_hat, x_bar)
        params_new = jax.tree_util.tree_map(
            lambda xb, xh: jnp.broadcast_to(xb[None], xh.shape).astype(
                xh.dtype), x_bar, x_hat)
        return (params_new, h_new, loss_sum / fed.local_steps,
                comm_bits.astype(jnp.float32))

    key_struct = S((2,), jnp.uint32)
    return StepBundle(
        fn=fed_round,
        args=(params_struct, h_struct, batch, key_struct),
        in_shardings=(pshard, pshard, bshard, NamedSharding(mesh, P())),
        out_shardings=(pshard, pshard, NamedSharding(mesh, P()),
                       NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )


def _strip_pod(mesh: Mesh) -> Mesh:
    """A (data, model) view of the per-pod sub-mesh for inner sharding rules."""
    import numpy as np
    devs = mesh.devices[0] if mesh.devices.ndim == 3 else mesh.devices
    return Mesh(np.asarray(devs), ("data", "model"))
