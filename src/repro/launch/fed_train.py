"""FedComLoc as a first-class multi-pod training feature (DESIGN.md §2).

Pod-as-client mapping: each pod of the (pod, data, model) production mesh is
one federated client.  Parameters and control variates carry a leading
``n_clients`` axis sharded over ``pod`` — within a pod they shard FSDP x TP
exactly like the plain trainer.  One *round* is a single jitted function:

  1. L local steps (lax.scan): x_i <- x_i - gamma * (grad_i - h_i), each pod
     touching only its own shard of the batch — **no cross-pod traffic**;
  2. communication (theta = 1): the uplink iterate is compressed (TopK /
     Q_r), the cross-pod mean is one all-reduce over ``pod`` (the mean over
     the leading client axis), and the control variates absorb the skip
     correction h_i += (p/gamma)(x_bar - x^_i).

The only cross-pod collective per round is the (compressed) parameter
average — this is exactly the paper's communication pattern: ProxSkip's
"skip the sync w.p. 1-p" becomes "skip the cross-pod collective", TopK/Q_r
shrink the payload of the one that happens.

TopK at 10^9-parameter scale uses per-tensor *threshold* masking (the kth
magnitude via jnp.quantile on |w|) rather than an explicit top_k sort — the
Pallas radix-select kernel implements the same threshold semantics exactly
on TPU; see kernels/topk_compress.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, InputShape
from repro.launch.steps import StepBundle, _n_experts, _params_struct
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.sharding import specs as sh

PyTree = Any
S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class FedTrainConfig:
    gamma: float = 3e-4
    p: float = 0.1
    local_steps: int = 10           # = round(1/p)
    compressor: str = "topk"        # topk | quant | none
    density: float = 0.1            # topk density
    quant_bits: int = 8
    variant: str = "com"            # com | global | local | none
    # "int8": the cross-pod sync moves an int8 payload (levels) + per-tensor
    # scales — the HLO collective shrinks 4x vs syncing dense f32/bf16
    # (jax dense collectives otherwise move full-width zeros; §Perf H3).
    # Requires compressor="quant" with quant_bits <= 7 magnitude bits.
    sync_mode: str = "dense"        # dense | int8


# --------------------------------------------------------------------------- #
# scalable compression ops (pytree, vmap-safe)
# --------------------------------------------------------------------------- #

def _threshold_topk(x: jax.Array, density: float) -> jax.Array:
    """Keep |x| >= (1-density)-quantile of |x| — threshold TopK semantics."""
    if density >= 1.0:
        return x
    mag = jnp.abs(x.astype(jnp.float32))
    thr = jnp.quantile(mag.reshape(-1), 1.0 - density)
    return jnp.where(mag >= thr, x, jnp.zeros_like(x))


def _quantize(x: jax.Array, bits: int, key: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(xf * xf))
    safe = jnp.where(norm > 0, norm, 1.0)
    levels = float(2 ** bits)
    y = jnp.abs(xf) / safe
    lo = jnp.floor(levels * y)
    frac = levels * y - lo
    u = jax.random.uniform(key, x.shape, jnp.float32)
    xi = (lo + (u < frac)) / levels
    return (norm * jnp.sign(xf) * xi).astype(x.dtype)


def compress_tree(tree: PyTree, cfg: FedTrainConfig,
                  key: jax.Array) -> PyTree:
    if cfg.compressor == "none":
        return tree
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    if cfg.compressor == "topk":
        new = [_threshold_topk(l, cfg.density) for l in leaves]
    elif cfg.compressor == "quant":
        new = [_quantize(l, cfg.quant_bits, k) for l, k in zip(leaves, keys)]
    else:
        raise ValueError(cfg.compressor)
    return jax.tree_util.tree_unflatten(treedef, new)


def compressed_bits(tree: PyTree, cfg: FedTrainConfig) -> float:
    n = sum(x.size for x in jax.tree_util.tree_leaves(tree))
    if cfg.compressor == "topk":
        return cfg.density * n * 64.0
    if cfg.compressor == "quant":
        return n * (1 + cfg.quant_bits)
    return n * 32.0


# --------------------------------------------------------------------------- #
# the federated round
# --------------------------------------------------------------------------- #

def build_fed_round(spec: ArchSpec, shape: InputShape, mesh: Mesh,
                    fed: FedTrainConfig) -> StepBundle:
    """One FedComLoc round over the pod axis as a single jitted step."""
    if "pod" not in mesh.axis_names:
        raise ValueError("fed_train requires a multi-pod mesh")
    n_clients = mesh.shape["pod"]
    m = spec.model
    b_local = shape.global_batch // n_clients

    params1 = _params_struct(spec)
    stack = lambda leaf_sh: jax.tree_util.tree_map(
        lambda l: S((n_clients,) + l.shape, l.dtype), leaf_sh)
    params_struct = stack(params1)
    h_struct = stack(params1)

    # shardings: leading client axis over pod, inner dims per the plain rules
    inner = sh.param_shardings(params1, _strip_pod(mesh),
                               n_experts=_n_experts(spec))

    def lift(ns: NamedSharding) -> NamedSharding:
        return NamedSharding(mesh, P("pod", *ns.spec))

    pshard = jax.tree_util.tree_map(lift, inner)

    if spec.is_encdec:
        t_src = shape.seq_len // 2
        t_tgt = shape.seq_len - t_src
        batch = {"src_embeds": S((n_clients, b_local, t_src, m.d_model),
                                 jnp.bfloat16),
                 "tgt_tokens": S((n_clients, b_local, t_tgt), jnp.int32)}
        bshard = {"src_embeds": NamedSharding(
            mesh, P("pod", "data", None, None)),
            "tgt_tokens": NamedSharding(mesh, P("pod", "data", None))}

        def loss_fn(p, batch_):
            return encdec_mod.loss(p, m, batch_["src_embeds"],
                                   batch_["tgt_tokens"], loss_chunk=512)
    else:
        npre = spec.n_prefix_tokens
        batch = {"tokens": S((n_clients, b_local, shape.seq_len - npre),
                             jnp.int32)}
        bshard = {"tokens": NamedSharding(mesh, P("pod", "data", None))}
        if npre:
            batch["prefix_embeds"] = S(
                (n_clients, b_local, npre, m.d_model), jnp.bfloat16)
            bshard["prefix_embeds"] = NamedSharding(
                mesh, P("pod", "data", None, None))

        def loss_fn(p, batch_):
            return tfm.loss(p, m, batch_["tokens"],
                            prefix_embeds=batch_.get("prefix_embeds"),
                            loss_chunk=512)

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    def fed_round(params, h, batch_, key):
        # --- local phase: L steps, zero cross-pod traffic ----------------- #
        def local_step(carry, k_step):
            x, loss_acc = carry
            x_eval = x
            if fed.variant == "local":
                x_eval = jax.vmap(
                    lambda t_, k_: compress_tree(t_, fed, k_))(
                    x, jax.random.split(k_step, n_clients))
            loss, g = grad_fn(x_eval, batch_)
            x = jax.tree_util.tree_map(
                lambda xc, gc, hc: (xc - fed.gamma
                                    * (gc - hc.astype(gc.dtype))
                                    ).astype(xc.dtype), x, g, h)
            return (x, loss_acc + loss.mean()), None

        keys = jax.random.split(key, fed.local_steps + 2)
        (x_hat, loss_sum), _ = jax.lax.scan(
            local_step, (params, jnp.zeros(())), keys[:fed.local_steps])

        # --- communication round (theta = 1) ------------------------------ #
        if fed.variant == "com" and fed.sync_mode == "int8":
            # quantize to an int8 payload: level index * sign in [-2^r, 2^r],
            # one f32 scale (norm / 2^r) per tensor.  The cross-pod gather
            # moves int8; dequant + mean are pod-local.
            levels = float(2 ** fed.quant_bits)
            up_keys = jax.random.split(keys[-1], n_clients)

            def enc(tree, key_):
                ls, treedef = jax.tree_util.tree_flatten(tree)
                ks_ = jax.random.split(key_, len(ls))
                payload, scales = [], []
                for leaf, k_ in zip(ls, ks_):
                    xf = leaf.astype(jnp.float32)
                    norm = jnp.sqrt(jnp.sum(xf * xf))
                    safe = jnp.where(norm > 0, norm, 1.0)
                    y = jnp.abs(xf) / safe
                    lo = jnp.floor(levels * y)
                    frac = levels * y - lo
                    u = jax.random.uniform(k_, leaf.shape, jnp.float32)
                    q = (lo + (u < frac)) * jnp.sign(xf)
                    payload.append(jnp.clip(q, -127, 127).astype(jnp.int8))
                    scales.append(norm / levels)
                return (jax.tree_util.tree_unflatten(treedef, payload),
                        jax.tree_util.tree_unflatten(treedef, scales))

            payload, scales = jax.vmap(enc)(x_hat, up_keys)
            # gather over `pod` ONLY (keep within-pod FSDP/TP sharding):
            # the wire collective is an int8 cross-pod all-gather.
            payload = jax.tree_util.tree_map(
                lambda t_, ns: jax.lax.with_sharding_constraint(
                    t_, P(None, *ns.spec[1:])), payload, pshard)
            x_bar = jax.tree_util.tree_map(
                lambda q_, s_, xh: (q_.astype(jnp.float32)
                                    * s_.reshape((-1,) + (1,) * (q_.ndim - 1))
                                    ).mean(axis=0).astype(xh.dtype),
                payload, scales, x_hat)
            x_hat = jax.tree_util.tree_map(
                lambda q_, s_, xh: (q_.astype(jnp.float32)
                                    * s_.reshape((-1,) + (1,) * (q_.ndim - 1))
                                    ).astype(xh.dtype),
                payload, scales, x_hat)
        else:
            if fed.variant == "com":
                x_hat = jax.vmap(lambda t_, k_: compress_tree(t_, fed, k_))(
                    x_hat, jax.random.split(keys[-1], n_clients))
            x_bar = jax.tree_util.tree_map(
                lambda t_: t_.mean(axis=0), x_hat)      # cross-pod all-reduce
        if fed.variant == "global":
            x_bar = compress_tree(x_bar, fed, keys[-2])
        h_new = jax.tree_util.tree_map(
            lambda hc, xh, xb: (hc + (fed.p / fed.gamma)
                                * (xb[None] - xh).astype(hc.dtype)),
            h, x_hat, x_bar)
        params_new = jax.tree_util.tree_map(
            lambda xb, xh: jnp.broadcast_to(xb[None], xh.shape).astype(
                xh.dtype), x_bar, x_hat)
        return params_new, h_new, loss_sum / fed.local_steps

    key_struct = S((2,), jnp.uint32)
    return StepBundle(
        fn=fed_round,
        args=(params_struct, h_struct, batch, key_struct),
        in_shardings=(pshard, pshard, bshard, NamedSharding(mesh, P())),
        out_shardings=(pshard, pshard, NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )


def _strip_pod(mesh: Mesh) -> Mesh:
    """A (data, model) view of the per-pod sub-mesh for inner sharding rules."""
    import numpy as np
    devs = mesh.devices[0] if mesh.devices.ndim == 3 else mesh.devices
    return Mesh(np.asarray(devs), ("data", "model"))
