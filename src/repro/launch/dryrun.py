import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)).

Lowers + compiles every (architecture x input shape) step on the production
meshes — 16x16 single-pod and 2x16x16 multi-pod — against ShapeDtypeStruct
inputs (no allocation), then records

* ``memory_analysis()``  — proves the program fits per-device HBM,
* ``cost_analysis()``    — HLO FLOPs / bytes for the roofline,
* collective bytes parsed from the HLO (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute operand sizes),

into JSON artifacts under ``benchmarks/artifacts/dryrun/`` that
benchmarks/roofline.py consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, get_spec
from repro.configs.base import ALL_SHAPES, SHAPES
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "f8": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """bytes of an HLO shape string like 'bf16[16,1024,128]{2,1,0}'."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO, by kind."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+(\w[\w-]*)\(",
                     ls)
        if not m:
            continue
        op = m.group(2)
        if op.replace("_", "-") in _COLLECTIVES:
            kind = op.replace("_", "-")
            out[kind] += _shape_bytes(m.group(1))
            out["count"] += 1
    return out


def _train_cost_extrapolation(spec, shape_name: str, mesh) -> dict:
    """Accurate train-step costs via depth extrapolation.

    ``cost_analysis`` counts a lax.scan body ONCE regardless of trip count,
    so the production scan-over-layers compile under-reports FLOPs/bytes by
    ~n_layers/cycle.  Costs are linear in depth, so we compile two small
    *unrolled* variants (L1 = cycle, L2 = 2*cycle) and extrapolate to the
    full depth.  (Verified: the unrolled qwen2-0.5b full compile matches the
    analytic 6ND within 2%.)
    """
    import dataclasses as dc

    from repro.launch import steps as steps_mod_
    from repro.models.transformer import _effective_cycle

    m = spec.model
    if spec.is_encdec:
        l_full = m.n_enc_layers  # enc and dec scale together
        l1, l2 = 1, 2
        mk = lambda k: dc.replace(
            spec, model=dc.replace(m, n_enc_layers=k, n_dec_layers=k,
                                   scan_layers=False))
    else:
        cyc = _effective_cycle(m)
        l1, l2 = cyc, 2 * cyc
        l_full = m.n_layers
        mk = lambda k: dc.replace(
            spec, model=dc.replace(m, n_layers=k, scan_layers=False))

    def costs(k: int):
        bundle = steps_mod_.build_step(mk(k), shape_name, mesh)
        with mesh:
            comp = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                           out_shardings=bundle.out_shardings,
                           donate_argnums=bundle.donate_argnums
                           ).lower(*bundle.args).compile()
        ca = comp.cost_analysis()
        coll = collective_bytes(comp.as_text())
        return (float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)),
                {k_: float(v) for k_, v in coll.items()})

    f1, b1, c1 = costs(l1)
    f2, b2, c2 = costs(l2)
    scale = (l_full - l1) / (l2 - l1)
    coll = {k_: c1[k_] + (c2[k_] - c1[k_]) * scale for k_ in c1}
    return {
        "flops": f1 + (f2 - f1) * scale,
        "bytes_accessed": b1 + (b2 - b1) * scale,
        "collective_bytes": coll,
        "method": f"depth-extrapolated unrolled L={l1},{l2} -> {l_full}",
    }


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            verbose: bool = True, step_override=None) -> dict:
    spec = get_spec(arch)
    if not spec.runs(shape_name):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": spec.skip_reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    bundle = (step_override or steps_mod.build_step)(spec, shape_name, mesh)
    with mesh:
        jitted = jax.jit(bundle.fn,
                         in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    cost_method = "direct"
    if shape_name == "train_4k" and step_override is None:
        # layer-scan bodies are cost-counted once; use depth extrapolation
        extra = _train_cost_extrapolation(get_spec(arch), shape_name, mesh)
        cost = {"flops": extra["flops"],
                "bytes accessed": extra["bytes_accessed"]}
        coll = extra["collective_bytes"]
        cost_method = extra["method"]
    n_dev = mesh.devices.size
    rec = {
        "cost_method": cost_method,
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "n_devices": int(n_dev),
        "compile_s": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "per_device_memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        "collective_bytes": coll,
        "model": {
            "num_params": int(spec.model.num_params()),
            "active_params": int(spec.model.active_params()),
        },
    }
    if verbose:
        ma = rec["per_device_memory"]
        live = ma["argument_bytes"] + ma["output_bytes"] + ma["temp_bytes"] \
            - ma["alias_bytes"]
        print(f"[{rec['mesh']}] {arch:28s} {shape_name:12s} "
              f"flops/dev={rec['flops']:.3e} "
              f"coll={sum(coll[k] for k in _COLLECTIVES)/1e9:.2f}GB "
              f"hbm/dev={live/2**30:.2f}GiB "
              f"(args {ma['argument_bytes']/2**30:.2f} + tmp "
              f"{ma['temp_bytes']/2**30:.2f}) "
              f"compile={rec['compile_s']}s")
    return rec


def collective_bytes_by_scope(hlo_text: str, pod_size: int = 256) -> dict:
    """Split collective bytes into cross-pod vs within-pod.

    A collective whose replica group contains device ids on both sides of
    the pod boundary rides the inter-pod (DCN/slow) link — the one the
    paper's compression targets.
    """
    out = {"cross_pod": 0, "within_pod": 0}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+(\w[\w-]*)\(",
                     ls)
        if not m or m.group(2).replace("_", "-") not in _COLLECTIVES:
            continue
        nbytes = _shape_bytes(m.group(1))
        gm = re.search(r"replica_groups=\{?\[?([\d,{} ]+)", ls)
        cross = False
        if gm:
            # first group's ids decide (groups are homogeneous)
            ids = [int(t) for t in re.findall(r"\d+", gm.group(1))[:64]]
            if ids and (min(ids) // pod_size) != (max(ids) // pod_size):
                cross = True
        out["cross_pod" if cross else "within_pod"] += nbytes
    return out


def run_fed(arch: str, *, verbose: bool = True) -> dict:
    """Lower + compile one federated (pod-as-client) FedComLoc round of the
    full-size architecture on the 2x16x16 mesh — the paper's technique at
    production scale.  train_4k shape; TopK-Com compression."""
    from repro.configs.base import SHAPES
    from repro.launch import fed_train

    spec = get_spec(arch)
    mesh = make_production_mesh(multi_pod=True)
    fed = fed_train.FedTrainConfig(local_steps=10, compressor="topk",
                                   density=0.1)
    t0 = time.time()
    bundle = fed_train.build_fed_round(spec, SHAPES["train_4k"], mesh, fed)
    with mesh:
        lowered = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings,
                          donate_argnums=bundle.donate_argnums
                          ).lower(*bundle.args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    rec = {
        "arch": arch, "shape": "fed_round_train_4k", "mesh": "2x16x16",
        "status": "ok", "n_devices": 512,
        "compile_s": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "per_device_memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        "collective_bytes": coll,
        "model": {"num_params": int(spec.model.num_params()),
                  "active_params": int(spec.model.active_params())},
        "fed": {"local_steps": 10, "compressor": "topk", "density": 0.1},
    }
    if verbose:
        print(f"[fed 2x16x16] {arch:28s} "
              f"flops/dev={rec['flops']:.3e} "
              f"coll={sum(coll[k] for k in _COLLECTIVES)/1e9:.2f}GB "
              f"tmp/dev={rec['per_device_memory']['temp_bytes']/2**30:.1f}GiB "
              f"compile={rec['compile_s']}s")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=ALL_SHAPES)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fed", action="store_true",
                    help="lower the federated pod-as-client round instead")
    ap.add_argument("--resume", action="store_true",
                    help="skip combos whose artifact is already ok/skipped")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.fed:
        archs = [args.arch] if args.arch else list(ARCH_IDS)
        ART_DIR.mkdir(parents=True, exist_ok=True)
        results = []
        for a in archs:
            try:
                rec = run_fed(a)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                rec = {"arch": a, "shape": "fed_round_train_4k",
                       "status": "error",
                       "error": f"{type(e).__name__}: {e}"}
            results.append(rec)
            (ART_DIR / f"{a}__fed_round__multipod.json").write_text(
                json.dumps(rec, indent=2))
        err = sum(r["status"] == "error" for r in results)
        print(f"\nfed dry-run: {len(results) - err} ok, {err} errors")
        if err:
            raise SystemExit(1)
        return

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in ALL_SHAPES:
                combos.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    ART_DIR.mkdir(parents=True, exist_ok=True)
    results = []
    for a, s in combos:
        tag_ = "multipod" if args.multi_pod else "singlepod"
        art = ART_DIR / f"{a}__{s}__{tag_}.json"
        if args.resume and art.exists():
            prev = json.loads(art.read_text())
            if prev.get("status") in ("ok", "skipped"):
                results.append(prev)
                continue
        try:
            rec = run_one(a, s, multi_pod=args.multi_pod)
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            rec = {"arch": a, "shape": s, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
        results.append(rec)
        tag = "multipod" if args.multi_pod else "singlepod"
        path = ART_DIR / f"{a}__{s}__{tag}.json"
        path.write_text(json.dumps(rec, indent=2))

    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {ok} ok, {skip} skipped, {err} errors "
          f"/ {len(results)} combos")
    if args.out:
        Path(args.out).write_text(json.dumps(results, indent=2))
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
