"""Out-of-core per-client state store (DESIGN.md §11).

Every algorithm with persistent per-client state — Scaffold/FedDyn control
variates, FedComLoc's EF memory, LoCoDL's per-client iterates — used to
stack that state ``(n_clients, ...)`` on device, capping the simulated
population at what fits in device memory.  This module owns the state
instead, behind one cohort-row contract every ``_round_impl`` writes
against:

* ``init_slot(name, template, n_clients, init)`` — allocate a named slot
  at algorithm ``init`` time; the returned value is what the algorithm
  puts in its state NamedTuple;
* ``gather(name, slot, idx)`` — the sampled cohort's rows, on device, at
  round start;
* ``scatter(name, slot, idx, rows, ctx)`` — write the cohort's updated
  rows back at round end; returns the new slot value for the next state.

Two backends:

* :class:`InMemoryStore` (the default) — the slot IS the stacked device
  array; ``gather``/``scatter`` emit *exactly* the gather/scatter ops the
  round bodies used to inline (``t[idx]`` / ``ctx.scatter_rows``), so the
  in-memory path is bit-identical to the historical stacked-state
  behaviour, works under every §6/§9 mesh, and checkpoints through the
  state tree unchanged.

* :class:`HostStore` — rows live host-side in numpy buffers (optionally
  ``np.memmap`` files under ``mmap_dir``, so the population can exceed
  host RAM too); the slot is an int32 *version token* and
  ``gather``/``scatter`` cross the jit boundary through **ordered**
  ``io_callback``\\ s, which sequence correctly inside the fused
  ``lax.scan`` engine (scatter of round r happens-before gather of round
  r+1).  Buffers are **lazily materialised**: allocation writes one fill
  row plus a ``touched`` bitmap, and a gather reads only rows previously
  scattered (everything else is served from the fill row) — so a
  million-client slot that has only ever seen 64-client cohorts costs
  64·rounds rows of host memory, not ``n_clients`` (``init="broadcast"``
  — LoCoDL's ``xs`` — never materialises the broadcast at all).  Device
  memory holds cohort rows only.  The backend is host-side by nature and
  cannot run inside ``shard_map`` meshes (``RoundEngine.use_mesh``
  rejects the combination).
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

INIT_MODES = ("zeros", "broadcast")


class ClientStore:
    """The cohort-row contract round implementations write against."""

    #: True if gather/scatter cross the jit boundary via host callbacks —
    #: incompatible with shard_map meshes (RoundEngine.use_mesh checks).
    host_side: bool = False

    def init_slot(self, name: str, template: PyTree, n_clients: int,
                  init: str = "zeros") -> PyTree:
        raise NotImplementedError

    def gather(self, name: str, slot: PyTree, idx: jax.Array) -> PyTree:
        raise NotImplementedError

    def scatter(self, name: str, slot: PyTree, idx: jax.Array,
                rows: PyTree, ctx) -> PyTree:
        raise NotImplementedError


class InMemoryStore(ClientStore):
    """Stacked-device-array backend: the slot is the ``(n, ...)`` tree.

    Every method emits exactly the op the round bodies historically
    inlined, so this backend reproduces the pre-store graphs (and hence
    trajectories, goldens, and checkpoints) byte-for-byte.
    """

    def init_slot(self, name: str, template: PyTree, n_clients: int,
                  init: str = "zeros") -> PyTree:
        if init not in INIT_MODES:
            raise ValueError(f"init must be one of {INIT_MODES}")
        if init == "broadcast":
            return jax.tree_util.tree_map(
                lambda p: jnp.broadcast_to(p, (n_clients,) + p.shape),
                template)
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_clients,) + p.shape, p.dtype), template)

    def gather(self, name: str, slot: PyTree, idx: jax.Array) -> PyTree:
        return jax.tree_util.tree_map(lambda t: t[idx], slot)

    def scatter(self, name: str, slot: PyTree, idx: jax.Array,
                rows: PyTree, ctx) -> PyTree:
        return ctx.scatter_rows(slot, idx, rows)


def _disable_async_dispatch() -> None:
    """Ordered host callbacks + JAX's async CPU dispatch can deadlock.

    On CPU, jax dispatches computations asynchronously on a background
    thread; a program with several ordered ``io_callback``\\ s moving
    large buffers can then deadlock inside the runtime (readily reproduced
    on 1-core hosts under jax 0.4.37 — the first such program hangs
    forever, racily).  Synchronous dispatch is the documented remedy and
    costs nothing here: every HostStore round already round-trips to the
    host, so there is no dispatch pipeline left to overlap.  Flipped once,
    at first HostStore construction, so in-memory runs keep the default.
    """
    try:
        jax.config.update("jax_cpu_enable_async_dispatch", False)
    except AttributeError:     # older jax without the flag: best effort
        pass


@dataclasses.dataclass
class _HostSlot:
    """One named slot's host-side storage."""

    leaves: List[np.ndarray]          # (n, ...) buffers (numpy or memmap)
    fill: List[np.ndarray]            # one (...) fill row per leaf
    touched: np.ndarray               # (n,) bool — rows ever scattered
    treedef: Any
    row_structs: List[jax.ShapeDtypeStruct]


class HostStore(ClientStore):
    """Host-memory (optionally memory-mapped) backend.

    ``mmap_dir`` spools each leaf buffer to a ``np.memmap`` file under
    that directory (created sparse — untouched rows cost no disk), so the
    population can exceed host RAM as well as device memory.
    """

    host_side = True

    def __init__(self, mmap_dir: Optional[str | Path] = None):
        _disable_async_dispatch()
        self._mmap_dir = Path(mmap_dir) if mmap_dir is not None else None
        self._slots: Dict[str, _HostSlot] = {}
        # host-side telemetry for benchmarks: bytes actually moved
        self.bytes_gathered = 0
        self.bytes_scattered = 0

    # -- allocation ------------------------------------------------------ #

    def _alloc(self, name: str, i: int, shape, dtype) -> np.ndarray:
        if self._mmap_dir is None:
            # calloc'd pages: untouched rows stay zero-page-backed, and
            # the touched bitmap keeps gathers from ever faulting them in
            return np.zeros(shape, dtype)
        self._mmap_dir.mkdir(parents=True, exist_ok=True)
        path = self._mmap_dir / f"{name}.leaf_{i}.mm"
        return np.memmap(path, dtype=dtype, mode="w+", shape=shape)

    def init_slot(self, name: str, template: PyTree, n_clients: int,
                  init: str = "zeros") -> jax.Array:
        if init not in INIT_MODES:
            raise ValueError(f"init must be one of {INIT_MODES}")
        leaves, treedef = jax.tree_util.tree_flatten(template)
        bufs, fills, structs = [], [], []
        for i, leaf in enumerate(leaves):
            leaf = np.asarray(leaf)
            bufs.append(self._alloc(name, i, (n_clients,) + leaf.shape,
                                    leaf.dtype))
            # the fill row serves every never-scattered gather, so a
            # "broadcast" init never writes n_clients copies of the model
            fills.append(leaf.copy() if init == "broadcast"
                         else np.zeros(leaf.shape, leaf.dtype))
            structs.append(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype))
        self._slots[name] = _HostSlot(
            leaves=bufs, fill=fills,
            touched=np.zeros((n_clients,), bool),
            treedef=treedef, row_structs=structs)
        # the slot value is a version token: an int32 the scatter bumps,
        # giving the state tree a real (checkpointable) leaf and the
        # engine's scan carry a data dependence on top of the ordered-
        # effect sequencing
        return jnp.zeros((), jnp.int32)

    # -- host-side row movement ------------------------------------------ #

    def _gather_host(self, name: str, idx: np.ndarray) -> List[np.ndarray]:
        slot = self._slots[name]
        idx = np.asarray(idx)
        t = slot.touched[idx]
        out = []
        for buf, fill in zip(slot.leaves, slot.fill):
            rows = np.empty((idx.shape[0],) + fill.shape, fill.dtype)
            # read ONLY previously-scattered rows: untouched rows come
            # from the fill row without faulting buffer pages in
            rows[:] = fill
            if t.any():
                rows[t] = buf[idx[t]]
            out.append(rows)
            self.bytes_gathered += rows.nbytes
        return out

    def _scatter_host(self, name: str, idx: np.ndarray,
                      leaves: List[np.ndarray]) -> None:
        slot = self._slots[name]
        idx = np.asarray(idx)
        for buf, rows in zip(slot.leaves, leaves):
            buf[idx] = rows
            self.bytes_scattered += rows.nbytes
        slot.touched[idx] = True

    # -- the in-graph contract ------------------------------------------- #

    def gather(self, name: str, slot: jax.Array, idx: jax.Array) -> PyTree:
        from jax.experimental import io_callback
        hs = self._slots[name]
        s = idx.shape[0]
        shapes = [jax.ShapeDtypeStruct((s,) + r.shape, r.dtype)
                  for r in hs.row_structs]

        def cb(idx_h, _token):
            return tuple(self._gather_host(name, idx_h))

        rows = io_callback(cb, tuple(shapes), idx, slot, ordered=True)
        return jax.tree_util.tree_unflatten(hs.treedef, list(rows))

    def scatter(self, name: str, slot: jax.Array, idx: jax.Array,
                rows: PyTree, ctx) -> jax.Array:
        from jax.experimental import io_callback
        leaves, treedef = jax.tree_util.tree_flatten(rows)
        hs = self._slots[name]
        if treedef != hs.treedef:
            raise ValueError(
                f"scatter to slot {name!r} with mismatched tree structure")

        def cb(idx_h, *leaves_h):
            self._scatter_host(name, idx_h, list(leaves_h))
            return np.zeros((), np.int32)

        io_callback(cb, jax.ShapeDtypeStruct((), jnp.int32), idx, *leaves,
                    ordered=True)
        return slot + 1

    # -- persistence (checkpoint-resume) --------------------------------- #

    def state_dict(self) -> dict:
        """The store's full host state as one nested-dict pytree, ready
        for ``repro.checkpoint.save``.  Buffers are materialised dense —
        checkpointing is for resumable *experiments*, not for spooling a
        million-client population (keep ``mmap_dir`` for that)."""
        out = {}
        for name, slot in self._slots.items():
            out[name] = {
                "touched": slot.touched.copy(),
                "fill": {f"leaf_{i}": f.copy()
                         for i, f in enumerate(slot.fill)},
                "data": {f"leaf_{i}": np.asarray(buf).copy()
                         for i, buf in enumerate(slot.leaves)},
            }
        return out

    def load_state_dict(self, d: dict) -> None:
        """Restore buffers saved by :meth:`state_dict` into the slots
        registered by ``init_slot`` (call the algorithm's ``init`` first —
        it defines the slot names/shapes this fills)."""
        for name, payload in d.items():
            if name not in self._slots:
                raise KeyError(
                    f"state_dict slot {name!r} was never registered; call "
                    "the algorithm's init() before load_state_dict()")
            slot = self._slots[name]
            slot.touched[:] = np.asarray(payload["touched"])
            for i in range(len(slot.leaves)):
                slot.fill[i][...] = np.asarray(payload["fill"][f"leaf_{i}"])
                slot.leaves[i][...] = np.asarray(payload["data"][f"leaf_{i}"])


def resolve_store(store: Optional[ClientStore]) -> ClientStore:
    """Default + type-check the ``store=`` argument every algorithm takes."""
    if store is None:
        return InMemoryStore()
    if not isinstance(store, ClientStore):
        raise TypeError(
            f"store must be a ClientStore, got {type(store).__name__}")
    return store
