"""Out-of-core per-client state store (DESIGN.md §11).

Every algorithm with persistent per-client state — Scaffold/FedDyn control
variates, FedComLoc's EF memory, LoCoDL's per-client iterates — used to
stack that state ``(n_clients, ...)`` on device, capping the simulated
population at what fits in device memory.  This module owns the state
instead, behind one cohort-row contract every ``_round_impl`` writes
against:

* ``init_slot(name, template, n_clients, init)`` — allocate a named slot
  at algorithm ``init`` time; the returned value is what the algorithm
  puts in its state NamedTuple;
* ``gather(name, slot, idx)`` — the sampled cohort's rows, on device, at
  round start;
* ``scatter(name, slot, idx, rows, ctx)`` — write the cohort's updated
  rows back at round end; returns the new slot value for the next state.

Two backends:

* :class:`InMemoryStore` (the default) — the slot IS the stacked device
  array; ``gather``/``scatter`` emit *exactly* the gather/scatter ops the
  round bodies used to inline (``t[idx]`` / ``ctx.scatter_rows``), so the
  in-memory path is bit-identical to the historical stacked-state
  behaviour, works under every §6/§9 mesh, and checkpoints through the
  state tree unchanged.

* :class:`HostStore` — rows live host-side in numpy buffers (optionally
  ``np.memmap`` files under ``mmap_dir``, so the population can exceed
  host RAM too); the slot is an int32 *version token* and
  ``gather``/``scatter`` cross the jit boundary through **ordered**
  ``io_callback``\\ s, which sequence correctly inside the fused
  ``lax.scan`` engine (scatter of round r happens-before gather of round
  r+1).  Buffers are **lazily materialised**: allocation writes one fill
  row plus a ``touched`` bitmap, and a gather reads only rows previously
  scattered (everything else is served from the fill row) — so a
  million-client slot that has only ever seen 64-client cohorts costs
  64·rounds rows of host memory, not ``n_clients`` (``init="broadcast"``
  — LoCoDL's ``xs`` — never materialises the broadcast at all).  Device
  memory holds cohort rows only.  The backend is host-side by nature and
  cannot run inside ``shard_map`` meshes (``RoundEngine.use_mesh``
  rejects the combination).

``HostStore(prefetch=True)`` (DESIGN.md §12) takes the host off the
critical path.  The plain store does all row movement *inside* the
ordered callbacks, serializing host I/O against device compute.  With
prefetching, a background worker owns the buffers between rounds:

* **write-behind scatter** — the scatter callback only copies the
  cohort's rows and enqueues them; the worker applies them to the
  buffers (and memmap files) while the device runs the next round's
  compute.  This also removes the large-buffer writes from the XLA
  callback thread, where they can deadlock the single-threaded CPU
  runtime (see :func:`_disable_async_dispatch`);
* **cohort prefetch** — ``submit_cohort_plan`` hands the store the
  round-by-round cohort index schedule (the engine derives it from the
  key chain before launching the scan); after applying round t's
  scatter for a slot, the worker immediately stages round t+1's rows,
  so the gather callback usually just hands over a staged buffer;
* **hazard rules** — the ordered callbacks remain the commit point: a
  gather that misses the staging buffer (mispredicted plan) drains the
  write-behind queue (a *flush stall*) and reads synchronously; a
  scatter whose index set overlaps a staged entry invalidates it (a
  *RAW hazard*); a stage that raced an apply to the same slot is
  discarded unpublished.  Every served row therefore equals what the
  plain store would have read at the same point in the ordered-effect
  sequence — the pipelined store is **bit-identical** to the plain one
  (the plan is purely a performance hint).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

INIT_MODES = ("zeros", "broadcast")


class ClientStore:
    """The cohort-row contract round implementations write against."""

    #: True if gather/scatter cross the jit boundary via host callbacks —
    #: incompatible with shard_map meshes (RoundEngine.use_mesh checks).
    host_side: bool = False

    def init_slot(self, name: str, template: PyTree, n_clients: int,
                  init: str = "zeros") -> PyTree:
        raise NotImplementedError

    def gather(self, name: str, slot: PyTree, idx: jax.Array) -> PyTree:
        raise NotImplementedError

    def scatter(self, name: str, slot: PyTree, idx: jax.Array,
                rows: PyTree, ctx) -> PyTree:
        raise NotImplementedError


class InMemoryStore(ClientStore):
    """Stacked-device-array backend: the slot is the ``(n, ...)`` tree.

    Every method emits exactly the op the round bodies historically
    inlined, so this backend reproduces the pre-store graphs (and hence
    trajectories, goldens, and checkpoints) byte-for-byte.
    """

    def init_slot(self, name: str, template: PyTree, n_clients: int,
                  init: str = "zeros") -> PyTree:
        if init not in INIT_MODES:
            raise ValueError(f"init must be one of {INIT_MODES}")
        if init == "broadcast":
            return jax.tree_util.tree_map(
                lambda p: jnp.broadcast_to(p, (n_clients,) + p.shape),
                template)
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_clients,) + p.shape, p.dtype), template)

    def gather(self, name: str, slot: PyTree, idx: jax.Array) -> PyTree:
        return jax.tree_util.tree_map(lambda t: t[idx], slot)

    def scatter(self, name: str, slot: PyTree, idx: jax.Array,
                rows: PyTree, ctx) -> PyTree:
        return ctx.scatter_rows(slot, idx, rows)


def _disable_async_dispatch() -> None:
    """Ordered host callbacks + JAX's async CPU dispatch can deadlock.

    On CPU, jax dispatches computations asynchronously on a background
    thread; a program with several ordered ``io_callback``\\ s moving
    large buffers can then deadlock inside the runtime (readily reproduced
    on 1-core hosts under jax 0.4.37 — the first such program hangs
    forever, racily).  Synchronous dispatch is the documented remedy and
    costs nothing here: every HostStore round already round-trips to the
    host, so there is no dispatch pipeline left to overlap.  Flipped once,
    at first HostStore construction, so in-memory runs keep the default.
    """
    try:
        jax.config.update("jax_cpu_enable_async_dispatch", False)
    except AttributeError:     # older jax without the flag: best effort
        pass


@dataclasses.dataclass
class _HostSlot:
    """One named slot's host-side storage."""

    leaves: List[np.ndarray]          # (n, ...) buffers (numpy or memmap)
    fill: List[np.ndarray]            # one (...) fill row per leaf
    touched: np.ndarray               # (n,) bool — rows ever scattered
    treedef: Any
    row_structs: List[jax.ShapeDtypeStruct]
    # write fds for memmap leaves (None per RAM leaf): scatters go through
    # pwrite into the backing file — page-cache-coherent with the mapping,
    # but ONE syscall per row instead of a storm of first-touch page
    # faults, each of which can park the writing thread behind a spinning
    # compute thread for a full timeslice (measured 1000x slower on a
    # busy single-core host)
    fds: List[Optional[int]] = dataclasses.field(default_factory=list)


class HostStore(ClientStore):
    """Host-memory (optionally memory-mapped) backend.

    ``mmap_dir`` spools each leaf buffer to a ``np.memmap`` file under
    that directory (created sparse — untouched rows cost no disk), so the
    population can exceed host RAM as well as device memory.

    ``prefetch=True`` adds the §12 pipelining layer: write-behind
    scatters and plan-driven cohort prefetch on a background worker,
    bit-identical to the plain store (see the module docstring for the
    hazard rules).  The engine feeds the plan via
    :meth:`submit_cohort_plan`; without a plan the store still benefits
    from write-behind alone.
    """

    host_side = True

    def __init__(self, mmap_dir: Optional[str | Path] = None, *,
                 prefetch: bool = False):
        _disable_async_dispatch()
        self._mmap_dir = Path(mmap_dir) if mmap_dir is not None else None
        self._slots: Dict[str, _HostSlot] = {}
        self.prefetch = bool(prefetch)
        # host-side telemetry for benchmarks: rows/bytes actually moved,
        # pipeline health, and wall-seconds per phase (gather/scatter are
        # critical-path callback time; apply/prefetch run on the worker)
        self.bytes_gathered = 0
        self.bytes_scattered = 0
        self.rows_gathered = 0
        self.rows_scattered = 0
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self.flush_stalls = 0
        self.raw_hazards = 0
        self.phase_seconds = {"gather": 0.0, "scatter": 0.0,
                              "apply": 0.0, "prefetch": 0.0}
        # pipeline state (prefetch mode): all mutated under _cond
        self._cond = threading.Condition()
        self._queue: deque = deque()
        self._pending = 0
        self._staged: Dict[str, tuple] = {}      # name -> (idx, leaves)
        self._plan: Optional[List[np.ndarray]] = None
        self._next_stage: Dict[str, int] = {}
        self._apply_seq: Dict[str, int] = {}
        self._worker: Optional[threading.Thread] = None
        self._worker_error: Optional[BaseException] = None

    def telemetry(self) -> dict:
        """All counters as one flat dict (benchmark artifact rows)."""
        out = {k: getattr(self, k) for k in (
            "rows_gathered", "rows_scattered", "bytes_gathered",
            "bytes_scattered", "prefetch_hits", "prefetch_misses",
            "flush_stalls", "raw_hazards")}
        out.update({f"{k}_seconds": round(v, 6)
                    for k, v in self.phase_seconds.items()})
        return out

    # -- allocation ------------------------------------------------------ #

    def _alloc(self, name: str, i: int, shape, dtype):
        if self._mmap_dir is None:
            # calloc'd pages: untouched rows stay zero-page-backed, and
            # the touched bitmap keeps gathers from ever faulting them in
            return np.zeros(shape, dtype), None
        self._mmap_dir.mkdir(parents=True, exist_ok=True)
        path = self._mmap_dir / f"{name}.leaf_{i}.mm"
        buf = np.memmap(path, dtype=dtype, mode="w+", shape=shape)
        # writes go through this fd (pwrite), reads through the mapping —
        # the Linux page cache keeps the two coherent (see _HostSlot.fds)
        return buf, os.open(path, os.O_WRONLY)

    def init_slot(self, name: str, template: PyTree, n_clients: int,
                  init: str = "zeros") -> jax.Array:
        if init not in INIT_MODES:
            raise ValueError(f"init must be one of {INIT_MODES}")
        leaves, treedef = jax.tree_util.tree_flatten(template)
        bufs, fds, fills, structs = [], [], [], []
        for i, leaf in enumerate(leaves):
            leaf = np.asarray(leaf)
            buf, fd = self._alloc(name, i, (n_clients,) + leaf.shape,
                                  leaf.dtype)
            bufs.append(buf)
            fds.append(fd)
            # the fill row serves every never-scattered gather, so a
            # "broadcast" init never writes n_clients copies of the model
            fills.append(leaf.copy() if init == "broadcast"
                         else np.zeros(leaf.shape, leaf.dtype))
            structs.append(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype))
        self._slots[name] = _HostSlot(
            leaves=bufs, fill=fills,
            touched=np.zeros((n_clients,), bool),
            treedef=treedef, row_structs=structs, fds=fds)
        # the slot value is a version token: an int32 the scatter bumps,
        # giving the state tree a real (checkpointable) leaf and the
        # engine's scan carry a data dependence on top of the ordered-
        # effect sequencing
        return jnp.zeros((), jnp.int32)

    # -- host-side row movement ------------------------------------------ #

    def _gather_host(self, name: str, idx: np.ndarray) -> List[np.ndarray]:
        slot = self._slots[name]
        idx = np.asarray(idx)
        t = slot.touched[idx]
        out = []
        for buf, fill in zip(slot.leaves, slot.fill):
            rows = np.empty((idx.shape[0],) + fill.shape, fill.dtype)
            # read ONLY previously-scattered rows: untouched rows come
            # from the fill row without faulting buffer pages in
            rows[:] = fill
            if t.any():
                rows[t] = buf[idx[t]]
            out.append(rows)
            self.bytes_gathered += rows.nbytes
        return out

    def _scatter_host(self, name: str, idx: np.ndarray,
                      leaves: List[np.ndarray]) -> None:
        slot = self._slots[name]
        idx = np.asarray(idx)
        for buf, fd, rows in zip(slot.leaves, slot.fds, leaves):
            if fd is None:
                buf[idx] = rows
            else:
                # memmap leaf: pwrite through the fd instead of storing
                # through the mapping.  A store into a fresh mapped page
                # takes a minor fault; on a busy single-core host each
                # fault can deschedule this thread behind a spinning
                # compute thread for a whole timeslice (~1000x slowdown,
                # measured).  pwrite lands in the same page cache the
                # mapping reads from, so gathers stay coherent.
                row_bytes = buf.dtype.itemsize * int(
                    np.prod(buf.shape[1:], dtype=np.int64))
                flat = np.ascontiguousarray(
                    rows, dtype=buf.dtype).reshape(idx.shape[0], -1)
                for k in range(idx.shape[0]):
                    os.pwrite(fd, flat[k], int(idx[k]) * row_bytes)
            self.bytes_scattered += rows.nbytes
        slot.touched[idx] = True

    # -- pipeline worker (prefetch mode) --------------------------------- #

    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._worker_loop, name="hoststore-pipeline",
                daemon=True)
            self._worker.start()

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue:
                    self._cond.wait()
                op = self._queue.popleft()
            try:
                if self._worker_error is None:
                    if op[0] == "apply":
                        t0 = time.perf_counter()
                        _, name, idx, leaves = op
                        self._scatter_host(name, idx, leaves)
                        self.phase_seconds["apply"] += (
                            time.perf_counter() - t0)
                        self._do_stage(name)
                    else:                      # ("stage", name)
                        self._do_stage(op[1])
            except BaseException as e:         # surfaced by the callbacks
                with self._cond:
                    self._worker_error = e
            finally:
                with self._cond:
                    self._pending -= 1
                    self._cond.notify_all()

    def _do_stage(self, name: str) -> None:
        """Read the slot's next planned cohort into the staging buffer.

        The read runs without the lock (the worker is the only buffer
        writer, and sync reads in the gather callback only happen after
        the queue drains); the result is published under the lock and
        discarded if an apply to the same slot raced past it.
        """
        with self._cond:
            if self._plan is None:
                return
            j = self._next_stage.get(name, len(self._plan))
            if j >= len(self._plan):
                return
            idx = self._plan[j]
            self._next_stage[name] = j + 1
            seq0 = self._apply_seq.get(name, 0)
        t0 = time.perf_counter()
        leaves = self._gather_host(name, idx)
        with self._cond:
            if self._apply_seq.get(name, 0) == seq0:
                self._staged[name] = (idx, leaves)
            self.phase_seconds["prefetch"] += time.perf_counter() - t0

    def _raise_worker_error(self) -> None:
        if self._worker_error is not None:
            err = self._worker_error
            raise RuntimeError(
                "HostStore pipeline worker failed") from err

    def flush(self) -> None:
        """Barrier: wait until every write-behind scatter has been
        applied and every queued stage has landed.  Re-raises worker
        errors.  No-op on a plain store."""
        with self._cond:
            while self._pending and self._worker_error is None:
                self._cond.wait()
        self._raise_worker_error()

    def submit_cohort_plan(self, cohorts: Sequence[np.ndarray]) -> None:
        """Hand the store the upcoming rounds' cohort index schedule.

        ``cohorts[t]`` is the (s,) client-index array the engine expects
        round t to gather/scatter.  The plan is a performance hint only:
        a mispredicted entry costs a prefetch miss (sync fallback), never
        a wrong row.  Replaces any previous plan; flushes first so stale
        staged rows cannot survive a re-plan.
        """
        if not self.prefetch:
            return
        self.flush()
        self._ensure_worker()
        with self._cond:
            self._staged.clear()
            self._plan = [np.asarray(c) for c in cohorts]
            self._next_stage = {name: 0 for name in self._slots}
            for name in self._slots:
                self._queue.append(("stage", name))
                self._pending += 1
            self._cond.notify_all()

    # -- the in-graph contract ------------------------------------------- #

    def gather(self, name: str, slot: jax.Array, idx: jax.Array) -> PyTree:
        from jax.experimental import io_callback
        hs = self._slots[name]
        s = idx.shape[0]
        shapes = [jax.ShapeDtypeStruct((s,) + r.shape, r.dtype)
                  for r in hs.row_structs]

        def cb(idx_h, _token):
            t0 = time.perf_counter()
            try:
                if not self.prefetch:
                    return tuple(self._gather_host(name, idx_h))
                self._raise_worker_error()
                idx_np = np.asarray(idx_h)
                with self._cond:
                    entry = self._staged.get(name)
                    if entry is not None and np.array_equal(entry[0],
                                                            idx_np):
                        del self._staged[name]
                        self.prefetch_hits += 1
                        return tuple(entry[1])
                    if self._pending:
                        # a planned stage (or a preceding write-behind
                        # scatter this gather must observe) is still in
                        # flight: drain, then retry the staging buffer
                        self.flush_stalls += 1
                        while (self._pending
                               and self._worker_error is None):
                            self._cond.wait()
                        entry = self._staged.get(name)
                        if entry is not None and np.array_equal(
                                entry[0], idx_np):
                            del self._staged[name]
                            self.prefetch_hits += 1
                            return tuple(entry[1])
                self._raise_worker_error()
                self.prefetch_misses += 1
                return tuple(self._gather_host(name, idx_np))
            finally:
                self.rows_gathered += int(s)
                self.phase_seconds["gather"] += time.perf_counter() - t0

        rows = io_callback(cb, tuple(shapes), idx, slot, ordered=True)
        return jax.tree_util.tree_unflatten(hs.treedef, list(rows))

    def scatter(self, name: str, slot: jax.Array, idx: jax.Array,
                rows: PyTree, ctx) -> jax.Array:
        from jax.experimental import io_callback
        leaves, treedef = jax.tree_util.tree_flatten(rows)
        hs = self._slots[name]
        if treedef != hs.treedef:
            raise ValueError(
                f"scatter to slot {name!r} with mismatched tree structure")

        def cb(idx_h, *leaves_h):
            t0 = time.perf_counter()
            try:
                if not self.prefetch:
                    self._scatter_host(name, idx_h, list(leaves_h))
                    return np.zeros((), np.int32)
                self._raise_worker_error()
                self._ensure_worker()
                # write-behind: copy (the runtime may reuse the callback
                # operands) and enqueue; the worker applies + restages
                idx_np = np.array(idx_h, copy=True)
                copies = [np.array(l, copy=True) for l in leaves_h]
                with self._cond:
                    entry = self._staged.get(name)
                    if (entry is not None
                            and np.intersect1d(entry[0], idx_np).size):
                        # RAW hazard: staged rows predate this write
                        del self._staged[name]
                        self.raw_hazards += 1
                    self._apply_seq[name] = (
                        self._apply_seq.get(name, 0) + 1)
                    self._queue.append(("apply", name, idx_np, copies))
                    self._pending += 1
                    self._cond.notify_all()
                return np.zeros((), np.int32)
            finally:
                self.rows_scattered += int(idx_h.shape[0])
                self.phase_seconds["scatter"] += time.perf_counter() - t0

        io_callback(cb, jax.ShapeDtypeStruct((), jnp.int32), idx, *leaves,
                    ordered=True)
        return slot + 1

    def __del__(self):
        for slot in getattr(self, "_slots", {}).values():
            for fd in slot.fds:
                if fd is not None:
                    try:
                        os.close(fd)
                    except OSError:
                        pass

    # -- persistence (checkpoint-resume) --------------------------------- #

    def state_dict(self) -> dict:
        """The store's full host state as one nested-dict pytree, ready
        for ``repro.checkpoint.save``.  Buffers are materialised dense —
        checkpointing is for resumable *experiments*, not for spooling a
        million-client population (keep ``mmap_dir`` for that).  Flushes
        the write-behind queue first, so a mid-pipeline checkpoint
        captures every committed scatter."""
        self.flush()
        out = {}
        for name, slot in self._slots.items():
            out[name] = {
                "touched": slot.touched.copy(),
                "fill": {f"leaf_{i}": f.copy()
                         for i, f in enumerate(slot.fill)},
                "data": {f"leaf_{i}": np.asarray(buf).copy()
                         for i, buf in enumerate(slot.leaves)},
            }
        return out

    def load_state_dict(self, d: dict) -> None:
        """Restore buffers saved by :meth:`state_dict` into the slots
        registered by ``init_slot`` (call the algorithm's ``init`` first —
        it defines the slot names/shapes this fills).  Drops any staged
        rows and cohort plan — they described the pre-restore timeline."""
        self.flush()
        with self._cond:
            self._staged.clear()
            self._plan = None
            self._next_stage = {}
        for name, payload in d.items():
            if name not in self._slots:
                raise KeyError(
                    f"state_dict slot {name!r} was never registered; call "
                    "the algorithm's init() before load_state_dict()")
            slot = self._slots[name]
            slot.touched[:] = np.asarray(payload["touched"])
            for i in range(len(slot.leaves)):
                slot.fill[i][...] = np.asarray(payload["fill"][f"leaf_{i}"])
                slot.leaves[i][...] = np.asarray(payload["data"][f"leaf_{i}"])


def resolve_store(store: Optional[ClientStore]) -> ClientStore:
    """Default + type-check the ``store=`` argument every algorithm takes."""
    if store is None:
        return InMemoryStore()
    if not isinstance(store, ClientStore):
        raise TypeError(
            f"store must be a ClientStore, got {type(store).__name__}")
    return store
