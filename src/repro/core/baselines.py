"""Baseline FL algorithms the paper compares against (§4.7, Fig. 9).

* ``FedAvg``       (McMahan et al., 2016) — plain local SGD + averaging.
* ``SparseFedAvg`` — FedAvg with TopK-compressed uplink weights.
* ``Scaffold``     (Karimireddy et al., 2020) — control variates c, c_i
  (option II update), server stepsize 1.
* ``FedDyn``       (Acar et al., 2021; the Fed-Dyn curve in Fig. 9) —
  dynamic regularisation with server-side correction h.

All share the jitted local-SGD scaffolding, the in-graph CommMeter
accounting (repro.compress.BitsReport) so bits-axes are comparable with
FedComLoc, and the fused ``run_rounds`` engine (repro.core.engine).
Scaffnew is FedComLoc with variant="none" and the Identity compressor
(see fedcomloc.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.compress import Compressor, Identity, TopK, dense_bits
from repro.core import aggregation, comm
from repro.core.clients import (
    NULL_CTX, ClientAxisCtx, ClientSchedule, apply_downlink, keep_where,
    masked_mean, mean_over_active, payload_metrics, per_client, tree_where,
    validate_schedule, vmap_compress)
from repro.core.engine import RoundEngine
from repro.core.fed_data import FederatedData

PyTree = Any
LossFn = Callable[[PyTree, jax.Array, jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class FedConfig:
    gamma: float = 0.1            # local stepsize
    local_steps: int = 10
    n_clients: int = 100
    clients_per_round: int = 10
    batch_size: int = 32
    alpha: float = 0.1            # FedDyn regularisation strength

    def __post_init__(self):
        if self.n_clients <= 0:
            raise ValueError("n_clients must be positive")
        if not (0 < self.clients_per_round <= self.n_clients):
            raise ValueError(
                f"clients_per_round must be in [1, n_clients]: got "
                f"{self.clients_per_round} with n_clients={self.n_clients}")
        if self.local_steps <= 0:
            raise ValueError("local_steps must be positive")


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def _local_sgd(loss_fn: LossFn, data: FederatedData, cfg: FedConfig,
               x0_stacked: PyTree, clients: jax.Array, key: jax.Array,
               grad_adjust: Callable[[PyTree, int], PyTree] | None = None,
               steps: jax.Array | None = None,
               ctx: ClientAxisCtx = NULL_CTX):
    """Run minibatch SGD on each sampled client.

    ``steps`` is an optional (s,) per-client step count (DESIGN.md §5): the
    scan always runs ``cfg.local_steps`` iterations and clients past their
    count carry through unchanged, so heterogeneous schedules stay inside
    one fused graph.  ``grad_adjust(g, client_slot, x_c)`` adjusts each
    client's gradient (vmapped).  Under a sharded ``ctx`` (DESIGN.md §6)
    ``x0_stacked`` / ``clients`` / ``steps`` are this shard's slice and the
    per-step loss means psum across shards.  Returns (x_final stacked,
    summed per-step mean loss) — the caller divides by the step denominator
    (``cfg.local_steps``, or the *full* plan's ``steps.max()`` under a
    deadline, which a shard-local slice cannot know).
    """
    s = cfg.clients_per_round
    s_loc = ctx.local_count(s)

    def step(carry, inp):
        x_i, loss_acc = carry
        step_idx, k_step = inp

        def one_client(x_c, client, kc, slot):
            xb, yb = data.sample_batch(kc, client, cfg.batch_size)
            loss, g = jax.value_and_grad(loss_fn)(x_c, xb, yb)
            if grad_adjust is not None:
                g = grad_adjust(g, slot, x_c)
            x_new = _tmap(lambda xc, gc: xc - cfg.gamma * gc, x_c, g)
            return x_new, loss

        # full (s,) key chain then slice: per-client keys are device-count
        # invariant
        keys = ctx.shard(jax.random.split(k_step, s))
        x_new, losses = jax.vmap(one_client)(
            x_i, clients, keys, jnp.arange(s_loc))
        if steps is None:
            return (x_new, loss_acc + ctx.mean_clients(losses)), None
        active = step_idx < steps
        x_i = keep_where(active, x_new, x_i)
        loss_acc = loss_acc + mean_over_active(losses, active, ctx)
        return (x_i, loss_acc), None

    step_keys = jax.random.split(key, cfg.local_steps)
    (x_fin, loss_sum), _ = jax.lax.scan(
        step, (x0_stacked, jnp.zeros(())),
        (jnp.arange(cfg.local_steps), step_keys))
    return x_fin, loss_sum


def _broadcast(x: PyTree, s: int) -> PyTree:
    return _tmap(lambda p: jnp.broadcast_to(p, (s,) + p.shape), x)


# --------------------------------------------------------------------------- #
# FedAvg / SparseFedAvg
# --------------------------------------------------------------------------- #

class FedAvgState(NamedTuple):
    x: PyTree
    round: jax.Array
    y: PyTree = ()   # clients' last-received model (downlink != "dense")


class FedAvg(RoundEngine):
    def __init__(self, loss_fn: LossFn, data: FederatedData, cfg: FedConfig,
                 compressor: Compressor | None = None,
                 schedule: ClientSchedule | None = None,
                 policy: aggregation.AggregationPolicy | None = None,
                 wire: str = "account",
                 downlink: str = "dense",
                 downlink_compressor: Compressor | None = None,
                 store=None,
                 meter_mode: str = "host"):
        self.loss_fn, self.data, self.cfg = loss_fn, data, cfg
        self.policy = policy
        self.wire = wire
        self.downlink = downlink
        self.down_comp = downlink_compressor
        self.store = store
        self.comp = compressor if compressor is not None else Identity()
        self.sched = validate_schedule(
            schedule if schedule is not None
            else ClientSchedule.homogeneous(cfg.n_clients),
            cfg.n_clients, self.comp)
        self.meter = comm.CommMeter(mode=meter_mode)
        self._setup_engine()

    def init(self, params0: PyTree) -> FedAvgState:
        y = params0 if self.downlink != "dense" else ()
        return FedAvgState(x=params0, round=jnp.zeros((), jnp.int32), y=y)

    @property
    def _round_key_fanout(self):
        # must mirror _round_impl's split below (§12 cohort planner)
        return 4 if self.downlink != "dense" else 3

    def _round_impl(self, state: FedAvgState, key: jax.Array,
                    ctx: ClientAxisCtx = NULL_CTX):
        cfg, sched = self.cfg, self.sched
        s = cfg.clients_per_round
        s_loc = ctx.local_count(s)
        dl_on = self.downlink != "dense"
        if dl_on:
            k_sample, k_local, k_comp, k_dl = jax.random.split(key, 4)
        else:
            # dense-mode split stays 3-way so existing trajectories never
            # move (split(key, n) differs per n)
            k_sample, k_local, k_comp = jax.random.split(key, 3)
            k_dl = None
        clients_full, avail_full = sched.sample_cohort(
            k_sample, s, state.round)
        plan = sched.plan(clients_full, cfg.local_steps,
                          available=avail_full)
        plan_l = ctx.shard_tree(plan)
        clients = ctx.shard(clients_full)
        partf_plan_full = plan.participating.astype(jnp.float32)
        het = sched.heterogeneous_steps
        ref = state.y if dl_on else state.x    # §10: clients hold y
        x0 = _broadcast(ref, s_loc)
        x_fin, loss_sum = _local_sgd(
            self.loss_fn, self.data, cfg, x0, clients, k_local,
            steps=plan_l.steps if het else None, ctx=ctx)
        loss = loss_sum / (jnp.maximum(plan.steps.max(), 1) if het
                           else cfg.local_steps)
        comp_keys = ctx.shard(jax.random.split(k_comp, s))
        wire_on = self.wire == "packed"
        payload = None
        if wire_on:
            # §8 packed uplink: encode at the client boundary.  FedAvg has
            # no client-side state to update, so nothing reads a local
            # decode — the server decodes the gathered payload below.
            payload, up_rep = ctx.encode_payload(self.comp, plan_l, x_fin,
                                                 comp_keys)
        else:
            x_fin, up_rep = vmap_compress(self.comp, plan_l, x_fin,
                                          comp_keys)
        # aggregation policy (DESIGN.md §7): plan-masked bits feed the
        # finish clock; the outcome is replicated, device-count invariant
        pol = aggregation.resolve_policy(
            self.policy, sched, plan,
            ctx.all_clients(up_rep.total_bits) * partf_plan_full, ctx)
        out, may_exclude = pol.out, pol.may_exclude
        client_up = pol.client_up             # excluded clients send nothing
        delta_combine = aggregation.uses_delta_combine(self.policy)
        if wire_on:
            # §8 wire aggregation: masked packed-payload gather, server-side
            # decode, aggregate the full (s,) stack with the unsharded
            # formula (see fedcomloc._round_impl)
            xf_full = ctx.gather_decoded_payload(payload, out.partf)
            x0_full = _broadcast(ref, s)
            if delta_combine:
                delta = _tmap(lambda yf, xs: yf - xs, xf_full, x0_full)
                x_new = _tmap(
                    lambda x_, u: x_ + u, state.x,
                    aggregation.async_weighted_sum(out, delta, NULL_CTX))
            elif may_exclude:
                x_new = tree_where(out.n_selected > 0,
                                   masked_mean(xf_full, out.weight, NULL_CTX,
                                               weight_sum=out.n_selected),
                                   state.x)
            else:
                x_new = _tmap(lambda t: t.mean(axis=0), xf_full)
        elif delta_combine:
            delta = _tmap(lambda yf, xs: yf - xs, x_fin, x0)
            x_new = _tmap(lambda x_, u: x_ + u, state.x,
                          aggregation.async_weighted_sum(out, delta, ctx))
        elif may_exclude:
            # if every sampled client was excluded, the server keeps its
            # model
            x_new = tree_where(out.n_selected > 0,
                               masked_mean(x_fin, pol.weight, ctx,
                                           weight_sum=out.n_selected),
                               state.x)
        else:
            x_new = ctx.mean_clients(x_fin)
        y_new = state.y
        down_bits = jnp.asarray(s * dense_bits(state.x))
        dl_extras = {}
        if dl_on:
            # §10: delta-code the new model against the cohort reference
            y_new, down_bits, dl_extras = apply_downlink(
                self.downlink, self.down_comp, ctx, state.y, x_new, k_dl, s)
        metrics = {"train_loss": loss,
                   "uplink_bits": client_up.sum(),
                   "downlink_bits": down_bits,
                   "client_steps": plan.steps,
                   "client_uplink_bits": client_up,
                   "client_finish": out.finish,
                   "sim_time": out.sim_time,
                   **aggregation.policy_metrics(out)}
        if wire_on:
            metrics.update(payload_metrics(payload, out.partf))
        metrics.update(dl_extras)
        return FedAvgState(x=x_new, round=state.round + 1, y=y_new), metrics


def SparseFedAvg(loss_fn, data, cfg, density: float = 0.1,
                 schedule: ClientSchedule | None = None,
                 policy: aggregation.AggregationPolicy | None = None,
                 wire: str = "account",
                 downlink: str = "dense",
                 downlink_compressor: Compressor | None = None):
    return FedAvg(loss_fn, data, cfg, compressor=TopK(density=density),
                  schedule=schedule, policy=policy, wire=wire,
                  downlink=downlink,
                  downlink_compressor=downlink_compressor)


# --------------------------------------------------------------------------- #
# Scaffold (option II)
# --------------------------------------------------------------------------- #

class ScaffoldState(NamedTuple):
    x: PyTree
    c: PyTree        # server control variate
    ci: PyTree       # per-client control variates, stacked
    round: jax.Array
    y: PyTree = ()   # clients' last-received (x, c) (downlink != "dense")


class Scaffold(RoundEngine):
    def __init__(self, loss_fn: LossFn, data: FederatedData, cfg: FedConfig,
                 schedule: ClientSchedule | None = None,
                 policy: aggregation.AggregationPolicy | None = None,
                 wire: str = "account",
                 downlink: str = "dense",
                 downlink_compressor: Compressor | None = None,
                 store=None,
                 meter_mode: str = "host"):
        self.loss_fn, self.data, self.cfg = loss_fn, data, cfg
        self.policy = policy
        self.wire = wire
        self.downlink = downlink
        self.down_comp = downlink_compressor
        self.store = store
        self.sched = validate_schedule(
            schedule if schedule is not None
            else ClientSchedule.homogeneous(cfg.n_clients), cfg.n_clients)
        self.meter = comm.CommMeter(mode=meter_mode)
        self._setup_engine()

    def init(self, params0: PyTree) -> ScaffoldState:
        zeros = _tmap(jnp.zeros_like, params0)
        ci = self.store.init_slot("ci", params0, self.cfg.n_clients)
        # Scaffold broadcasts model AND server control variate: the §10
        # downlink reference is the (x, c) pair the cohort last received
        y = (params0, zeros) if self.downlink != "dense" else ()
        return ScaffoldState(x=params0, c=zeros, ci=ci,
                             round=jnp.zeros((), jnp.int32), y=y)

    @property
    def _round_key_fanout(self):
        # must mirror _round_impl's split below (§12 cohort planner)
        return 3 if self.downlink != "dense" else 2

    def _round_impl(self, state: ScaffoldState, key: jax.Array,
                    ctx: ClientAxisCtx = NULL_CTX):
        cfg, sched = self.cfg, self.sched
        dl_on = self.downlink != "dense"
        if dl_on:
            k_sample, k_local, k_dl = jax.random.split(key, 3)
        else:
            k_sample, k_local = jax.random.split(key)
            k_dl = None
        s = cfg.clients_per_round
        s_loc = ctx.local_count(s)
        clients_full, avail_full = sched.sample_cohort(
            k_sample, s, state.round)
        plan = sched.plan(clients_full, cfg.local_steps,
                          available=avail_full)
        plan_l = ctx.shard_tree(plan)
        clients = ctx.shard(clients_full)
        partf_plan_full = plan.participating.astype(jnp.float32)
        ci_s = self.store.gather("ci", state.ci, clients)
        # §10: clients work from the (x, c) pair they last received
        x_ref, c_ref = state.y if dl_on else (state.x, state.c)
        x0 = _broadcast(x_ref, s_loc)

        def adjust(g, slot, x_c):
            return _tmap(lambda gc, cic, cc: gc - cic + cc,
                         g, _tmap(lambda c: c[slot], ci_s), c_ref)

        het = sched.heterogeneous_steps
        x_fin, loss_sum = _local_sgd(self.loss_fn, self.data, cfg, x0,
                                     clients, k_local, grad_adjust=adjust,
                                     steps=plan_l.steps if het else None,
                                     ctx=ctx)
        loss = loss_sum / (jnp.maximum(plan.steps.max(), 1) if het
                           else cfg.local_steps)

        # option II: ci+ = ci - c + (x - y_i) / (K_i * gamma) — K_i is the
        # steps the client actually completed (DESIGN.md §5).
        if het:
            coef = 1.0 / (jnp.maximum(plan_l.steps, 1).astype(jnp.float32)
                          * cfg.gamma)
            ci_new = _tmap(
                lambda cic, cc, xs, yf: cic - cc[None]
                + per_client(coef, xs) * (xs - yf),
                ci_s, c_ref, x0, x_fin)
            # a zero-step client did no work: the update above would still
            # shift its variate by -c (x_fin == x0), so keep the old ci
            ci_new = keep_where(plan_l.steps > 0, ci_new, ci_s)
        else:
            coef = 1.0 / (cfg.local_steps * cfg.gamma)
            ci_new = _tmap(
                lambda cic, cc, xs, yf: cic - cc[None] + coef * (xs - yf),
                ci_s, c_ref, x0, x_fin)
        # Scaffold communicates both the model and the control variate;
        # the (plan-masked) per-client wire cost feeds the policy's
        # finish-time clock (DESIGN.md §7).
        dense = dense_bits(state.x)
        pol = aggregation.resolve_policy(
            self.policy, sched, plan, 2 * dense * partf_plan_full, ctx)
        out, part, may_exclude = pol.out, pol.part, pol.may_exclude
        client_up = pol.client_up
        if may_exclude:   # excluded stragglers never report; keep ci
            ci_new = keep_where(part, ci_new, ci_s)
        wire_on = self.wire == "packed"
        delta_combine = aggregation.uses_delta_combine(self.policy)
        payload = None
        if wire_on:
            # §8 packed uplink: Scaffold transmits model + control variate
            # (the 2x-dense accounting) — both ride one dense payload
            payload, _ = ctx.encode_payload(None, plan_l, (x_fin, ci_new))
            xf_full, ci_new_full = ctx.gather_decoded_payload(
                payload, out.partf)
            x0_full = _broadcast(x_ref, s)
            ci_s_full = self.store.gather("ci", state.ci, clients_full)
            dxs = _tmap(lambda yf, xs: yf - xs, xf_full, x0_full)
            dcs = _tmap(lambda cn, co: cn - co, ci_new_full, ci_s_full)
            if delta_combine:
                dx = aggregation.async_weighted_sum(out, dxs, NULL_CTX)
                dc = aggregation.async_weighted_sum(out, dcs, NULL_CTX)
                s_eff = out.n_selected
            elif may_exclude:
                wsum = out.n_selected
                dx = masked_mean(dxs, out.weight, NULL_CTX, weight_sum=wsum)
                dc = masked_mean(dcs, out.weight, NULL_CTX, weight_sum=wsum)
                s_eff = wsum
            else:
                dx = _tmap(lambda t: t.mean(axis=0), dxs)
                dc = _tmap(lambda t: t.mean(axis=0), dcs)
                s_eff = s
        elif delta_combine:
            dx = aggregation.async_weighted_sum(
                out, _tmap(lambda yf, xs: yf - xs, x_fin, x0), ctx)
            dc = aggregation.async_weighted_sum(
                out, _tmap(lambda cn, co: cn - co, ci_new, ci_s), ctx)
            s_eff = out.n_selected
        elif may_exclude:
            wsum = out.n_selected
            dx = masked_mean(_tmap(lambda yf, xs: yf - xs, x_fin, x0),
                             pol.weight, ctx, weight_sum=wsum)
            dc = masked_mean(_tmap(lambda cn, co: cn - co, ci_new, ci_s),
                             pol.weight, ctx, weight_sum=wsum)
            s_eff = wsum
        else:
            dx = ctx.mean_clients(_tmap(lambda yf, xs: yf - xs, x_fin, x0))
            dc = ctx.mean_clients(_tmap(lambda cn, co: cn - co,
                                        ci_new, ci_s))
            s_eff = s
        x_new = _tmap(lambda x_, d: x_ + d, state.x, dx)
        c_new = _tmap(lambda c_, d: c_ + (s_eff / cfg.n_clients) * d,
                      state.c, dc)
        ci_all = self.store.scatter("ci", state.ci, clients, ci_new, ctx)
        y_new = state.y
        down_bits = jnp.asarray(2 * s * dense)
        dl_extras = {}
        if dl_on:
            # §10: one payload delta-codes BOTH broadcast halves (model +
            # server control variate) against the cohort's (x, c) reference
            y_new, down_bits, dl_extras = apply_downlink(
                self.downlink, self.down_comp, ctx, state.y,
                (x_new, c_new), k_dl, s)
        metrics = {"train_loss": loss,
                   "uplink_bits": (client_up.sum() if may_exclude
                                   else jnp.asarray(2 * s * dense)),
                   "downlink_bits": down_bits,
                   "client_steps": plan.steps,
                   "client_uplink_bits": client_up,
                   "client_finish": out.finish,
                   "sim_time": out.sim_time,
                   **aggregation.policy_metrics(out)}
        if wire_on:
            metrics.update(payload_metrics(payload, out.partf))
        metrics.update(dl_extras)
        return (ScaffoldState(x=x_new, c=c_new, ci=ci_all,
                              round=state.round + 1, y=y_new), metrics)


# --------------------------------------------------------------------------- #
# FedDyn
# --------------------------------------------------------------------------- #

class FedDynState(NamedTuple):
    x: PyTree
    h: PyTree        # server correction
    grads: PyTree    # per-client dual variables, stacked
    round: jax.Array
    y: PyTree = ()   # clients' last-received model (downlink != "dense")


class FedDyn(RoundEngine):
    def __init__(self, loss_fn: LossFn, data: FederatedData, cfg: FedConfig,
                 schedule: ClientSchedule | None = None,
                 policy: aggregation.AggregationPolicy | None = None,
                 wire: str = "account",
                 downlink: str = "dense",
                 downlink_compressor: Compressor | None = None,
                 store=None,
                 meter_mode: str = "host"):
        self.loss_fn, self.data, self.cfg = loss_fn, data, cfg
        self.policy = policy
        self.wire = wire
        self.downlink = downlink
        self.down_comp = downlink_compressor
        self.store = store
        self.sched = validate_schedule(
            schedule if schedule is not None
            else ClientSchedule.homogeneous(cfg.n_clients), cfg.n_clients)
        self.meter = comm.CommMeter(mode=meter_mode)
        self._setup_engine()

    def init(self, params0: PyTree) -> FedDynState:
        zeros = _tmap(jnp.zeros_like, params0)
        g = self.store.init_slot("grads", params0, self.cfg.n_clients)
        y = params0 if self.downlink != "dense" else ()
        return FedDynState(x=params0, h=zeros, grads=g,
                           round=jnp.zeros((), jnp.int32), y=y)

    @property
    def _round_key_fanout(self):
        # must mirror _round_impl's split below (§12 cohort planner)
        return 3 if self.downlink != "dense" else 2

    def _round_impl(self, state: FedDynState, key: jax.Array,
                    ctx: ClientAxisCtx = NULL_CTX):
        cfg, sched = self.cfg, self.sched
        dl_on = self.downlink != "dense"
        if dl_on:
            k_sample, k_local, k_dl = jax.random.split(key, 3)
        else:
            k_sample, k_local = jax.random.split(key)
            k_dl = None
        s = cfg.clients_per_round
        s_loc = ctx.local_count(s)
        clients_full, avail_full = sched.sample_cohort(
            k_sample, s, state.round)
        plan = sched.plan(clients_full, cfg.local_steps,
                          available=avail_full)
        plan_l = ctx.shard_tree(plan)
        clients = ctx.shard(clients_full)
        partf_plan_full = plan.participating.astype(jnp.float32)
        g_s = self.store.gather("grads", state.grads, clients)
        ref = state.y if dl_on else state.x    # §10: clients hold y
        x0 = _broadcast(ref, s_loc)

        def adjust(g, slot, x_c):
            gp = _tmap(lambda gg: gg[slot], g_s)
            return _tmap(
                lambda gc, gpc, xc, xs: gc - gpc + cfg.alpha * (xc - xs),
                g, gp, x_c, ref)

        het = sched.heterogeneous_steps
        x_fin, loss_sum = _local_sgd(self.loss_fn, self.data, cfg, x0,
                                     clients, k_local, grad_adjust=adjust,
                                     steps=plan_l.steps if het else None,
                                     ctx=ctx)
        loss = loss_sum / (jnp.maximum(plan.steps.max(), 1) if het
                           else cfg.local_steps)
        dense = dense_bits(state.x)
        pol = aggregation.resolve_policy(
            self.policy, sched, plan, dense * partf_plan_full, ctx)
        out, part, partf, may_exclude = (pol.out, pol.part, pol.partf,
                                         pol.may_exclude)
        client_up = pol.client_up
        g_new = _tmap(lambda gp, yf, xs: gp - cfg.alpha * (yf - xs),
                      g_s, x_fin, x0)
        if may_exclude:   # excluded stragglers keep their dual variables
            g_new = keep_where(part, g_new, g_s)
        grads_all = self.store.scatter("grads", state.grads, clients,
                                       g_new, ctx)
        wire_on = self.wire == "packed"
        delta_combine = aggregation.uses_delta_combine(self.policy)
        payload = None
        if wire_on:
            # §8 packed (dense) uplink + replicated full-stack aggregation
            payload, _ = ctx.encode_payload(None, plan_l, x_fin)
            xf_full = ctx.gather_decoded_payload(payload, out.partf)
            x0_full = _broadcast(ref, s)
            deltas = _tmap(lambda yf, xs: yf - xs, xf_full, x0_full)
            if delta_combine:
                dsum = _tmap(
                    lambda d_: (d_ * per_client(out.discount, d_)
                                ).sum(axis=0), deltas)
                h_new = _tmap(
                    lambda h_, d_: h_ - cfg.alpha * (1.0 / cfg.n_clients)
                    * d_, state.h, dsum)
                x_new = _tmap(
                    lambda x_, u, h_: x_ + u - h_ / cfg.alpha, state.x,
                    aggregation.async_weighted_sum(out, deltas, NULL_CTX),
                    h_new)
                if sched.may_drop:
                    x_new = tree_where(out.n_selected > 0, x_new, state.x)
            elif may_exclude:
                delta = _tmap(
                    lambda d_: (d_ * per_client(out.partf, d_)).sum(axis=0),
                    deltas)
                h_new = _tmap(
                    lambda h_, d_: h_ - cfg.alpha * (1.0 / cfg.n_clients)
                    * d_, state.h, delta)
                x_new = _tmap(lambda ym, h_: ym - h_ / cfg.alpha,
                              masked_mean(xf_full, out.weight, NULL_CTX,
                                          weight_sum=out.n_selected), h_new)
                x_new = tree_where(out.n_selected > 0, x_new, state.x)
            else:
                dsum = _tmap(lambda d_: d_.sum(axis=0), deltas)
                h_new = _tmap(
                    lambda h_, d_: h_ - cfg.alpha * (1.0 / cfg.n_clients)
                    * d_, state.h, dsum)
                x_new = _tmap(lambda ym, h_: ym - h_ / cfg.alpha,
                              _tmap(lambda t: t.mean(axis=0), xf_full),
                              h_new)
        elif delta_combine:
            # the server correction absorbs the staleness-discounted delta
            # *sum*; the average applies the per-flush buffer means
            disc = ctx.shard(out.discount)
            deltas = _tmap(lambda yf, xs: yf - xs, x_fin, x0)
            dsum = ctx.psum(_tmap(
                lambda d_: (d_ * per_client(disc, d_)).sum(axis=0), deltas))
            h_new = _tmap(
                lambda h_, d_: h_ - cfg.alpha * (1.0 / cfg.n_clients) * d_,
                state.h, dsum)
            x_new = _tmap(
                lambda x_, u, h_: x_ + u - h_ / cfg.alpha, state.x,
                aggregation.async_weighted_sum(out, deltas, ctx), h_new)
            if sched.may_drop:
                # if every sampled client dropped, keep the server model
                x_new = tree_where(out.n_selected > 0, x_new, state.x)
        elif may_exclude:
            # only participants' deltas feed the server correction/average
            delta = ctx.sum_clients(_tmap(
                lambda yf, xs: (yf - xs) * per_client(partf, yf),
                x_fin, x0))
            h_new = _tmap(
                lambda h_, d_: h_ - cfg.alpha * (1.0 / cfg.n_clients) * d_,
                state.h, delta)
            x_new = _tmap(lambda ym, h_: ym - h_ / cfg.alpha,
                          masked_mean(x_fin, pol.weight, ctx,
                                      weight_sum=out.n_selected), h_new)
            # if every sampled client was excluded, keep the server model
            x_new = tree_where(out.n_selected > 0, x_new, state.x)
        else:
            dsum = ctx.sum_clients(_tmap(lambda yf, xs: yf - xs,
                                         x_fin, x0))
            h_new = _tmap(
                lambda h_, d_: h_ - cfg.alpha * (1.0 / cfg.n_clients) * d_,
                state.h, dsum)
            x_new = _tmap(lambda ym, h_: ym - h_ / cfg.alpha,
                          ctx.mean_clients(x_fin), h_new)
        y_new = state.y
        down_bits = jnp.asarray(s * dense)
        dl_extras = {}
        if dl_on:
            y_new, down_bits, dl_extras = apply_downlink(
                self.downlink, self.down_comp, ctx, state.y, x_new, k_dl, s)
        metrics = {"train_loss": loss,
                   "uplink_bits": (client_up.sum() if may_exclude
                                   else jnp.asarray(s * dense)),
                   "downlink_bits": down_bits,
                   "client_steps": plan.steps,
                   "client_uplink_bits": client_up,
                   "client_finish": out.finish,
                   "sim_time": out.sim_time,
                   **aggregation.policy_metrics(out)}
        if wire_on:
            metrics.update(payload_metrics(payload, out.partf))
        metrics.update(dl_extras)
        return (FedDynState(x=x_new, h=h_new, grads=grads_all,
                            round=state.round + 1, y=y_new), metrics)
