"""Client-axis device parallelism: ``shard_map`` federated rounds.

The paper's experiments (100 clients, 10 sampled per round) are
embarrassingly parallel along the client axis, but the round
implementations vmap the sampled clients onto one device.  This module
splits that axis across a ``clients`` mesh axis (DESIGN.md §6):

* :class:`ShardCtx` — the sharded implementation of
  :class:`repro.core.clients.ClientAxisCtx`: per-client work (local SGD,
  TopK/Q_r compression, RNG keys, ``RoundPlan`` vectors) runs shard-local
  on an ``s/D`` slice of the sampled clients, and every cross-client
  reduction is an explicit collective — ``psum`` for model-tree means and
  masked sums, ``all_gather`` for the (s,) metric vectors;
* :func:`shard_round` — wraps an algorithm's ``_round_impl`` in
  ``shard_map`` over the mesh.  State and key go in replicated and come
  out replicated, so ``lax.scan`` over rounds (the fused
  ``RoundEngine.run_rounds`` engine) stays a single jit with the
  ``shard_map`` inside the scan body.

Determinism contract (tests/test_distributed.py): per-client RNG keys are
split from the *full* (s,) chain and then sliced, so each client computes
exactly what it computes unsharded; metric scalars (``uplink_bits`` /
``downlink_bits``, ``client_steps``, ``sim_time``) are derived from
``all_gather``-ed full vectors with the unsharded formula and are therefore
**bit-identical** at any device count, while psum-reduced model trees
(server mean, control variates) are allclose (summation order changes with
D).  On a 1-device mesh everything — params included — is bit-identical.

The persistent (n_clients, ...) client state stays replicated: sampling
draws arbitrary global indices each round, so a round gathers its s rows
replicated (cheap — s << n_clients) and scatters them back with a
psum-of-disjoint-rows trick that is exact because ``replace=False``
sampling makes shard contributions disjoint.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.clients import ClientAxisCtx, per_client

try:  # jax >= 0.6 exports shard_map at the top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

# older jax wants check_rep=False for axis_index-based slicing; the kwarg
# was renamed/retired in newer releases, so pass it only when accepted.
_SM_KWARGS = ({"check_rep": False}
              if "check_rep" in inspect.signature(_shard_map).parameters
              else {})

PyTree = Any

CLIENT_AXIS = "clients"


class ShardCtx(ClientAxisCtx):
    """Sharded view of the sampled-client axis inside ``shard_map``."""

    def __init__(self, axis_name: str, n_shards: int):
        self.axis = axis_name
        self.n_shards = n_shards

    def local_count(self, s: int) -> int:
        return s // self.n_shards

    def axis_index(self) -> jax.Array:
        """This shard's position on the client axis."""
        return jax.lax.axis_index(self.axis)

    def shard(self, arr: jax.Array) -> jax.Array:
        nl = arr.shape[0] // self.n_shards
        start = self.axis_index() * nl
        return jax.lax.dynamic_slice_in_dim(arr, start, nl, axis=0)

    def shard_tree(self, tree: PyTree) -> PyTree:
        return jax.tree_util.tree_map(self.shard, tree)

    def all_clients(self, vec: jax.Array) -> jax.Array:
        # tiled gather in axis order == the inverse of ``shard``'s slicing,
        # so the reassembled vector matches the unsharded one row-for-row
        return jax.lax.all_gather(vec, self.axis, axis=0, tiled=True)

    def psum(self, x):
        return jax.tree_util.tree_map(
            lambda t: jax.lax.psum(t, self.axis), x)

    def all_clients_tree(self, tree: PyTree) -> PyTree:
        """Tiled all_gather of every (s/D, ...) leaf back to (s, ...).

        This is the §8 wire-mode uplink collective: gathering a packed
        ``Payload`` pytree moves its packed buffers — uint32 index/code
        words, sub-byte level planes, int8 levels — across the mesh
        instead of dense fp32 trees, which is where the ~32/r× wire saving
        physically happens.  Row order matches ``shard``'s slicing, so the
        reassembled client axis is identical to the unsharded one.
        """
        return jax.tree_util.tree_map(self.all_clients, tree)

    def mean_clients(self, stacked: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda t: jax.lax.psum(t.sum(axis=0), self.axis)
            / (t.shape[0] * self.n_shards), stacked)

    def sum_clients(self, stacked: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda t: jax.lax.psum(t.sum(axis=0), self.axis), stacked)

    def scatter_rows(self, full: PyTree, idx: jax.Array, upd: PyTree
                     ) -> PyTree:
        """Exact cross-shard scatter into a replicated (n, ...) store.

        Each shard zero-fills a copy, writes its rows, and the psum merges
        them: sampling without replacement makes the written rows disjoint,
        so every touched row receives exactly one shard's value plus zeros
        (exact in fp), and untouched rows keep the old value via the mask.
        """
        n_rows = jax.tree_util.tree_leaves(full)[0].shape[0]
        touched = jnp.zeros((n_rows,), jnp.int32).at[idx].set(1)
        touched = jax.lax.psum(touched, self.axis) > 0

        def one(f, u):
            contrib = jax.lax.psum(jnp.zeros_like(f).at[idx].set(u),
                                   self.axis)
            return jnp.where(per_client(touched, f), contrib, f)

        return jax.tree_util.tree_map(one, full, upd)


# --------------------------------------------------------------------------- #
# composed clients x model meshes (DESIGN.md §9)
# --------------------------------------------------------------------------- #
#
# With extra mesh axes the round does NOT run inside ``shard_map`` at all:
# transformer training bodies are full of ``lax.scan`` (flash-attention KV
# chunks, the chunked loss, scanned layer stacks), and XLA's sharding
# propagation cannot carry a while loop whose carries/xs touch sharded
# values through a partially-manual (manual ``clients`` + auto ``model``)
# region — it aborts on a manual-subgroup check.  Scalar-only loops pass;
# anything real does not, with or without sharding constraints, and
# ``unroll=True`` doesn't save it (the unrolled slicing hits the same
# check).  So the composed regime is a plain GSPMD program:
#
# * per-client compute keeps the base :class:`ClientAxisCtx` global
#   semantics — the graph is exactly the unsharded one; ``shard``/
#   ``shard_tree`` become placement *hints* (client axis over ``clients``,
#   every other dim unconstrained) so GSPMD splits the vmapped local SGD
#   across client devices while ``param_shardings`` splits the math over
#   ``model``;
# * ALL wire work (encode -> mask -> decode) runs in top-level fully-manual
#   ``shard_map`` regions over the whole mesh, where each device packs /
#   unpacks its model shard of its clients' payloads — the §9 shard-local
#   wire path (psum'd radix-walk thresholds, psum'd norms, psum'd nnz
#   accounting);
# * the decoded uplink comes back clients x model sharded, so the server
#   aggregation's cross-device traffic is GSPMD reductions of shard-local
#   dense trees — per-device bytes scale with ``1/model_shards``.

class ModelShardCtx(ClientAxisCtx):
    """Client-axis ctx for composed clients x model meshes (GSPMD regime).

    Per-client compute sees logically-global, physically-sharded leaves;
    the wire path runs shard-local per model shard: each shard packs the
    slots of its slice of every sharded leaf against the exact *global*
    TopK threshold (per-pass psum'd radix-walk counts) or *global* l2 norm
    (one psum'd sum of squares).  Encode work and uplink bytes per device
    scale with ``1/model_shards``; bit accounting stays bit-identical to
    the unsharded path (psum'd integer nnz).
    """

    def __init__(self, mesh: Mesh, axis: str = CLIENT_AXIS,
                 model_axis: str = "model"):
        self.mesh = mesh
        self.axis = axis
        self.client_shards = mesh.shape[axis]
        self.model_axis = model_axis if model_axis in mesh.axis_names else None
        self.model_shards = (mesh.shape[model_axis]
                             if self.model_axis is not None else 1)

    # -- per-client compute: global semantics + placement hints ----------- #

    def shard(self, arr: jax.Array) -> jax.Array:
        """No slicing — pin the client axis over the ``clients`` devices
        and leave every other dim to GSPMD (fail-soft on indivisible or
        scalar leaves, mirroring ``sharding.constrain``)."""
        if arr.ndim == 0 or arr.shape[0] % self.client_shards:
            return arr
        spec = P(self.axis, *([P.UNCONSTRAINED] * (arr.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            arr, jax.sharding.NamedSharding(self.mesh, spec))

    def shard_tree(self, tree: PyTree) -> PyTree:
        return jax.tree_util.tree_map(self.shard, tree)

    # -- shard-local wire path -------------------------------------------- #

    def _manual(self, fn, in_specs, out_specs):
        """Run ``fn`` in a fully-manual ``shard_map`` region over the whole
        mesh (the only non-GSPMD islands of the composed regime)."""
        return _shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                          out_specs=out_specs, **_SM_KWARGS)

    def _leaf_model_dims(self, flat):
        """Per-leaf sharded-dim index from the path rules (None when the
        rules replicate the leaf or its dim doesn't divide the axis)."""
        from repro.sharding import specs as sspecs
        return tuple(
            sspecs.model_dim_index(path, leaf.shape[1:], self.model_shards)
            for path, leaf in flat)

    def _buffer_specs(self, spec):
        """Wire-region PartitionSpecs of one unit's buffers, per unit:
        axis 0 is the client dim; slot/word buffers of sharded units split
        over the model axis on axis 1 (the opaque shard-concatenated
        layout), replicated units' buffers and qr norms are identical on
        every model shard."""
        shard_p = P(self.axis, self.model_axis)
        repl_p = P(self.axis, None)
        out = []
        for i, mdim in enumerate(spec.model_dims):
            b = shard_p if mdim is not None else repl_p
            if spec.codec == "topk":
                out.append((b, b))
            elif spec.codec == "qr":
                out.append((b, P(self.axis)))
            else:                             # dense
                out.append((b,))
        return tuple(out)

    def _leaf_specs(self, spec, mdims):
        """Per-leaf specs of the stacked (client-leading) global tree."""
        specs = []
        for shp, mdim in zip(spec.shapes, mdims):
            ent = [None] * (len(shp) + 1)
            ent[0] = self.axis
            if mdim is not None:
                ent[mdim + 1] = self.model_axis
            specs.append(P(*ent))
        return jax.tree_util.tree_unflatten(spec.treedef, specs)

    def encode_payload(self, comp, plan, stacked, keys=None):
        from repro.compress import wire
        if self.model_shards <= 1:
            # clients x data composition: payload layout and collectives
            # are the unsharded ones; GSPMD places the vmapped encode.
            return super().encode_payload(comp, plan, stacked, keys)
        if plan.comp_overrides:
            raise ValueError(
                "packed wire mode cannot carry per-client compressor "
                "overrides (static payload capacity); run them in account "
                "mode")
        flat, treedef = jax.tree_util.tree_flatten_with_path(stacked)
        mdims = self._leaf_model_dims(flat)
        structs = jax.tree_util.tree_unflatten(
            treedef, [jax.ShapeDtypeStruct(l.shape[1:], l.dtype)
                      for _, l in flat])
        spec = wire.sharded_wire_spec(comp, structs, mdims,
                                      self.model_shards)
        rep_p = jax.tree_util.tree_map(lambda _: P(self.axis),
                                       wire.BitsReport(0., 0., 0.))
        out_specs = (self._buffer_specs(spec), rep_p)
        leaf_specs = self._leaf_specs(spec, mdims)

        if keys is None:
            def body(tree_loc):
                enc = lambda t: wire.encode_shard_local(
                    comp, t, spec, self.model_axis)
                return jax.vmap(enc)(tree_loc)
            data, report = self._manual(
                body, in_specs=(leaf_specs,), out_specs=out_specs)(stacked)
        else:
            def body(tree_loc, ks):
                enc = lambda t, k: wire.encode_shard_local(
                    comp, t, spec, self.model_axis, k)
                return jax.vmap(enc)(tree_loc, ks)
            data, report = self._manual(
                body, in_specs=(leaf_specs, P(self.axis)),
                out_specs=out_specs)(stacked, keys)
        return wire.Payload(data, spec), report

    def gather_decoded_payload(self, payload, partf_full):
        from repro.compress import wire
        spec = payload.spec
        if spec.model_shards <= 1:
            from repro.core.clients import gather_decoded
            return gather_decoded(payload, partf_full, self)
        out_specs = self._leaf_specs(spec, spec.model_dims)

        def body(data, partf):
            # partf arrives pre-sliced to this shard's clients by in_specs;
            # no gather: each device decodes its own clients' buffers and
            # the out_specs reassemble the clients x model sharded tree.
            keep = partf > 0
            masked = jax.tree_util.tree_map(
                lambda b: jnp.where(per_client(keep, b), b,
                                    jnp.zeros((), b.dtype)), data)
            return jax.vmap(
                lambda d: wire.decode_shard_local(d, spec))(masked)

        return self._manual(
            body, in_specs=(self._buffer_specs(spec), P(self.axis)),
            out_specs=out_specs)(payload.data, partf_full)

    # -- shard-local downlink path (§10) ---------------------------------- #

    def _bcast_buffer_specs(self, spec):
        """:meth:`_buffer_specs` without the client dim: one broadcast
        payload serves the whole cohort, so slot/word buffers of sharded
        units split over the model axis on axis 0 and everything else is
        replicated on every device."""
        shard_p = P(self.model_axis)
        repl_p = P(None)
        out = []
        for mdim in spec.model_dims:
            b = shard_p if mdim is not None else repl_p
            if spec.codec == "topk":
                out.append((b, b))
            elif spec.codec == "qr":
                out.append((b, P()))
            else:                             # dense
                out.append((b,))
        return tuple(out)

    def _bcast_leaf_specs(self, spec, mdims):
        """Per-leaf specs of the (client-free) broadcast tree."""
        specs = []
        for shp, mdim in zip(spec.shapes, mdims):
            ent = [None] * len(shp)
            if mdim is not None:
                ent[mdim] = self.model_axis
            specs.append(P(*ent))
        return jax.tree_util.tree_unflatten(spec.treedef, specs)

    def encode_broadcast(self, comp, tree, key=None):
        from repro.compress import wire
        if self.model_shards <= 1:
            return super().encode_broadcast(comp, tree, key)
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        from repro.sharding import specs as sspecs
        mdims = tuple(
            sspecs.model_dim_index(path, leaf.shape, self.model_shards)
            for path, leaf in flat)
        structs = jax.tree_util.tree_unflatten(
            treedef, [jax.ShapeDtypeStruct(l.shape, l.dtype)
                      for _, l in flat])
        spec = wire.sharded_wire_spec(comp, structs, mdims,
                                      self.model_shards)
        rep_p = jax.tree_util.tree_map(lambda _: P(),
                                       wire.BitsReport(0., 0., 0.))
        out_specs = (self._bcast_buffer_specs(spec), rep_p)
        leaf_specs = self._bcast_leaf_specs(spec, mdims)

        if key is None:
            def body(tree_loc):
                return wire.encode_shard_local(
                    comp, tree_loc, spec, self.model_axis)
            data, report = self._manual(
                body, in_specs=(leaf_specs,), out_specs=out_specs)(tree)
        else:
            def body(tree_loc, k):
                return wire.encode_shard_local(
                    comp, tree_loc, spec, self.model_axis, k)
            data, report = self._manual(
                body, in_specs=(leaf_specs, P()),
                out_specs=out_specs)(tree, key)
        return wire.Payload(data, spec), report

    def decode_broadcast(self, payload):
        from repro.compress import wire
        spec = payload.spec
        if spec.model_shards <= 1:
            return super().decode_broadcast(payload)
        out_specs = self._bcast_leaf_specs(spec, spec.model_dims)

        def body(data):
            # each model shard unpacks its own slice of the broadcast;
            # out_specs reassemble the model-sharded tree — no gather
            return wire.decode_shard_local(data, spec)

        return self._manual(
            body, in_specs=(self._bcast_buffer_specs(spec),),
            out_specs=out_specs)(payload.data)


# --------------------------------------------------------------------------- #


def validate_model_axis(mesh: Mesh, model_cfg, axis: str = "model") -> int:
    """Check the mesh's model axis divides the config's sharded dims.

    ``model_cfg`` is a ``ModelConfig`` or an ``ArchSpec`` (unwrapped).
    Without this, a bad composition surfaces as a deep XLA sharding
    failure; here it names the offending dimensions and the shard counts
    that would work.  Returns the model-axis size (1 when absent).
    """
    if axis not in mesh.axis_names:
        return 1
    m = mesh.shape[axis]
    if m == 1:
        return 1
    cfg = getattr(model_cfg, "model", model_cfg)
    hd = getattr(cfg, "hd", None) or cfg.head_dim
    dims = {
        "n_heads*head_dim (q/o projections)": cfg.n_heads * hd,
        "n_kv_heads*head_dim (k/v projections)": cfg.n_kv_heads * hd,
        "d_ff (mlp wi/wo)": cfg.d_ff,
        "vocab (embed/unembed)": cfg.vocab,
    }
    bad = {name: d for name, d in dims.items() if d % m}
    if bad:
        usable = [k for k in range(1, m + 1)
                  if all(d % k == 0 for d in dims.values())]
        lines = ", ".join(f"{name}={d}" for name, d in bad.items())
        raise ValueError(
            f"model mesh axis of {m} devices does not divide {lines} for "
            f"arch {getattr(model_cfg, 'arch_id', type(cfg).__name__)!r}; "
            f"usable {axis!r} sizes here: {usable} (pick one, or drop the "
            f"model axis)")
    return m


def validate_client_mesh(mesh: Mesh, clients_per_round: int,
                         axis: str = CLIENT_AXIS) -> int:
    """Check the mesh can shard ``clients_per_round``; return shard count."""
    if axis not in mesh.axis_names:
        raise ValueError(
            f"mesh axes {mesh.axis_names} have no {axis!r} axis; build one "
            f"with repro.launch.mesh.make_client_mesh()")
    n = mesh.shape[axis]
    if clients_per_round % n != 0:
        raise ValueError(
            f"clients_per_round={clients_per_round} must divide evenly over "
            f"the {n}-device {axis!r} mesh axis")
    return n


def shard_round(round_impl: Callable, mesh: Mesh, clients_per_round: int,
                axis: str = CLIENT_AXIS) -> Callable:
    """Wrap ``_round_impl(state, key, ctx)`` in ``shard_map`` over ``mesh``.

    Returns a drop-in ``(state, key) -> (state, metrics)`` with replicated
    in/out specs: the sampled-client slicing happens *inside* via
    ``ShardCtx`` (axis_index-based), and every output is either psum- or
    all_gather-reassembled, so the wrapper composes with ``jax.jit`` and
    ``lax.scan`` exactly like the unsharded implementation.

    With extra mesh axes of size > 1 (a composed clients x data x model
    mesh) there is no ``shard_map`` wrapper at all — the round runs as a
    plain GSPMD program under a :class:`ModelShardCtx`: model-sharded
    parameters placed by ``sharding.specs.param_shardings`` stay sharded
    through the per-client math (sharded ``lax.scan`` only works outside
    manual regions — see the §9 comment block above), and the wire path
    runs shard-local in fully-manual islands.
    """
    n = validate_client_mesh(mesh, clients_per_round, axis)
    extra = tuple(a for a in mesh.axis_names if a != axis)
    if any(mesh.shape[a] > 1 for a in extra):
        gctx = ModelShardCtx(mesh, axis)

        def run_gspmd(state, key):
            return round_impl(state, key, ctx=gctx)

        return run_gspmd

    ctx = ShardCtx(axis, n)

    def run(state, key):
        return round_impl(state, key, ctx=ctx)

    return _shard_map(run, mesh=mesh, in_specs=(P(), P()),
                      out_specs=(P(), P()), **_SM_KWARGS)


def usable_shard_counts(clients_per_round: int,
                        max_devices: int | None = None) -> Sequence[int]:
    """Divisors of ``clients_per_round`` realisable on this host's devices
    (ascending) — the sweep axis for tests and benchmarks."""
    cap = len(jax.devices()) if max_devices is None else max_devices
    return [d for d in range(1, min(clients_per_round, cap) + 1)
            if clients_per_round % d == 0]
