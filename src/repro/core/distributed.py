"""Client-axis device parallelism: ``shard_map`` federated rounds.

The paper's experiments (100 clients, 10 sampled per round) are
embarrassingly parallel along the client axis, but the round
implementations vmap the sampled clients onto one device.  This module
splits that axis across a ``clients`` mesh axis (DESIGN.md §6):

* :class:`ShardCtx` — the sharded implementation of
  :class:`repro.core.clients.ClientAxisCtx`: per-client work (local SGD,
  TopK/Q_r compression, RNG keys, ``RoundPlan`` vectors) runs shard-local
  on an ``s/D`` slice of the sampled clients, and every cross-client
  reduction is an explicit collective — ``psum`` for model-tree means and
  masked sums, ``all_gather`` for the (s,) metric vectors;
* :func:`shard_round` — wraps an algorithm's ``_round_impl`` in
  ``shard_map`` over the mesh.  State and key go in replicated and come
  out replicated, so ``lax.scan`` over rounds (the fused
  ``RoundEngine.run_rounds`` engine) stays a single jit with the
  ``shard_map`` inside the scan body.

Determinism contract (tests/test_distributed.py): per-client RNG keys are
split from the *full* (s,) chain and then sliced, so each client computes
exactly what it computes unsharded; metric scalars (``uplink_bits`` /
``downlink_bits``, ``client_steps``, ``sim_time``) are derived from
``all_gather``-ed full vectors with the unsharded formula and are therefore
**bit-identical** at any device count, while psum-reduced model trees
(server mean, control variates) are allclose (summation order changes with
D).  On a 1-device mesh everything — params included — is bit-identical.

The persistent (n_clients, ...) client state stays replicated: sampling
draws arbitrary global indices each round, so a round gathers its s rows
replicated (cheap — s << n_clients) and scatters them back with a
psum-of-disjoint-rows trick that is exact because ``replace=False``
sampling makes shard contributions disjoint.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.clients import ClientAxisCtx, per_client

try:  # jax >= 0.6 exports shard_map at the top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

# older jax wants check_rep=False for axis_index-based slicing; the kwarg
# was renamed/retired in newer releases, so pass it only when accepted.
_SM_KWARGS = ({"check_rep": False}
              if "check_rep" in inspect.signature(_shard_map).parameters
              else {})

PyTree = Any

CLIENT_AXIS = "clients"


class ShardCtx(ClientAxisCtx):
    """Sharded view of the sampled-client axis inside ``shard_map``."""

    def __init__(self, axis_name: str, n_shards: int):
        self.axis = axis_name
        self.n_shards = n_shards

    def local_count(self, s: int) -> int:
        return s // self.n_shards

    def shard(self, arr: jax.Array) -> jax.Array:
        nl = arr.shape[0] // self.n_shards
        start = jax.lax.axis_index(self.axis) * nl
        return jax.lax.dynamic_slice_in_dim(arr, start, nl, axis=0)

    def shard_tree(self, tree: PyTree) -> PyTree:
        return jax.tree_util.tree_map(self.shard, tree)

    def all_clients(self, vec: jax.Array) -> jax.Array:
        # tiled gather in axis order == the inverse of ``shard``'s slicing,
        # so the reassembled vector matches the unsharded one row-for-row
        return jax.lax.all_gather(vec, self.axis, axis=0, tiled=True)

    def psum(self, x):
        return jax.tree_util.tree_map(
            lambda t: jax.lax.psum(t, self.axis), x)

    def all_clients_tree(self, tree: PyTree) -> PyTree:
        """Tiled all_gather of every (s/D, ...) leaf back to (s, ...).

        This is the §8 wire-mode uplink collective: gathering a packed
        ``Payload`` pytree moves its packed buffers — uint32 index/code
        words, sub-byte level planes, int8 levels — across the mesh
        instead of dense fp32 trees, which is where the ~32/r× wire saving
        physically happens.  Row order matches ``shard``'s slicing, so the
        reassembled client axis is identical to the unsharded one.
        """
        return jax.tree_util.tree_map(self.all_clients, tree)

    def mean_clients(self, stacked: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda t: jax.lax.psum(t.sum(axis=0), self.axis)
            / (t.shape[0] * self.n_shards), stacked)

    def sum_clients(self, stacked: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda t: jax.lax.psum(t.sum(axis=0), self.axis), stacked)

    def scatter_rows(self, full: PyTree, idx: jax.Array, upd: PyTree
                     ) -> PyTree:
        """Exact cross-shard scatter into a replicated (n, ...) store.

        Each shard zero-fills a copy, writes its rows, and the psum merges
        them: sampling without replacement makes the written rows disjoint,
        so every touched row receives exactly one shard's value plus zeros
        (exact in fp), and untouched rows keep the old value via the mask.
        """
        n_rows = jax.tree_util.tree_leaves(full)[0].shape[0]
        touched = jnp.zeros((n_rows,), jnp.int32).at[idx].set(1)
        touched = jax.lax.psum(touched, self.axis) > 0

        def one(f, u):
            contrib = jax.lax.psum(jnp.zeros_like(f).at[idx].set(u),
                                   self.axis)
            return jnp.where(per_client(touched, f), contrib, f)

        return jax.tree_util.tree_map(one, full, upd)


# --------------------------------------------------------------------------- #


def validate_client_mesh(mesh: Mesh, clients_per_round: int,
                         axis: str = CLIENT_AXIS) -> int:
    """Check the mesh can shard ``clients_per_round``; return shard count."""
    if axis not in mesh.axis_names:
        raise ValueError(
            f"mesh axes {mesh.axis_names} have no {axis!r} axis; build one "
            f"with repro.launch.mesh.make_client_mesh()")
    n = mesh.shape[axis]
    if clients_per_round % n != 0:
        raise ValueError(
            f"clients_per_round={clients_per_round} must divide evenly over "
            f"the {n}-device {axis!r} mesh axis")
    return n


def shard_round(round_impl: Callable, mesh: Mesh, clients_per_round: int,
                axis: str = CLIENT_AXIS) -> Callable:
    """Wrap ``_round_impl(state, key, ctx)`` in ``shard_map`` over ``mesh``.

    Returns a drop-in ``(state, key) -> (state, metrics)`` with replicated
    in/out specs: the sampled-client slicing happens *inside* via
    ``ShardCtx`` (axis_index-based), and every output is either psum- or
    all_gather-reassembled, so the wrapper composes with ``jax.jit`` and
    ``lax.scan`` exactly like the unsharded implementation.
    """
    n = validate_client_mesh(mesh, clients_per_round, axis)
    ctx = ShardCtx(axis, n)

    def run(state, key):
        return round_impl(state, key, ctx=ctx)

    return _shard_map(run, mesh=mesh, in_specs=(P(), P()),
                      out_specs=(P(), P()), **_SM_KWARGS)


def usable_shard_counts(clients_per_round: int,
                        max_devices: int | None = None) -> Sequence[int]:
    """Divisors of ``clients_per_round`` realisable on this host's devices
    (ascending) — the sweep axis for tests and benchmarks."""
    cap = len(jax.devices()) if max_devices is None else max_devices
    return [d for d in range(1, min(clients_per_round, cap) + 1)
            if clients_per_round % d == 0]
