"""Aggregation policies: sync, semi-sync wait-for-K, FedBuff-style async.

The paper's rounds are fully synchronous — every sampled client reports
before the server moves, so one straggler sets the round clock (the §5
``sim_time`` cost model quantifies exactly how much wall-clock that
wastes).  This module is the event-driven answer (DESIGN.md §7): every
round implementation runs unchanged under one of three policies on the
``ClientSchedule`` sim-time clock:

* ``sync`` — today's path, graph-for-graph unchanged: the server waits for
  the slowest sampled client, then averages every (plan-)participant.
* ``semi_sync(K)`` — the server aggregates as soon as the K fastest sampled
  clients have *finished* (local phase + uplink on the sim-time clock).
  The rest are carried as stragglers exactly like §5 deadline drops:
  they transmit nothing this round, keep their control variates, and are
  excluded from the server average.  ``sim_time`` is the K-th smallest
  finish time instead of the max.  Selection uses the same sort-based
  dynamic-k threshold semantics as the §5 TopK machinery (ties at the
  K-th finish time are all kept), so ``K = clients_per_round`` reproduces
  the sync policy bit-identically on every metric.
* ``async_buffered(capacity, alpha)`` — FedBuff-style buffered
  aggregation (Nguyen et al., 2022): client updates (deltas from the
  broadcast anchor) arrive in finish-time order and the server flushes
  its fixed-``capacity`` buffer every ``capacity`` arrivals, applying
  each buffer mean scaled by the staleness weight ``w/(1+staleness)^α``
  where an update's staleness is the number of server applications since
  its anchor was broadcast.  One engine round = one sampled cohort =
  ``s/capacity`` server applications, all inside the fused ``lax.scan``
  (one jit still drives R rounds).  At ``capacity = clients_per_round``
  there is a single flush with staleness 0, reproducing sync's metrics
  bit-identically (params allclose: the server update is applied in
  delta form).

The cohort simplification (the buffer refills from a fresh sample each
engine round, so staleness spans ``0..s/capacity-1``) is what keeps every
shape static and the RNG key chain identical to the sync engine — see
DESIGN.md §7 for why bits accounting survives buffering unchanged.

Everything is computed from the *replicated* full ``(s,)`` plan/bits
vectors with the unsharded formula, so under the §6 ``shard_map`` mesh
the policy outcome — participation, staleness, weights, ``sim_time`` —
is bit-identical at every device count.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

MODES = ("sync", "semi_sync", "async_buffered")


@dataclasses.dataclass(frozen=True)
class AggregationPolicy:
    """How the server combines one round's sampled-client updates.

    ``wait_for`` (semi_sync) and ``capacity`` (async_buffered) default to
    ``clients_per_round`` at validation time — the neutral settings that
    reproduce the sync engine exactly.  ``alpha`` is the staleness
    exponent of the FedBuff weight ``1/(1+staleness)^alpha``.
    """

    mode: str = "sync"
    wait_for: Optional[int] = None     # K (semi_sync)
    capacity: Optional[int] = None     # buffer size (async_buffered)
    alpha: float = 0.0                 # staleness exponent (async_buffered)

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.wait_for is not None and self.wait_for <= 0:
            raise ValueError("wait_for must be positive")
        if self.capacity is not None and self.capacity <= 0:
            raise ValueError("capacity must be positive")
        if self.alpha < 0:
            raise ValueError("alpha must be non-negative")
        if self.mode != "semi_sync" and self.wait_for is not None:
            raise ValueError("wait_for only applies to semi_sync")
        if self.mode != "async_buffered" and (self.capacity is not None
                                              or self.alpha != 0.0):
            raise ValueError("capacity/alpha only apply to async_buffered")

    # -- constructors ---------------------------------------------------- #

    @classmethod
    def sync(cls) -> "AggregationPolicy":
        return cls()

    @classmethod
    def semi_sync(cls, wait_for: int) -> "AggregationPolicy":
        return cls(mode="semi_sync", wait_for=wait_for)

    @classmethod
    def async_buffered(cls, capacity: Optional[int] = None,
                       alpha: float = 0.0) -> "AggregationPolicy":
        return cls(mode="async_buffered", capacity=capacity, alpha=alpha)

    # -- derived --------------------------------------------------------- #

    @property
    def is_sync(self) -> bool:
        return self.mode == "sync"

    @property
    def may_exclude(self) -> bool:
        """True if the policy itself can exclude a sampled client from the
        aggregate (semi_sync stragglers) — round implementations gate the
        control-variate/EF keep-old paths on this, exactly like §5 drops."""
        return self.mode == "semi_sync"


SYNC = AggregationPolicy()


@dataclasses.dataclass(frozen=True)
class HierarchicalPolicy:
    """Two-tier edge→server aggregation (DESIGN.md §11).

    Cross-device deployments aggregate through regional edge servers: the
    ``s`` sampled clients split into ``n_edges`` contiguous groups of
    ``s/n_edges``, each edge runs its own §7 ``edge`` policy over its
    group on the client finish clock, and the central server runs the
    ``server`` policy over *edge arrival times* (each edge's tier-1
    ``sim_time`` plus ``edge_latency``, the edge→server hop).  Both tiers
    reuse the flat sync / semi_sync / async_buffered machinery unchanged
    — the composition happens in the outcome vectors:

    * ``participating`` — client ∩ its edge aggregated it ∩ the server
      aggregated its edge;
    * ``weight`` — normalised so ``masked_mean(x, weight,
      weight_sum=n_selected)`` is the weighted mean of *edge means* (the
      quantity the server actually receives), not the flat client mean;
    * ``coef``/``discount`` — per-tier factors multiply, so the async
      delta-combine ``Σ coef_i·Δ_i`` telescopes to "server combines edge
      combines";
    * ``staleness`` — tiers add;
    * ``sim_time`` — the server tier's clock.

    With ``sync``/``sync`` tiers, zero latency and no drops, every edge
    mean carries equal weight and the outcome equals the flat sync policy
    (edge means average to the client mean).
    """

    edge: AggregationPolicy = dataclasses.field(
        default_factory=AggregationPolicy)
    server: AggregationPolicy = dataclasses.field(
        default_factory=AggregationPolicy)
    n_edges: int = 1
    edge_latency: float = 0.0

    def __post_init__(self):
        if self.n_edges <= 0:
            raise ValueError("n_edges must be positive")
        if self.edge_latency < 0:
            raise ValueError("edge_latency must be non-negative")
        for tier in (self.edge, self.server):
            if not isinstance(tier, AggregationPolicy):
                raise TypeError("edge/server tiers must be flat "
                                "AggregationPolicy instances")

    @property
    def mode(self) -> str:
        return "hierarchical"

    @property
    def is_sync(self) -> bool:
        return False

    @property
    def may_exclude(self) -> bool:
        """Hierarchical outcomes are *weighted* (mean of edge means), so
        round implementations must always take the masked/weighted
        aggregation path — and either tier may genuinely exclude."""
        return True


def uses_delta_combine(policy) -> bool:
    """True if the round must apply the server update in delta form
    (``Σ coef_i·Δ_i``) — flat async_buffered, or a hierarchical policy
    with an async tier (the composed ``coef`` telescopes both tiers)."""
    if isinstance(policy, HierarchicalPolicy):
        return (policy.edge.mode == "async_buffered"
                or policy.server.mode == "async_buffered")
    return policy.mode == "async_buffered"


def validate_policy(policy, clients_per_round: int):
    """Resolve ``None``/defaults against ``clients_per_round`` and check
    realisability (host-side, at construction time)."""
    if policy is None:
        return SYNC
    if isinstance(policy, HierarchicalPolicy):
        s = clients_per_round
        if s % policy.n_edges != 0:
            raise ValueError(
                f"n_edges={policy.n_edges} must divide clients_per_round="
                f"{s} (contiguous equal-size edge groups)")
        k = s // policy.n_edges
        return dataclasses.replace(
            policy,
            edge=validate_policy(policy.edge, k),
            server=validate_policy(policy.server, policy.n_edges))
    if not isinstance(policy, AggregationPolicy):
        raise TypeError(f"policy must be an AggregationPolicy, got "
                        f"{type(policy).__name__}")
    s = clients_per_round
    if policy.mode == "semi_sync":
        k = s if policy.wait_for is None else policy.wait_for
        if not (1 <= k <= s):
            raise ValueError(
                f"semi_sync wait_for={k} must be in [1, clients_per_round="
                f"{s}]")
        return dataclasses.replace(policy, wait_for=k)
    if policy.mode == "async_buffered":
        cap = s if policy.capacity is None else policy.capacity
        if not (1 <= cap <= s) or s % cap != 0:
            # the buffer flushes s/cap times per sampled cohort; a ragged
            # final flush would need a data-dependent shape inside the scan
            raise ValueError(
                f"async_buffered capacity={cap} must divide "
                f"clients_per_round={s}")
        return dataclasses.replace(policy, capacity=cap)
    return policy


class PolicyOutcome(NamedTuple):
    """One round's resolved aggregation decision (replicated (s,) vectors).

    ``participating`` already folds the plan's §5 straggler mask together
    with the policy's own exclusions; ``coef`` is the per-client weight of
    the *delta-form* server application ``x + Σ_i coef_i·Δ_i`` (async
    path), folding participation, the staleness weight and the per-flush
    buffer-mean divisor; ``discount`` is the un-normalised staleness
    weight ``partf/(1+staleness)^α`` (FedDyn-style delta *sums*).
    """

    participating: jax.Array   # (s,) bool — plan ∩ policy
    partf: jax.Array           # (s,) f32 — participating as float
    n_selected: jax.Array      # () f32 — partf.sum()
    sim_time: jax.Array        # () f32 — this round's simulated wall-clock
    finish: jax.Array          # (s,) f32 — per-client finish times
    staleness: jax.Array       # (s,) f32 — flush index (0 for sync/semi)
    coef: jax.Array            # (s,) f32 — delta-form aggregation weights
    discount: jax.Array        # (s,) f32 — partf / (1+staleness)^alpha
    # (s,) f32 mean-aggregation weights: Σ weight == n_selected, and
    # masked_mean(x, weight, weight_sum=n_selected) is the server mean.
    # Flat policies set this to the SAME array as partf (bit-identical
    # graphs); hierarchical outcomes reweight it to the mean-of-edge-means.
    weight: jax.Array
    # () f32 — edges the server tier aggregated (hierarchical only)
    edges_aggregated: Optional[jax.Array] = None


def _outcome_from_finish(policy: AggregationPolicy, participating: jax.Array,
                         finish: jax.Array) -> PolicyOutcome:
    """Resolve one flat policy from a participation mask + finish clock.

    This is the §7 tier primitive: ``apply_policy`` feeds it the client
    plan and finish times; the hierarchical composition vmaps it over
    edge groups and then runs it again over edge arrival times.
    """
    s = finish.shape[0]
    partf_plan = participating.astype(jnp.float32)

    if policy.mode == "semi_sync":
        k = policy.wait_for
        # sort-based dynamic-k threshold (same semantics as §5 TopK): the
        # K-th smallest finish time; ties at the threshold are all kept.
        # Only plan participants count toward K — a §5-dropped straggler
        # never finishes or transmits, so its deadline-held finish must
        # not crowd a real report out of the buffer (sorted last as +inf).
        finish_eff = jnp.where(participating, finish, jnp.inf)
        kth = jnp.sort(finish_eff)[k - 1]
        part = (finish_eff <= kth) & participating
        partf = part.astype(jnp.float32)
        # fewer than K participants: every report arrives and the dropped
        # stragglers hold the round open until the deadline (sync rule)
        sim_time = jnp.where(jnp.isinf(kth), jnp.max(finish), kth)
        zeros = jnp.zeros((s,), jnp.float32)
        return PolicyOutcome(
            participating=part, partf=partf,
            n_selected=partf.sum(), sim_time=sim_time, finish=finish,
            staleness=zeros, coef=partf / jnp.maximum(partf.sum(), 1.0),
            discount=partf, weight=partf)

    if policy.mode == "async_buffered":
        cap = policy.capacity
        # arrival order on the sim-time clock; plan-dropped stragglers
        # never arrive (sorted last via +inf) and take no buffer slot
        finish_eff = jnp.where(participating, finish, jnp.inf)
        order = jnp.argsort(finish_eff)
        ranks = jnp.zeros((s,), jnp.int32).at[order].set(
            jnp.arange(s, dtype=jnp.int32))
        flush = ranks // cap                       # which buffer flush
        staleness = flush.astype(jnp.float32) * partf_plan
        discount = partf_plan * jnp.power(1.0 + staleness, -policy.alpha)
        # participants in flush j: the last flush may be part-filled when
        # plan drops thin the cohort; each flush applies its buffer *mean*
        n_part = partf_plan.sum()
        n_flush = jnp.clip(n_part - flush.astype(jnp.float32) * cap,
                           0.0, float(cap))
        coef = discount / jnp.maximum(n_flush, 1.0)
        return PolicyOutcome(
            participating=participating, partf=partf_plan,
            n_selected=n_part, sim_time=jnp.max(finish), finish=finish,
            staleness=staleness, coef=coef, discount=discount,
            weight=partf_plan)

    # sync: today's semantics, same formula graph (sim_time = max finish)
    zeros = jnp.zeros((s,), jnp.float32)
    return PolicyOutcome(
        participating=participating, partf=partf_plan,
        n_selected=partf_plan.sum(), sim_time=jnp.max(finish),
        finish=finish, staleness=zeros,
        coef=partf_plan / jnp.maximum(partf_plan.sum(), 1.0),
        discount=partf_plan, weight=partf_plan)


def _apply_hierarchical(policy: HierarchicalPolicy, participating: jax.Array,
                        finish: jax.Array) -> PolicyOutcome:
    """Compose two §7 tiers over contiguous edge groups (DESIGN.md §11)."""
    s = finish.shape[0]
    e = policy.n_edges
    k = s // e
    edge = jax.vmap(lambda p, f: _outcome_from_finish(policy.edge, p, f))(
        participating.reshape(e, k), finish.reshape(e, k))
    # each edge's aggregate reaches the server one hop after its tier-1
    # clock closes; an empty edge (every client dropped) sends nothing
    srv = _outcome_from_finish(
        policy.server, edge.n_selected > 0,
        edge.sim_time + policy.edge_latency)

    part = (edge.participating & srv.participating[:, None]).reshape(s)
    partf = part.astype(jnp.float32)
    n_sel = partf.sum()
    # server mean = Σ_e srv_w_e/E_agg · (Σ_i edge_w_i x_i / n_e): scale so
    # Σ weight == n_selected and the masked_mean call sites' divisor
    # (weight_sum=n_selected) cancels back to the mean of edge means
    edge_wn = edge.weight / jnp.maximum(edge.n_selected, 1.0)[:, None]
    srv_wn = srv.weight / jnp.maximum(srv.n_selected, 1.0)
    weight = n_sel * (srv_wn[:, None] * edge_wn).reshape(s)
    return PolicyOutcome(
        participating=part, partf=partf, n_selected=n_sel,
        sim_time=srv.sim_time, finish=finish,
        staleness=(edge.staleness + srv.staleness[:, None]).reshape(s),
        coef=(edge.coef * srv.coef[:, None]).reshape(s),
        discount=(edge.discount * srv.discount[:, None]).reshape(s),
        weight=weight, edges_aggregated=srv.n_selected)


def apply_policy(policy, sched, plan,
                 client_bits_full: jax.Array) -> PolicyOutcome:
    """Resolve one round's policy from the full replicated plan + bits.

    ``client_bits_full`` is the (s,) wire cost each plan-participant would
    transmit (0 for §5-dropped stragglers) — the uplink term of the finish
    clock.  All inputs and outputs are replicated full vectors, so the
    outcome is bit-identical at every §6 device count.
    """
    finish = sched.finish_times(plan, client_bits_full)
    if isinstance(policy, HierarchicalPolicy):
        return _apply_hierarchical(policy, plan.participating, finish)
    return _outcome_from_finish(policy, plan.participating, finish)


class ResolvedPolicy(NamedTuple):
    """One round's policy outcome plus the shard-local/derived views every
    round implementation needs — the single resolution point, so the four
    algorithms cannot drift apart in how they consume a policy."""

    out: PolicyOutcome
    part: jax.Array        # shard-local bool participation (plan ∩ policy)
    partf: jax.Array       # shard-local f32 participation
    may_exclude: bool      # static: gate keep-old control-variate paths
    client_up: jax.Array   # full (s,) applied wire bits (excluded -> 0)
    weight: jax.Array      # shard-local f32 mean-aggregation weights


def resolve_policy(policy, sched, plan,
                   client_bits_full: jax.Array, ctx) -> ResolvedPolicy:
    """``apply_policy`` + the standard derived views (shard-local masks,
    the §5-composed ``may_exclude`` flag, and the applied per-client wire
    cost — an excluded client's update never reaches the server)."""
    out = apply_policy(policy, sched, plan, client_bits_full)
    part = ctx.shard(out.participating)
    return ResolvedPolicy(
        out=out, part=part, partf=part.astype(jnp.float32),
        may_exclude=sched.may_drop or policy.may_exclude,
        client_up=client_bits_full * out.partf,
        weight=ctx.shard(out.weight))


def async_weighted_sum(out: PolicyOutcome, stacked, ctx):
    """Staleness-weighted delta combine ``Σ_i coef_i · stacked_i`` over the
    client axis (the async server application, in delta form).  ``stacked``
    is shard-local under a §6 ctx; ``out.coef`` is replicated and sliced
    here, and the cross-shard reduction is one psum."""
    from repro.core.clients import per_client
    coef_l = ctx.shard(out.coef)
    return ctx.psum(jax.tree_util.tree_map(
        lambda t: (t * per_client(coef_l, t)).sum(axis=0), stacked))


def policy_metrics(out: PolicyOutcome) -> dict:
    """The per-round metric entries every policy-aware round emits: the
    staleness vector rides the §5 vector-metrics path through the fused
    engine; ``clients_aggregated`` is the number of updates the server
    actually applied this round."""
    metrics = {"client_staleness": out.staleness,
               "clients_aggregated": out.n_selected}
    if out.edges_aggregated is not None:
        metrics["edges_aggregated"] = out.edges_aggregated
    return metrics
