"""Client-heterogeneity layer: profiles, schedules, straggler rounds.

The paper's experiments model *data* heterogeneity (Dirichlet alpha) but run
every client with the same step count, compressor and density.  Real FL
deployments are dominated by *system* heterogeneity — device speed and
uplink bandwidth vary by orders of magnitude — and per-client bit budgets
are exactly the plug-in point the compression subsystem (DESIGN.md §3)
promises.  This module is the layer every heterogeneous scenario plugs into
(DESIGN.md §5):

* :class:`ClientProfile` — static per-client attributes: relative compute
  ``speed``, relative uplink ``bandwidth``, and per-client compressor
  parameter arrays (``comp_params``, e.g. ``{"density": (n,)}``) routed to
  ``Compressor.compress(**overrides)`` as traced values under ``vmap``;
* :class:`ClientSchedule` — resolves a round's sampled clients into a
  :class:`RoundPlan`: per-client local-step counts (a straggler ``deadline``
  truncates slow clients; ``drop_stragglers`` removes clients that finish
  zero steps from the aggregate entirely), the participation mask, and the
  per-client compressor overrides;
* ``sim_time`` — the round's simulated wall-clock: the server waits for the
  slowest sampled client, ``max_i(steps_i·step_cost/speed_i +
  bits_i·bit_cost/bandwidth_i)``.

Everything is jit/scan-safe: profiles are device arrays gathered by the
sampled-client indices inside the round graph, so the fused ``run_rounds``
engine (DESIGN.md §3.4) carries heterogeneous rounds bit-identically to the
per-round driver.  A homogeneous schedule (the default everywhere) plans
``steps_i = nominal`` for every client and no overrides, reproducing the
homogeneous trajectories exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class RoundPlan(NamedTuple):
    """One round's resolved schedule for the ``s`` sampled clients."""

    steps: jax.Array          # (s,) int32 — local steps each client completes
    participating: jax.Array  # (s,) bool — False = straggler dropped
    speed: jax.Array          # (s,) float32 — relative compute speed
    bandwidth: jax.Array      # (s,) float32 — relative uplink bandwidth
    comp_overrides: Dict[str, jax.Array]  # name -> (s,) per-client values
    # (s,) bool — False = the availability process (§11) marked this
    # sampled slot offline: it never starts, transmits nothing, holds
    # nothing open.  ``None`` (the default) means no availability process
    # is attached and every sampled client is online.
    available: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class ClientProfile:
    """Static per-client system attributes (device arrays over n_clients).

    ``speed`` and ``bandwidth`` are relative rates (1.0 = reference device).
    ``comp_params`` maps compressor override names (``TopK.density``,
    ``QuantQr.r`` — see ``Compressor.param_overrides``) to per-client value
    arrays.
    """

    speed: jax.Array
    bandwidth: jax.Array
    comp_params: Mapping[str, jax.Array] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        speed = jnp.asarray(self.speed, jnp.float32)
        bandwidth = jnp.asarray(self.bandwidth, jnp.float32)
        object.__setattr__(self, "speed", speed)
        object.__setattr__(self, "bandwidth", bandwidth)
        if speed.ndim != 1 or bandwidth.shape != speed.shape:
            raise ValueError(
                f"speed/bandwidth must be matching (n,) arrays, got "
                f"{speed.shape} / {bandwidth.shape}")
        if not (np.all(np.asarray(speed) > 0)
                and np.all(np.asarray(bandwidth) > 0)):
            raise ValueError("speed and bandwidth must be positive")
        object.__setattr__(
            self, "comp_params",
            {k: jnp.asarray(v) for k, v in dict(self.comp_params).items()})
        for name, v in self.comp_params.items():
            if v.shape != speed.shape:
                raise ValueError(
                    f"comp_params[{name!r}] must have shape {speed.shape}, "
                    f"got {v.shape}")

    @property
    def n_clients(self) -> int:
        return self.speed.shape[0]

    # -- constructors ---------------------------------------------------- #

    @classmethod
    def homogeneous(cls, n_clients: int) -> "ClientProfile":
        ones = jnp.ones((n_clients,), jnp.float32)
        return cls(speed=ones, bandwidth=ones)

    @classmethod
    def lognormal(cls, n_clients: int, *, speed_sigma: float = 0.5,
                  bandwidth_sigma: float = 0.0, seed: int = 0
                  ) -> "ClientProfile":
        """Median-1 lognormal speeds/bandwidths (heavy straggler tail)."""
        rng = np.random.default_rng(seed)
        speed = rng.lognormal(0.0, speed_sigma, n_clients)
        bw = (rng.lognormal(0.0, bandwidth_sigma, n_clients)
              if bandwidth_sigma > 0 else np.ones(n_clients))
        return cls(speed=jnp.asarray(speed, jnp.float32),
                   bandwidth=jnp.asarray(bw, jnp.float32))

    @classmethod
    def uniform(cls, n_clients: int, *, lo: float = 0.5, hi: float = 2.0,
                bandwidth_lo: Optional[float] = None,
                bandwidth_hi: Optional[float] = None, seed: int = 0
                ) -> "ClientProfile":
        """Speeds (and optionally bandwidths) uniform in [lo, hi]."""
        rng = np.random.default_rng(seed)
        speed = rng.uniform(lo, hi, n_clients)
        if bandwidth_lo is None:
            bw = np.ones(n_clients)
        else:
            bw = rng.uniform(bandwidth_lo,
                             bandwidth_hi if bandwidth_hi is not None
                             else bandwidth_lo, n_clients)
        return cls(speed=jnp.asarray(speed, jnp.float32),
                   bandwidth=jnp.asarray(bw, jnp.float32))

    # -- derived profiles ------------------------------------------------ #

    def with_comp_param(self, name: str, values) -> "ClientProfile":
        params = dict(self.comp_params)
        params[name] = jnp.asarray(values)
        return dataclasses.replace(self, comp_params=params)

    def with_density_allocation(self, base_density: float,
                                mode: str = "uniform",
                                floor: float = 0.01) -> "ClientProfile":
        """Attach a per-client TopK ``density`` allocation.

        ``mode="uniform"`` gives every client ``base_density``;
        ``mode="bandwidth"`` allocates the same *total* bit budget
        proportionally to each client's bandwidth (d_i ∝ bw_i, clipped to
        [floor, 1]), so fast links carry denser payloads.  The allocation
        preserves the budget invariant ``mean(d) == base_density``: when
        the clip binds, the pre-clip slope is rescaled (host-side
        bisection) so the clipped mean still lands on ``base_density``
        instead of silently drifting.
        """
        if mode == "uniform":
            d = jnp.full((self.n_clients,), base_density, jnp.float32)
        elif mode == "bandwidth":
            if not (floor <= base_density <= 1.0):
                raise ValueError(
                    f"base_density={base_density} outside [floor={floor}, "
                    "1.0]: the clipped allocation cannot average to it")
            raw = np.asarray(self.bandwidth, np.float64)
            raw = raw / raw.mean()
            clipped = np.clip(base_density * raw, floor, 1.0)
            if abs(clipped.mean() - base_density) <= 1e-9:
                # clip doesn't bind — keep the original (traced) formula
                rel = self.bandwidth / jnp.mean(self.bandwidth)
                d = jnp.clip(base_density * rel, floor, 1.0)
            else:
                # mean(clip(c·raw, floor, 1)) is monotone in c and spans
                # [floor, 1] ∋ base_density: bisect the slope host-side
                lo, hi = 0.0, base_density
                while np.clip(hi * raw, floor, 1.0).mean() < base_density:
                    hi *= 2.0
                for _ in range(80):
                    mid = 0.5 * (lo + hi)
                    if np.clip(mid * raw, floor, 1.0).mean() < base_density:
                        lo = mid
                    else:
                        hi = mid
                d = jnp.asarray(np.clip(hi * raw, floor, 1.0), jnp.float32)
        else:
            raise ValueError(f"unknown allocation mode {mode!r}")
        return self.with_comp_param("density", d)


@dataclasses.dataclass(frozen=True)
class ClientAvailability:
    """Population availability process (DESIGN.md §11).

    Cross-device populations are never fully online: devices follow
    diurnal (timezone-staggered) usage cycles and churn in and out of the
    population.  This models both as a *deterministic* per-round weight
    trace — a pure function of ``round_idx`` — so fused-scan rounds and
    checkpoint-resumed runs see identical traces:

    * diurnal: ``w_i(t) = 1 - amp·(0.5 + 0.5·sin(2π(t/period + φ_i)))``
      with per-client phase ``φ_i`` (the client's timezone); ``amp=1``
      takes each client to fully offline at its local night trough;
    * churn: staggered epoch gating — client i is in the population iff
      ``frac(t·churn_rate + ψ_i) < online_frac``, so every round a
      ``churn_rate`` fraction of clients departs and (a disjoint equal
      fraction) arrives, with ``online_frac`` of the population present
      in steady state.

    ``weights(t)`` is the (n,) sampling weight; a zero weight means the
    client is offline that round.  The cohort sampler
    (:meth:`ClientSchedule.sample_cohort`) draws proportionally to these
    weights and flags any offline pick in ``RoundPlan.available``.
    """

    phase: jax.Array                  # (n,) diurnal phase in [0, 1)
    stagger: jax.Array                # (n,) churn stagger in [0, 1)
    period: float = 24.0              # rounds per diurnal cycle
    amp: float = 0.8                  # diurnal modulation depth in [0, 1]
    churn_rate: float = 0.0           # population fraction cycling per round
    online_frac: float = 1.0          # steady-state in-population fraction

    def __post_init__(self):
        phase = jnp.asarray(self.phase, jnp.float32)
        stagger = jnp.asarray(self.stagger, jnp.float32)
        object.__setattr__(self, "phase", phase)
        object.__setattr__(self, "stagger", stagger)
        if phase.ndim != 1 or stagger.shape != phase.shape:
            raise ValueError("phase/stagger must be matching (n,) arrays")
        if not 0.0 <= self.amp <= 1.0:
            raise ValueError("amp must be in [0, 1]")
        if self.period <= 0:
            raise ValueError("period must be positive")
        if self.churn_rate < 0:
            raise ValueError("churn_rate must be non-negative")
        if not 0.0 < self.online_frac <= 1.0:
            raise ValueError("online_frac must be in (0, 1]")

    @property
    def n_clients(self) -> int:
        return self.phase.shape[0]

    @classmethod
    def diurnal(cls, n_clients: int, *, period: float = 24.0,
                amp: float = 0.8, churn_rate: float = 0.0,
                online_frac: float = 1.0, seed: int = 0
                ) -> "ClientAvailability":
        """Uniform-random timezones and churn staggers over the population."""
        rng = np.random.default_rng(seed)
        return cls(phase=jnp.asarray(rng.random(n_clients), jnp.float32),
                   stagger=jnp.asarray(rng.random(n_clients), jnp.float32),
                   period=period, amp=amp, churn_rate=churn_rate,
                   online_frac=online_frac)

    def weights(self, round_idx) -> jax.Array:
        """The (n,) availability weight at ``round_idx`` (in-graph)."""
        t = jnp.asarray(round_idx, jnp.float32)
        w = 1.0 - self.amp * (0.5 + 0.5 * jnp.sin(
            2.0 * jnp.pi * (t / self.period + self.phase)))
        if self.churn_rate > 0.0 and self.online_frac < 1.0:
            u = jnp.mod(t * self.churn_rate + self.stagger, 1.0)
            w = jnp.where(u < self.online_frac, w, 0.0)
        return w


@dataclasses.dataclass(frozen=True)
class ClientSchedule:
    """Turns a profile + straggler policy into per-round :class:`RoundPlan`s.

    ``deadline`` is a sim-time budget for the local phase: client i
    completes ``min(nominal, floor(deadline·speed_i/step_cost))`` steps.
    With ``drop_stragglers`` clients that complete zero steps are removed
    from the round (no uplink payload, no control-variate update, excluded
    from the server average); otherwise they report their (unchanged)
    broadcast iterate.  ``step_cost``/``bit_cost`` are the sim-time of one
    local step at speed 1 and of one uplink bit at bandwidth 1.

    ``availability`` attaches a :class:`ClientAvailability` process: the
    cohort sampler draws clients proportionally to the round's
    availability weights, and any sampled-but-offline client (only
    possible when fewer than ``s`` clients are online) rides the
    straggler-drop machinery — zero steps, no uplink, excluded from the
    aggregate, holding nothing open on the sim clock.

    ``sampler`` picks the weighted-draw implementation when an
    availability process is attached: ``"gumbel"`` (the in-graph O(n)
    Gumbel-top-k) or ``"tree"`` (the host-side O(s log n) segment-tree
    sampler of :mod:`repro.core.sampling`, crossing the jit boundary
    through one ordered ``io_callback`` — the population-scale choice,
    see DESIGN.md §12).  Both are exact weighted sampling without
    replacement over the same weights; they consume randomness
    differently, so their cohort *sequences* differ while their
    *distributions* agree.  Without an availability process the sampler
    choice is inert and the uniform ``jax.random.choice`` path runs
    unchanged (byte-identical trajectories).
    """

    profile: ClientProfile
    deadline: Optional[float] = None
    drop_stragglers: bool = False
    step_cost: float = 1.0
    bit_cost: float = 0.0
    availability: Optional[ClientAvailability] = None
    sampler: str = "gumbel"

    def __post_init__(self):
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError("deadline must be positive")
        if self.step_cost <= 0:
            raise ValueError("step_cost must be positive")
        if self.bit_cost < 0:
            raise ValueError("bit_cost must be non-negative")
        if self.drop_stragglers and self.deadline is None:
            raise ValueError("drop_stragglers requires a deadline")
        if self.sampler not in ("gumbel", "tree"):
            raise ValueError(
                f"unknown sampler {self.sampler!r}: expected 'gumbel' or "
                f"'tree'")
        if (self.availability is not None
                and self.availability.n_clients != self.profile.n_clients):
            raise ValueError(
                f"availability traces {self.availability.n_clients} clients "
                f"but the profile has {self.profile.n_clients}")

    @classmethod
    def homogeneous(cls, n_clients: int) -> "ClientSchedule":
        return cls(profile=ClientProfile.homogeneous(n_clients))

    @property
    def n_clients(self) -> int:
        return self.profile.n_clients

    @property
    def may_drop(self) -> bool:
        return self.drop_stragglers or self.availability is not None

    @property
    def heterogeneous_steps(self) -> bool:
        """True if per-client step counts can differ within a round
        (deadline truncation, or offline clients running zero steps) —
        round bodies must mask their local-step scans."""
        return self.deadline is not None or self.availability is not None

    @property
    def comp_override_names(self):
        return tuple(sorted(self.profile.comp_params))

    @property
    def uses_host_sampler(self) -> bool:
        """True when cohort draws run host-side (``sampler="tree"`` with
        an availability process) — such schedules need an io_callback per
        round and cannot run inside ``shard_map`` meshes."""
        return self.sampler == "tree" and self.availability is not None

    @property
    def tree_sampler(self):
        """The lazily-built per-schedule :class:`TreeSampler` (host
        state: segment tree + draw memo shared by the in-graph callback
        and the §12 prefetch planner)."""
        if not self.uses_host_sampler:
            raise ValueError("schedule does not use the tree sampler")
        inst = getattr(self, "_tree_sampler", None)
        if inst is None:
            from .sampling import TreeSampler
            inst = TreeSampler(self.availability)
            object.__setattr__(self, "_tree_sampler", inst)
        return inst

    # ------------------------------------------------------------------ #

    def plan_cohort_host(self, key, s: int, round_idx: int):
        """Host-side cohort draw for the §12 prefetch planner.

        Returns numpy ``(clients (s,) int32, online (s,) bool)`` — the
        exact arrays the in-graph ``sample_cohort`` callback will return
        for the same ``(key, round_idx)`` (one memoised draw feeds both).
        Only valid on ``uses_host_sampler`` schedules.
        """
        kd = (key if jnp.issubdtype(key.dtype, jnp.unsignedinteger)
              else jax.random.key_data(key))
        return self.tree_sampler.draw(np.asarray(kd), round_idx, s)

    def sample_cohort(self, key: jax.Array, s: int, round_idx=0):
        """Sample the round's cohort (s,) from the population (in-graph).

        Without an availability process this is exactly the uniform
        without-replacement draw every round has always used (same key
        consumption, bit-identical trajectories).  With one, clients are
        drawn by Gumbel-top-k — weighted sampling without replacement
        proportional to ``availability.weights(round_idx)`` — and the
        returned ``available`` mask flags offline picks (only non-empty
        when fewer than ``s`` clients are online that round).  With
        ``sampler="tree"`` the weighted draw runs host-side in
        O(s log n) (no O(n) ops or constants in the round graph) and
        enters the graph through one ordered ``io_callback``.

        Returns ``(clients, available)`` with ``available=None`` on the
        neutral path.
        """
        n = self.n_clients
        if self.availability is None:
            return jax.random.choice(key, n, (s,), replace=False), None
        if self.sampler == "tree":
            from jax.experimental import io_callback
            sampler = self.tree_sampler
            kd = (key if jnp.issubdtype(key.dtype, jnp.unsignedinteger)
                  else jax.random.key_data(key))

            def cb(kd_h, t_h):
                clients, online = sampler.draw(kd_h, int(t_h), s)
                return clients, online

            clients, online = io_callback(
                cb, (jax.ShapeDtypeStruct((s,), jnp.int32),
                     jax.ShapeDtypeStruct((s,), jnp.bool_)),
                kd, jnp.asarray(round_idx, jnp.int32), ordered=True)
            return clients, online
        w = self.availability.weights(round_idx)
        online = w > 0.0
        # Gumbel-top-k: iid Gumbel noise + log-weights, top s scores ==
        # weighted sampling without replacement.  Offline clients score
        # -inf and only surface when the online population is < s.
        g = jax.random.gumbel(key, (n,))
        scores = jnp.where(online, jnp.log(jnp.maximum(w, 1e-20)) + g,
                           -jnp.inf)
        _, clients = jax.lax.top_k(scores, s)
        return clients, online[clients]

    def plan(self, clients: jax.Array, nominal_steps,
             available: Optional[jax.Array] = None) -> RoundPlan:
        """Resolve the sampled ``clients`` (s,) for one round (in-graph)."""
        speed = self.profile.speed[clients]
        bandwidth = self.profile.bandwidth[clients]
        nominal = jnp.asarray(nominal_steps, jnp.int32)
        if self.deadline is None:
            steps = jnp.broadcast_to(nominal, clients.shape)
            participating = jnp.ones(clients.shape, bool)
        else:
            can_do = jnp.floor(
                self.deadline * speed / self.step_cost).astype(jnp.int32)
            steps = jnp.minimum(nominal, jnp.maximum(can_do, 0))
            participating = (steps > 0 if self.drop_stragglers
                             else jnp.ones(clients.shape, bool))
        if available is not None:
            # an offline client runs nothing and joins no aggregate
            steps = jnp.where(available, steps, 0)
            participating = participating & available
        overrides = {k: v[clients]
                     for k, v in self.profile.comp_params.items()}
        return RoundPlan(steps=steps, participating=participating,
                         speed=speed, bandwidth=bandwidth,
                         comp_overrides=overrides, available=available)

    def finish_times(self, plan: RoundPlan, client_uplink_bits) -> jax.Array:
        """Per-client finish times (s,) on the sim clock: local phase plus
        uplink.  This is the event clock the aggregation policies order
        arrivals by (DESIGN.md §7); its max is the synchronous round
        wall-clock."""
        compute = plan.steps.astype(jnp.float32) * self.step_cost / plan.speed
        comm = (jnp.asarray(client_uplink_bits, jnp.float32) * self.bit_cost
                / plan.bandwidth)
        # a non-participant transmits nothing — zero its uplink term here
        # instead of trusting callers to mask client_uplink_bits upstream
        comm = jnp.where(plan.participating, comm, 0.0)
        finish = compute + comm
        if self.deadline is not None and self.drop_stragglers:
            # a dropped straggler holds the round until the deadline
            finish = jnp.where(plan.participating, finish, self.deadline)
        if plan.available is not None:
            # an offline client never starts: it holds nothing open
            finish = jnp.where(plan.available, finish, 0.0)
        return finish

    def sim_time(self, plan: RoundPlan, client_uplink_bits) -> jax.Array:
        """Round wall-clock in the sim cost model: wait for the slowest."""
        return jnp.max(self.finish_times(plan, client_uplink_bits))


# --------------------------------------------------------------------------- #
# Shared helpers for schedule-aware round implementations
# --------------------------------------------------------------------------- #

def per_client(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Reshape a (s,) mask to broadcast over a (s, ...) stacked leaf."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1))


class ClientAxisCtx:
    """Single-device view of the sampled-client axis (DESIGN.md §6).

    Round implementations write every cross-client operation against this
    interface; the base class is the unsharded path and each method is
    *exactly* the op the pre-sharding code inlined, so the unsharded graph
    is unchanged.  :class:`repro.core.distributed.ShardCtx` overrides the
    methods with shard-local slicing + explicit collectives, turning the
    same round body into a ``shard_map`` program over a ``clients`` mesh
    axis.
    """

    #: number of devices the sampled-client axis is split across
    n_shards: int = 1

    def local_count(self, s: int) -> int:
        """Clients this shard owns out of ``s`` sampled per round."""
        return s

    def shard(self, arr: jax.Array) -> jax.Array:
        """Slice this shard's rows from a full (s, ...) array."""
        return arr

    def shard_tree(self, tree: PyTree) -> PyTree:
        """``shard`` over every (s, ...) leaf (e.g. a :class:`RoundPlan`)."""
        return tree

    def all_clients(self, vec: jax.Array) -> jax.Array:
        """Reassemble the full (s, ...) array from shard-local rows.

        Metric vectors go through this before any scalar reduction, so
        totals are computed from the *same* full vector on every shard —
        bit-identical to the unsharded path at any device count.
        """
        return vec

    def psum(self, x):
        """Sum a value (array or pytree) across shards."""
        return x

    def all_clients_tree(self, tree: PyTree) -> PyTree:
        """``all_clients`` over every (s, ...) leaf — the §8 wire
        collective: gathering a packed :class:`repro.compress.wire.Payload`
        moves the packed buffers across shards, not dense trees."""
        return tree

    def mean_clients(self, stacked: PyTree) -> PyTree:
        """Mean over the (local) client axis of a stacked tree."""
        return jax.tree_util.tree_map(lambda t: t.mean(axis=0), stacked)

    def sum_clients(self, stacked: PyTree) -> PyTree:
        """Sum over the (local) client axis of a stacked tree."""
        return jax.tree_util.tree_map(lambda t: t.sum(axis=0), stacked)

    def scatter_rows(self, full: PyTree, idx: jax.Array, upd: PyTree
                     ) -> PyTree:
        """Write shard-local per-client rows back into the full
        (n_clients, ...) store (rows not owned by any shard unchanged)."""
        return jax.tree_util.tree_map(
            lambda all_, up_: all_.at[idx].set(up_), full, upd)

    def encode_payload(self, comp, plan: RoundPlan, stacked: PyTree,
                       keys: Optional[jax.Array] = None):
        """Wire-encode the stacked uplink tree under this ctx's placement.

        The base ctx is plain :func:`vmap_encode`;
        :class:`repro.core.distributed.ModelShardCtx` overrides this with
        the shard-local encode (each model shard packs the slots of its
        slice against psum'd global thresholds/norms, DESIGN.md §9).
        Round bodies call this instead of ``vmap_encode`` directly so one
        implementation serves every mesh composition.
        """
        return vmap_encode(comp, plan, stacked, keys)

    def gather_decoded_payload(self, payload, partf_full: jax.Array):
        """Server-side uplink under this ctx's placement — the companion
        of :meth:`encode_payload` (base: :func:`gather_decoded`; model
        shards gather packed buffers over clients inside their own
        manual region and decode shard-local)."""
        return gather_decoded(payload, partf_full, self)

    def encode_broadcast(self, comp, tree: PyTree,
                         key: Optional[jax.Array] = None):
        """Server-side downlink encode (DESIGN.md §10): ONE payload for
        the whole cohort — no client axis.  Under the §6 client mesh the
        round body runs inside ``shard_map``, so this traces once per
        shard on the replicated broadcast tree (the payload is replicated,
        exactly like the server model it encodes);
        :class:`repro.core.distributed.ModelShardCtx` overrides it with
        the shard-local encode over the model axis (§9)."""
        from repro.compress import wire
        return wire.encode(comp, tree, key)

    def decode_broadcast(self, payload) -> PyTree:
        """Client-side downlink decode under this ctx's placement — the
        companion of :meth:`encode_broadcast`."""
        from repro.compress import wire
        return wire.decode(payload)


#: The default (unsharded) client-axis context.
NULL_CTX = ClientAxisCtx()


def keep_where(mask: jax.Array, new: PyTree, old: PyTree) -> PyTree:
    """Per-client select over stacked trees: take ``new`` where ``mask`` is
    set, keep ``old`` elsewhere (e.g. revert non-participants' updates)."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(per_client(mask, n), n, o), new, old)


def tree_where(cond: jax.Array, a: PyTree, b: PyTree) -> PyTree:
    """Scalar-condition select over whole trees (e.g. 'every sampled client
    dropped — keep the server model')."""
    return jax.tree_util.tree_map(lambda x, y: jnp.where(cond, x, y), a, b)


def mean_over_active(values: jax.Array, active: jax.Array,
                     ctx: ClientAxisCtx = NULL_CTX) -> jax.Array:
    """Mean of per-client scalars over the active subset; 0 if none are
    active.  With every client active this reduces to ``values.mean()``
    bit-exactly (same sum, same divisor).  Under a sharded ``ctx`` both the
    masked sum and the active count are psum'd across shards."""
    act = active.astype(values.dtype)
    return (ctx.psum((values * act).sum())
            / jnp.maximum(ctx.psum(act.sum()), 1.0))


def masked_mean(stacked: PyTree, weights: jax.Array,
                ctx: ClientAxisCtx = NULL_CTX,
                weight_sum: Optional[jax.Array] = None) -> PyTree:
    """Mean over the client axis weighted by ``weights`` (s,) (e.g. the
    participation mask); a zero-weight round returns zeros, never NaN.

    Under a sharded ``ctx``, ``stacked``/``weights`` are shard-local and the
    numerator is psum'd; pass ``weight_sum`` (the full-vector weight total,
    available replicated from the round plan) so the divisor stays
    bit-identical to the unsharded path."""
    wsum = jnp.maximum(weights.sum() if weight_sum is None else weight_sum,
                       1.0)
    return jax.tree_util.tree_map(
        lambda t: ctx.psum((t * per_client(weights, t)).sum(axis=0)) / wsum,
        stacked)


def vmap_compress(comp, plan: RoundPlan, stacked: PyTree, keys: jax.Array):
    """Compress a stacked-client tree, one client per vmap lane.

    Routes the plan's per-client compressor parameters (if any) into
    ``comp.compress(**overrides)`` as traced scalars; without overrides this
    is exactly ``jax.vmap(comp.compress)`` (the homogeneous fast path).
    Returns ``(compressed stacked tree, BitsReport)`` whose report leaves
    carry the client axis — ``report.total_bits`` is the (s,) per-client
    wire cost.
    """
    names = tuple(sorted(plan.comp_overrides))
    if not names:
        return jax.vmap(comp.compress)(stacked, keys)
    vals = [plan.comp_overrides[n] for n in names]
    fn = lambda t, k, *ov: comp.compress(t, k, **dict(zip(names, ov)))
    return jax.vmap(fn)(stacked, keys, *vals)


def vmap_encode(comp, plan: RoundPlan, stacked: PyTree,
                keys: Optional[jax.Array] = None):
    """Wire-encode a stacked-client uplink tree, one client per vmap lane
    (DESIGN.md §8): the packed-payload counterpart of :func:`vmap_compress`.

    Returns ``(Payload, BitsReport)`` with a leading client axis on every
    buffer/report leaf; the report is identical to the account-mode one, so
    finish clocks and bit metrics don't change between modes.  Per-client
    compressor overrides change payload *shapes*, which a static wire
    format cannot carry — engines reject packed mode with overrides
    (``engine.validate_wire``) and this guards the same invariant.
    """
    from repro.compress import wire
    if plan.comp_overrides:
        raise ValueError(
            "packed wire mode cannot carry per-client compressor overrides "
            "(static payload capacity); run them in account mode")
    if keys is None:
        return jax.vmap(lambda t: wire.encode(comp, t))(stacked)
    return jax.vmap(lambda t, k: wire.encode(comp, t, k))(stacked, keys)


def mask_payload(payload, partf: jax.Array):
    """Zero the packed buffers of non-participating clients.

    A deadline-dropped or policy-excluded straggler contributes a
    *fully-masked* payload — not a packed buffer of zeros counted as
    transmitted: its measured bytes are excluded by the same ``partf``
    mask, and a masked payload decodes to an all-zero tree (sparse slots
    at index 0 value 0, quantizer norms 0) that the aggregation masks are
    already discarding.
    """
    keep = partf > 0
    data = jax.tree_util.tree_map(
        lambda b: jnp.where(per_client(keep, b), b, jnp.zeros((), b.dtype)),
        payload.data)
    return type(payload)(data, payload.spec)


def payload_metrics(payload, partf_full: jax.Array) -> Dict[str, jax.Array]:
    """The §8 measured-bytes metric entries every packed round emits: the
    static per-client payload size masked by the final participation
    vector — a dropped/excluded client's measured bytes are zero, matching
    its zeroed accounted bits."""
    pb = jnp.asarray(payload.nbytes, jnp.float32) * partf_full
    return {"client_payload_bytes": pb, "uplink_payload_bytes": pb.sum()}


def apply_downlink(mode: str, comp, ctx: ClientAxisCtx, ref: PyTree,
                   x_new: PyTree, key: Optional[jax.Array], s: int):
    """The §10 downlink seam shared by every round implementation.

    The server delta-codes the new broadcast model against ``ref`` — the
    model the cohort last *received* — so the compression error is error-
    feedback bounded: whatever ``C`` drops this round rides into the next
    round's delta.  The delta is encoded **once** (one payload serves the
    whole cohort) and decoded under ``ctx``'s placement; every client
    adopts the same ``y_new = ref + decode(C(x_new - ref))``, so server
    and clients stay in lockstep on what the cohort holds.

    ``mode="account"`` moves the dense transform output and only the
    ledger claims compression; ``mode="packed"`` moves the real packed
    broadcast payload and additionally returns the measured
    ``downlink_payload_bytes`` metric that must reconcile with the
    accounted bits (``bytes*8 - bits == s * padding_bits``).  Both modes
    consume the same key chain (the wire encode replicates the
    transform's rng contract), so their trajectories are bit-identical on
    one device.

    Returns ``(y_new, downlink_bits, extra_metrics)`` with the bits
    counted once per receiving client (``s * report.total_bits``),
    mirroring the dense accounting's ``s * dense_bits``.
    """
    delta = jax.tree_util.tree_map(lambda a, b: a - b, x_new, ref)
    if mode == "packed":
        payload, rep = ctx.encode_broadcast(comp, delta, key)
        dec = ctx.decode_broadcast(payload)
        extras = {"downlink_payload_bytes":
                  jnp.asarray(float(s * payload.nbytes), jnp.float32)}
    else:
        dec, rep = comp.compress(delta, key)
        extras = {}
    y_new = jax.tree_util.tree_map(lambda y, d: y + d, ref, dec)
    return y_new, rep.total_bits * s, extras


def gather_decoded(payload, partf_full: jax.Array, ctx: ClientAxisCtx):
    """The §8 server-side uplink: mask non-participants, gather the packed
    buffers across shards (the only cross-shard traffic of a wire-mode
    aggregation — ~32/r× fewer bytes than dense trees), decode to the full
    (s, ...) stacked tree, replicated on every shard."""
    from repro.compress import wire
    masked = mask_payload(payload, ctx.shard(partf_full))
    full = ctx.all_clients_tree(masked)
    return jax.vmap(wire.decode)(full)


def validate_schedule(schedule: ClientSchedule, n_clients: int,
                      compressor=None) -> ClientSchedule:
    """Check a schedule against an algorithm's config + compressor."""
    if schedule.n_clients != n_clients:
        raise ValueError(
            f"schedule profiles {schedule.n_clients} clients but the config "
            f"has n_clients={n_clients}")
    if schedule.profile.comp_params:
        if compressor is None:
            # an algorithm that never compresses would silently drop them
            raise ValueError(
                f"profile comp_params {sorted(schedule.profile.comp_params)} "
                f"given, but this algorithm has no compressor to apply them")
        accepted = set(compressor.param_overrides())
        unknown = set(schedule.profile.comp_params) - accepted
        if unknown:
            raise ValueError(
                f"profile comp_params {sorted(unknown)} are not accepted by "
                f"{type(compressor).__name__} (accepts {sorted(accepted)})")
        for name, values in schedule.profile.comp_params.items():
            # traced overrides bypass the compressor's __post_init__ range
            # checks — validate the per-client values host-side, up front
            compressor.validate_override(name, values)
    return schedule
