"""FedComLoc (paper Algorithm 1) — Scaffnew + compression, three variants.

Faithful mapping of Algorithm 1:

* server pre-decides communication iterations via Bernoulli(p) coins; the
  run between two heads of the coin is one *local phase* whose length is
  Geometric(p) — we draw that length directly (``local_steps="geometric"``),
  or fix it to round(1/p) (``local_steps="fixed"``, the deterministic setting
  used for the headline experiments, matching the paper's "average of 10
  local iterations per round" with p = 0.1);
* line 7  (FedComLoc-Local):  g_i evaluated at C(x_i);
* line 8  (FedComLoc-Com):    uplink iterate compressed, x^_i <- C(x^_i);
* line 11 (FedComLoc-Global): averaged iterate compressed before broadcast;
* line 16: h_i <- h_i + (p/gamma)(x_{t+1} - x^_{i,t+1}) — only communication
  iterations change h_i (otherwise x_{t+1} = x^_{i,t+1});
* client sampling: S resampled at every communication round (the paper's
  experimental setting: 10 of 100 clients per global round).  Non-sampled
  clients keep their control variates; they re-enter from the current server
  model.  With full participation and C = Identity this is exactly Scaffnew.

Communication accounting is **in-graph** (repro.compress.BitsReport): every
round's metrics carry the exact uplink/downlink wire cost of the payloads
produced that round — per-client TopK nnz, per-tensor Q_r norms, and under
error feedback the bits of the *transmitted innovation*, not the dense
model.  Rounds run either one-jit-per-round (``round``) or fused R-per-jit
(``run_rounds``, inherited from :class:`repro.core.engine.RoundEngine`),
under any of the three aggregation policies (``sync`` / ``semi_sync(K)`` /
``async_buffered`` — repro.core.aggregation, DESIGN.md §7).

State layout: the server model ``x`` is stored once (all clients restart a
round from the broadcast model); control variates ``h`` are stacked with a
leading client axis.  All per-round compute is one jitted function.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.compress import Compressor, Identity, dense_bits
from repro.core import aggregation, comm
from repro.core.clients import (
    NULL_CTX, ClientAxisCtx, ClientSchedule, apply_downlink, keep_where,
    masked_mean, mean_over_active, payload_metrics, per_client, tree_where,
    validate_schedule, vmap_compress)
from repro.core.engine import RoundEngine
from repro.core.fed_data import FederatedData

PyTree = Any
LossFn = Callable[[PyTree, jax.Array, jax.Array], jax.Array]

VARIANTS = ("none", "com", "local", "global")


class FedComLocState(NamedTuple):
    x: PyTree          # server model (broadcast value)
    h: PyTree          # control variates, stacked (n_clients, ...)
    round: jax.Array   # communication rounds completed
    e: PyTree = ()     # per-client error-feedback memory (beyond-paper)
    mom: PyTree = ()   # server momentum buffer (beyond-paper)
    y: PyTree = ()     # clients' last-received model (downlink != "dense")


@dataclasses.dataclass(frozen=True)
class FedComLocConfig:
    gamma: float = 0.1                 # local stepsize
    p: float = 0.1                     # communication probability
    n_clients: int = 100
    clients_per_round: int = 10
    batch_size: int = 32
    variant: str = "com"               # none | com | local | global
    local_steps: str = "fixed"         # fixed | geometric
    max_local_steps: Optional[int] = None  # cap (geometric); default 4/p
    # ---- beyond-paper extensions (EXPERIMENTS.md §Beyond) ---------------- #
    error_feedback: bool = False       # leaky delta-EF on the Com uplink
    ef_decay: float = 0.7              # EF memory leak (1.0 diverges here)
    server_momentum: float = 0.0       # Polyak momentum on the server mean

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(f"variant must be one of {VARIANTS}")
        if not (0 < self.p <= 1):
            raise ValueError("p must be in (0, 1]")
        if self.n_clients <= 0:
            raise ValueError("n_clients must be positive")
        if not (0 < self.clients_per_round <= self.n_clients):
            # jax.random.choice(..., replace=False) fails opaquely (or
            # silently misbehaves) outside this range — reject up front.
            raise ValueError(
                f"clients_per_round must be in [1, n_clients]: got "
                f"{self.clients_per_round} with n_clients={self.n_clients}")
        if self.local_steps not in ("fixed", "geometric"):
            raise ValueError('local_steps must be "fixed" or "geometric"')
        if self.error_feedback and self.variant != "com":
            raise ValueError("error_feedback applies to the Com variant")
        if not (0.0 <= self.server_momentum < 1.0):
            raise ValueError("server_momentum must be in [0, 1)")

    @property
    def steps_cap(self) -> int:
        if self.max_local_steps is not None:
            return self.max_local_steps
        if self.local_steps == "fixed":
            return max(1, round(1.0 / self.p))
        return max(1, round(4.0 / self.p))


class FedComLoc(RoundEngine):
    """Algorithm 1.  ``variant="none"`` with Identity compression = Scaffnew."""

    def __init__(self, loss_fn: LossFn, data: FederatedData,
                 config: FedComLocConfig,
                 compressor: Compressor | None = None,
                 schedule: ClientSchedule | None = None,
                 policy: aggregation.AggregationPolicy | None = None,
                 wire: str = "account",
                 downlink: str = "dense",
                 downlink_compressor: Compressor | None = None,
                 store=None,
                 meter_mode: str = "host"):
        self.loss_fn = loss_fn
        self.data = data
        self.cfg = config
        self.policy = policy
        self.wire = wire
        self.downlink = downlink
        self.down_comp = downlink_compressor
        self.store = store
        self.comp = compressor if compressor is not None else Identity()
        if config.variant == "none" and not isinstance(self.comp, Identity):
            raise ValueError('variant="none" requires the Identity compressor')
        self.sched = validate_schedule(
            schedule if schedule is not None
            else ClientSchedule.homogeneous(config.n_clients),
            config.n_clients, self.comp)
        self.meter = comm.CommMeter(mode=meter_mode)
        self._setup_engine()

    # ------------------------------------------------------------------ #

    def _validate_downlink_combo(self) -> None:
        if self.downlink == "dense":
            return
        if self.cfg.variant == "global":
            raise ValueError(
                'variant="global" already compresses the broadcast its own '
                "way (line 11); combine the downlink seam with the other "
                "variants, or keep variant='global' with downlink='dense'")
        if self.cfg.server_momentum > 0:
            raise ValueError(
                "server_momentum extrapolates the broadcast point, which "
                "the delta-coded downlink reference cannot track stably; "
                "use downlink='dense' with momentum")

    def init(self, params0: PyTree) -> FedComLocState:
        # per-client state lives behind the §11 store contract: the
        # in-memory backend returns the familiar stacked arrays, the host
        # backend a version token (rows stay host-side)
        n = self.cfg.n_clients
        e = (self.store.init_slot("e", params0, n)
             if self.cfg.error_feedback else ())
        mom = (jax.tree_util.tree_map(jnp.zeros_like, params0)
               if self.cfg.server_momentum > 0 else ())
        y = params0 if self.downlink != "dense" else ()
        return FedComLocState(x=params0, h=self.store.init_slot(
                                  "h", params0, n),
                              round=jnp.zeros((), jnp.int32), e=e, mom=mom,
                              y=y)

    # ------------------------------------------------------------------ #

    def _num_local_steps(self, key: jax.Array) -> jax.Array:
        cap = self.cfg.steps_cap
        if self.cfg.local_steps == "fixed":
            return jnp.asarray(cap, jnp.int32)
        # Geometric(p) truncated at cap: #iterations until the coin lands 1.
        u = jax.random.uniform(key)
        g = jnp.floor(jnp.log1p(-u) / jnp.log1p(-self.cfg.p)).astype(jnp.int32) + 1
        return jnp.clip(g, 1, cap)

    @property
    def _round_key_fanout(self):
        # must mirror _round_impl's split below (§12 cohort planner)
        return 6 if self.downlink != "dense" else 5

    def _round_impl(self, state: FedComLocState, key: jax.Array,
                    ctx: ClientAxisCtx = NULL_CTX):
        cfg, sched = self.cfg, self.sched
        dl_on = self.downlink != "dense"
        if dl_on:
            # one extra key for the downlink codec; the dense-mode split
            # stays exactly 5-way so existing trajectories never move
            (k_sample, k_steps, k_local, k_up, k_down,
             k_dl) = jax.random.split(key, 6)
        else:
            k_sample, k_steps, k_local, k_up, k_down = jax.random.split(
                key, 5)
            k_dl = None
        s = cfg.clients_per_round
        s_loc = ctx.local_count(s)
        # §11: availability-aware cohort sampling (the neutral path is the
        # historical uniform choice, same key consumption)
        clients_full, avail_full = sched.sample_cohort(
            k_sample, s, state.round)
        num_steps = self._num_local_steps(k_steps)
        # Client-heterogeneity layer (DESIGN.md §5): per-client step counts
        # (straggler deadline), participation mask, compressor overrides.
        # The full (s,) plan is computed replicated (metrics use it); the
        # per-client compute below runs on this shard's slice (§6).
        plan = sched.plan(clients_full, num_steps, available=avail_full)
        plan_l = ctx.shard_tree(plan)
        clients = ctx.shard(clients_full)
        partf_plan_full = plan.participating.astype(jnp.float32)
        ov_names = sched.comp_override_names
        ov_vals = [plan_l.comp_overrides[n] for n in ov_names]

        h_s = self.store.gather("h", state.h, clients)
        # §10: with a delta-coded downlink the cohort restarts from the
        # model the clients actually HOLD (state.y — last-received), not
        # the server's exact iterate; every client-side anchor below
        # (local phase start, EF innovation, FedBuff delta) uses ref.
        ref = state.y if dl_on else state.x
        x0 = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p, (s_loc,) + p.shape), ref)

        def local_step(carry, inp):
            x_i, loss_acc = carry
            step_idx, k_step = inp
            active = step_idx < plan_l.steps        # (s_loc,) per-client mask

            def one_client(x_c, h_c, client, kc, *ov):
                kb, kcomp = jax.random.split(kc)
                xb, yb = self.data.sample_batch(kb, client, cfg.batch_size)
                x_eval = (self.comp.apply(x_c, kcomp,
                                          **dict(zip(ov_names, ov)))
                          if cfg.variant == "local" else x_c)
                loss, g = jax.value_and_grad(self.loss_fn)(x_eval, xb, yb)
                x_new = jax.tree_util.tree_map(
                    lambda xc, gc, hc: xc - cfg.gamma * (gc - hc),
                    x_c, g, h_c)
                return x_new, loss

            # split the full (s,) key chain, then slice: client i sees the
            # same key at every device count
            keys = ctx.shard(jax.random.split(k_step, s))
            x_new, losses = jax.vmap(one_client)(x_i, h_s, clients, keys,
                                                 *ov_vals)
            x_i = jax.tree_util.tree_map(
                lambda new, old: jnp.where(per_client(active, new), new, old),
                x_new, x_i)
            loss_acc = loss_acc + mean_over_active(losses, active, ctx)
            return (x_i, loss_acc), None

        cap = cfg.steps_cap
        step_keys = jax.random.split(k_local, cap)
        (x_hat, loss_sum), _ = jax.lax.scan(
            local_step, (x0, jnp.zeros(())),
            (jnp.arange(cap), step_keys))

        # --- communication (theta_t = 1) --------------------------------- #
        # Exact wire accounting: the dense payload is 32 bits/scalar; the
        # compressed payloads report their own cost in-graph (BitsReport),
        # per client — a dropped straggler transmits nothing.
        dense = dense_bits(state.x)
        client_up = jnp.full((s_loc,), dense, jnp.float32)
        up_bits = jnp.asarray(s * dense)
        down_bits = jnp.asarray(s * dense)
        e_new = state.e
        innov = sent = e_s = payload = None
        wire_on = self.wire == "packed"
        if cfg.variant == "com":
            up_keys = ctx.shard(jax.random.split(k_up, s))
            if cfg.error_feedback:
                # EF on the uplink *innovation*: transmit
                # C(x^_i - x_prev + e_i); the server reconstructs
                # x_prev + mean(sent).  Deltas after a local phase are small
                # in magnitude, so TopK keeps far more of their energy than
                # it keeps of the raw iterates; the residual stays in e_i.
                # The uplink bits are those of the transmitted innovation.
                e_s = self.store.gather("e", state.e, clients)
                innov = jax.tree_util.tree_map(
                    lambda xh, x0_, e: xh - x0_[None] + e,
                    x_hat, ref, e_s)
                if wire_on:
                    # decode happens once, server-side, after the gather —
                    # the client rows the h/e updates need are sliced back
                    # out of the full decoded stack below
                    payload, up_rep = ctx.encode_payload(
                        self.comp, plan_l, innov, up_keys)
                else:
                    sent, up_rep = vmap_compress(self.comp, plan_l, innov,
                                                 up_keys)
                    x_hat = jax.tree_util.tree_map(
                        lambda x0_, snt: x0_[None] + snt, ref, sent)
            elif wire_on:
                # §8 packed uplink: the client boundary emits the wire
                # payload; the round carries on with its (gathered) decode.
                payload, up_rep = ctx.encode_payload(
                    self.comp, plan_l, x_hat, up_keys)
            else:
                x_hat, up_rep = vmap_compress(self.comp, plan_l, x_hat,
                                              up_keys)
            client_up = up_rep.total_bits      # (s_loc,) — vmap axis on leaves
            up_bits = None                     # recomputed from client_up
        elif wire_on:
            # uncompressed-uplink variants still move a real (dense) buffer
            payload, _ = ctx.encode_payload(None, plan_l, x_hat)

        # --- aggregation policy (DESIGN.md §7) --------------------------- #
        # The full (s,) bits each plan-participant would transmit feed the
        # finish-time clock; the policy outcome (participation, staleness,
        # weights, sim_time) is computed replicated, so it is bit-identical
        # at every §6 device count.
        pol = aggregation.resolve_policy(
            self.policy, sched, plan,
            ctx.all_clients(client_up) * partf_plan_full, ctx)
        out, part, may_exclude = pol.out, pol.part, pol.may_exclude
        client_up = pol.client_up             # excluded clients send nothing
        if up_bits is None or may_exclude:
            up_bits = client_up.sum()
        if wire_on:
            # §8 packed uplink: the only cross-shard traffic is the masked
            # packed-payload gather; decode happens ONCE, server-side, on
            # the full (s,) stack — the client rows the h/e updates need
            # are sliced back out of it (an excluded client's masked zero
            # row never lands in state: the §5/§7 keep-old guards below
            # are gated on the same participation mask).
            dec_full = ctx.gather_decoded_payload(payload, out.partf)
            if cfg.variant == "com" and cfg.error_feedback:
                sent = ctx.shard_tree(dec_full)
                srv_hat = jax.tree_util.tree_map(
                    lambda x0_, sf: x0_[None] + sf, ref, dec_full)
                x_hat = ctx.shard_tree(srv_hat)
            else:
                # non-com variants ship the raw iterate: decode is the
                # identity and the local x_hat already equals its rows
                srv_hat = dec_full
                if cfg.variant == "com":
                    x_hat = ctx.shard_tree(srv_hat)
        if cfg.variant == "com" and cfg.error_feedback:
            # leaky memory: undecayed EF diverges inside Scaffnew (the
            # residual integrates against the control variates — see the
            # EXPERIMENTS.md §Beyond decay study); 0.7 is the sweet spot.
            e_s_new = jax.tree_util.tree_map(
                lambda c, snt: cfg.ef_decay * (c - snt), innov, sent)
            if may_exclude:    # an excluded client never transmitted
                e_s_new = keep_where(part, e_s_new, e_s)
            e_new = self.store.scatter("e", state.e, clients, e_s_new, ctx)
        delta_combine = aggregation.uses_delta_combine(self.policy)
        if wire_on:
            # server aggregation from the decoded full stack, with the
            # unsharded formula (bit-identical at any device count)
            if delta_combine:
                delta = jax.tree_util.tree_map(
                    lambda xh, x0_: xh - x0_[None], srv_hat, ref)
                x_bar = jax.tree_util.tree_map(
                    lambda x0_, u: x0_ + u, state.x,
                    aggregation.async_weighted_sum(out, delta, NULL_CTX))
            elif may_exclude:
                x_bar = tree_where(out.n_selected > 0,
                                   masked_mean(srv_hat, out.weight, NULL_CTX,
                                               weight_sum=out.n_selected),
                                   state.x)
            else:
                x_bar = jax.tree_util.tree_map(
                    lambda t: t.mean(axis=0), srv_hat)
        elif delta_combine:
            # FedBuff server application in delta form: each buffer flush
            # applies its staleness-discounted mean of anchor deltas
            delta = jax.tree_util.tree_map(
                lambda xh, x0_: xh - x0_[None], x_hat, ref)
            x_bar = jax.tree_util.tree_map(
                lambda x0_, u: x0_ + u, state.x,
                aggregation.async_weighted_sum(out, delta, ctx))
        elif may_exclude:
            # if every sampled client was excluded, the server keeps its
            # model
            x_bar = tree_where(out.n_selected > 0,
                               masked_mean(x_hat, pol.weight, ctx,
                                           weight_sum=out.n_selected),
                               state.x)
        else:
            x_bar = ctx.mean_clients(x_hat)
        if cfg.variant == "global":
            x_bar, down_rep = self.comp.compress(x_bar, k_down)
            down_bits = down_rep.total_bits * s

        # §10 downlink seam: delta-code the new broadcast against the
        # cohort's reference, once; clients decode under the mesh (this
        # body IS the shard_map/GSPMD region) and adopt y_new.
        y_new = state.y
        dl_extras = {}
        if dl_on:
            y_new, down_bits, dl_extras = apply_downlink(
                self.downlink, self.down_comp, ctx, state.y, x_bar, k_dl, s)
        bcast = y_new if dl_on else x_bar

        # line 16: h_i += (p/gamma) (x_{t+1} - x^_{i,t+1}) for i in S —
        # x_{t+1} is the value clients ADOPT (the decoded y under a
        # compressed downlink) and the pre-momentum mean otherwise: the
        # extrapolation below must not leak into the control variates (it
        # destabilises them; see tests).
        h_s_new = jax.tree_util.tree_map(
            lambda h, xh, xb_: h + (cfg.p / cfg.gamma) * (xb_[None] - xh),
            h_s, x_hat, bcast)
        if may_exclude:   # an excluded client keeps its control variate
            h_s_new = keep_where(part, h_s_new, h_s)
        h_new = self.store.scatter("h", state.h, clients, h_s_new, ctx)

        # beyond-paper: Polyak momentum on the broadcast point only
        mom_new = state.mom
        if cfg.server_momentum > 0:
            delta = jax.tree_util.tree_map(
                lambda xb_, x0_: xb_ - x0_, x_bar, state.x)
            mom_new = jax.tree_util.tree_map(
                lambda m, d_: cfg.server_momentum * m
                + (1 - cfg.server_momentum) * d_, state.mom, delta)
            x_bar = jax.tree_util.tree_map(
                lambda x0_, m: x0_ + m, state.x, mom_new)

        metrics = {
            "train_loss": loss_sum / jnp.maximum(plan.steps.max(), 1),
            "num_local_steps": num_steps,
            "uplink_bits": up_bits,
            "downlink_bits": down_bits,
            "client_steps": plan.steps,           # (s,) per-client schedule
            "client_uplink_bits": client_up,      # (s,) exact per-client wire
            "client_finish": out.finish,          # (s,) sim-clock arrivals
            "sim_time": out.sim_time,
            **aggregation.policy_metrics(out),
        }
        if wire_on:
            # measured packed bytes (§8): the static payload size, masked
            # in-graph by participation — a dropped client transmits a
            # zero-length payload, not a buffer of zeros counted as sent
            metrics.update(payload_metrics(payload, out.partf))
        metrics.update(dl_extras)
        return (FedComLocState(x=x_bar, h=h_new, round=state.round + 1,
                               e=e_new, mom=mom_new, y=y_new), metrics)
