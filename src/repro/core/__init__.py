# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

from repro.core.clients import ClientProfile, ClientSchedule, RoundPlan
from repro.core.locodl import LoCoDL, LoCoDLConfig, LoCoDLState

__all__ = ["ClientProfile", "ClientSchedule", "RoundPlan",
           "LoCoDL", "LoCoDLConfig", "LoCoDLState"]
