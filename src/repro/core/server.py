"""FL orchestration: runs an algorithm for R communication rounds with
periodic centralized evaluation, collecting the histories the paper plots
(loss / accuracy vs rounds and vs communicated bits)."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass
class History:
    rounds: list = dataclasses.field(default_factory=list)
    train_loss: list = dataclasses.field(default_factory=list)
    test_acc: list = dataclasses.field(default_factory=list)
    test_loss: list = dataclasses.field(default_factory=list)
    uplink_bits: list = dataclasses.field(default_factory=list)
    downlink_bits: list = dataclasses.field(default_factory=list)
    total_bits: list = dataclasses.field(default_factory=list)
    wall_s: list = dataclasses.field(default_factory=list)
    sim_time: list = dataclasses.field(default_factory=list)  # cumulative
    final_params: Optional[Any] = None  # set by run_federated on completion

    @property
    def best_acc(self) -> float:
        return max(self.test_acc) if self.test_acc else float("nan")

    def as_dict(self) -> dict:
        # json-friendly view: the metric curves, without the params pytree
        # (not asdict(), which would deep-copy the params just to drop them)
        return {f.name: list(getattr(self, f.name))
                for f in dataclasses.fields(self) if f.name != "final_params"}


def make_eval_fn(apply_fn: Callable, x_test: jax.Array, y_test: jax.Array,
                 batch: int = 512):
    """Centralized eval on the held-out set; returns (loss, accuracy)."""
    n = x_test.shape[0]

    @jax.jit
    def eval_params(params):
        def body(carry, idx):
            loss_sum, correct = carry
            xb = jax.lax.dynamic_index_in_dim(xbs, idx, keepdims=False)
            yb = jax.lax.dynamic_index_in_dim(ybs, idx, keepdims=False)
            logits = apply_fn(params, xb)
            logp = jax.nn.log_softmax(logits)
            loss = -jnp.take_along_axis(logp, yb[:, None], axis=1).squeeze(1)
            pred = jnp.argmax(logits, axis=-1)
            return (loss_sum + loss.sum(), correct + (pred == yb).sum()), None

        (loss_sum, correct), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros((), jnp.int32)),
            jnp.arange(num_b))
        return loss_sum / (num_b * batch), correct / (num_b * batch)

    num_b = max(1, n // batch)
    xbs = x_test[: num_b * batch].reshape((num_b, batch) + x_test.shape[1:])
    ybs = y_test[: num_b * batch].reshape((num_b, batch))
    return eval_params


def run_federated(
    algorithm,
    params0: PyTree,
    num_rounds: int,
    key: jax.Array,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 10,
    log_every: int = 0,
    log_prefix: str = "",
    fuse: bool = True,
    mesh: Optional[Any] = None,
    policy: Optional[Any] = None,
    wire: Optional[str] = None,
    downlink: Optional[str] = None,
    downlink_compressor: Optional[Any] = None,
) -> History:
    """Drive ``algorithm`` (anything with .init/.round/.meter) for R rounds.

    When the algorithm exposes the fused multi-round engine
    (``run_rounds``, see repro.core.engine) and ``fuse`` is on, every
    stretch of rounds between two evaluation points runs as ONE jit call
    instead of one per round — same trajectory (the fused engine replays
    the host loop's ``key, sub = jax.random.split(key)`` chain), one host
    round-trip per chunk instead of per round.

    ``mesh`` (a ``jax.sharding.Mesh`` with a ``clients`` axis — see
    ``repro.launch.mesh.make_client_mesh``) binds the algorithm's rounds to
    the client-sharded ``shard_map`` path (DESIGN.md §6) before driving.
    ``policy`` (a ``repro.core.aggregation.AggregationPolicy``) rebinds the
    aggregation policy (DESIGN.md §7) the same way; ``wire``
    (``"account"`` | ``"packed"``) rebinds the wire mode (DESIGN.md §8);
    ``downlink`` (``"dense"`` | ``"account"`` | ``"packed"``, with
    ``downlink_compressor``) rebinds the broadcast codec path (DESIGN.md
    §10) — necessarily before ``init``, since the downlink reference
    ``y`` lives in the algorithm state.
    """
    if mesh is not None:
        algorithm.use_mesh(mesh)
    if policy is not None:
        algorithm.set_policy(policy)
    if wire is not None:
        algorithm.set_wire(wire)
    if downlink is not None:
        algorithm.set_downlink(downlink, downlink_compressor)
    state = algorithm.init(params0)
    hist = History()
    t0 = time.time()
    sim_t = 0.0          # cumulative straggler-aware simulated time (§5)
    fused = fuse and hasattr(algorithm, "run_rounds")

    def is_eval_round(r: int) -> bool:  # r = 0-based index just completed
        return eval_fn is not None and (r % eval_every == 0
                                        or r == num_rounds - 1)

    r = 0
    while r < num_rounds:
        stop = r
        while stop < num_rounds - 1 and not is_eval_round(stop):
            stop += 1
        n = stop - r + 1
        if fused and n > 1:
            state, chunk = algorithm.run_rounds(state, key, n)
            for _ in range(n):          # stay on the host loop's key chain
                key, _ = jax.random.split(key)
            # last round's values; per-client vectors keep their axis
            metrics = {k: (v[-1] if v.ndim > 1 else float(v[-1]))
                       for k, v in chunk.items()}
            if "sim_time" in chunk:
                sim_t += float(np.sum(chunk["sim_time"]))
        else:
            for _ in range(n):
                key, sub = jax.random.split(key)
                state, metrics = algorithm.round(state, sub)
                sim_t += metrics.get("sim_time", 0.0)
        r = stop + 1
        if is_eval_round(stop):
            tl, ta = eval_fn(state.x)
            hist.rounds.append(stop + 1)
            hist.train_loss.append(metrics.get("train_loss", float("nan")))
            hist.test_loss.append(float(tl))
            hist.test_acc.append(float(ta))
            hist.uplink_bits.append(algorithm.meter.uplink_bits)
            hist.downlink_bits.append(algorithm.meter.downlink_bits)
            hist.total_bits.append(algorithm.meter.total_bits)
            hist.wall_s.append(time.time() - t0)
            hist.sim_time.append(sim_t)
            if log_every and (stop % log_every == 0 or stop == num_rounds - 1):
                print(f"{log_prefix}round {stop + 1:5d}  "
                      f"loss {metrics.get('train_loss', float('nan')):.4f}  "
                      f"acc {float(ta):.4f}  "
                      f"Mbits {algorithm.meter.total_bits / 1e6:.1f}")
    hist.final_params = state.x
    return hist
