"""Sub-linear cohort sampling for million-client populations (DESIGN.md §12).

The Gumbel-top-k sampler in :meth:`ClientSchedule.sample_cohort` is exact
but O(n) *per round*: it recomputes all n diurnal weights, draws n Gumbel
variates and runs a top-k over the population — at n = 10^6 that puts four
population-sized constants and several O(n) ops inside every round graph,
which is what dominates trace/compile (and a measurable slice of exec) in
the population-scale benchmark.  This module is the ``sampler="tree"``
replacement: a host-side **segment tree** over the churn gate with
rejection on the diurnal factor,

* O(s log n) per weighted without-replacement draw,
* O(churn · log n) incremental gate updates per round (only the clients
  whose churn gate *flips* touch the tree — found by an arc search over
  the once-sorted staggers, never a population scan),
* zero O(n) arrays in the round graph (the draw crosses the jit boundary
  through one ordered ``io_callback`` returning the (s,) cohort).

Distributional contract (tested in ``tests/test_tree_sampler.py``): the
availability weight factors as ``w_i(t) = gate_i(t) · diurnal_i(t)`` with
``gate ∈ {0, 1}`` and ``diurnal ∈ [1-amp, 1]``.  The tree stores the gate
as an *envelope*; a draw proposes a client uniformly among gated clients
(tree descent) and accepts with probability ``diurnal_i(t)``, which is a
draw proportional to ``w_i(t)`` among the remaining clients — repeated
without replacement (accepted leaves are zeroed, restored after the
cohort), exactly the sequential-sampling semantics of Gumbel-top-k.  When
fewer than ``s`` clients are online the cohort is padded with the
lowest-indexed offline clients, matching ``lax.top_k``'s tie-break on the
-inf scores of the Gumbel path; the returned ``online`` mask flags them.

Draws are deterministic functions of ``(key, round, s)`` (the RNG is
seeded from the raw key bits and the round index) and memoised, so the
engine's host-side cohort *planner* (which pre-computes next rounds'
cohorts for the §12 prefetching store) and the in-graph callback agree on
— and never recompute — the same cohort.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Dict, Tuple

import numpy as np

#: proposals per draw before falling back to the exact O(n) path — only
#: reachable when almost every gated client sits at a deep diurnal trough
_REJECTION_CAP_PER_PICK = 64
#: memoised (key, t, s) -> cohort entries kept for the planner/graph pair
_CACHE_SIZE = 4096


class TreeSampler:
    """Segment-tree weighted without-replacement cohort sampler.

    One instance per :class:`~repro.core.clients.ClientAvailability`; all
    state is host-side numpy.  ``draw`` is thread-safe (the §12 store
    worker and the io_callback thread may race on the memo cache).
    """

    def __init__(self, availability):
        self.avail = availability
        n = availability.n_clients
        self.n = n
        self.phase = np.asarray(availability.phase, np.float32)
        self.stagger = np.asarray(availability.stagger, np.float32)
        self.period = float(availability.period)
        self.amp = float(availability.amp)
        self.churn_rate = float(availability.churn_rate)
        self.online_frac = float(availability.online_frac)
        self.gated = (self.churn_rate > 0.0 and self.online_frac < 1.0)
        # implicit segment tree over the gate indicator: leaves are 0/1 so
        # every internal node is an exact integer-valued double (counts,
        # no float drift) and a descent never mis-routes
        self._m = 1 << max(1, (n - 1).bit_length())
        self._tree = np.zeros(2 * self._m, np.float64)
        self._gate = np.ones(n, bool)
        self._t: int | None = None
        # staggers sorted ONCE: the per-round incremental update finds the
        # flip candidates by binary search over these arcs
        self._sort_idx = np.argsort(self.stagger, kind="stable")
        self._sorted_stagger = self.stagger[self._sort_idx]
        self._cache: "OrderedDict[Tuple[bytes, int, int], Tuple[np.ndarray, np.ndarray]]" = OrderedDict()
        self._lock = threading.RLock()
        #: telemetry: wall seconds spent inside draw() (sample phase)
        self.sample_seconds = 0.0
        #: telemetry: incremental vs full gate updates
        self.incremental_updates = 0
        self.full_rebuilds = 0
        self.fallback_draws = 0

    # -- gate (churn) ----------------------------------------------------- #

    def _gate_exact(self, t: int, idx=None) -> np.ndarray:
        """The f32 churn gate at round ``t`` (matches ``weights()``'s
        formula op-for-op: f32 multiply-add, f32 mod, strict <)."""
        stg = self.stagger if idx is None else self.stagger[idx]
        if not self.gated:
            return np.ones(stg.shape, bool)
        u = np.mod(np.float32(t) * np.float32(self.churn_rate) + stg,
                   np.float32(1.0))
        return u < np.float32(self.online_frac)

    def _set_leaves(self, idx: np.ndarray, values: np.ndarray) -> None:
        """Write leaves and repair ancestor sums — O(k log n) for k leaves."""
        self._tree[self._m + idx] = values
        nodes = np.unique((self._m + idx) >> 1)
        while nodes.size and nodes[0] >= 1:
            self._tree[nodes] = (self._tree[2 * nodes]
                                 + self._tree[2 * nodes + 1])
            nodes = np.unique(nodes >> 1)
            if nodes[0] == 0:
                break

    def _rebuild(self, t: int) -> None:
        gate = self._gate_exact(t)
        self._tree[:] = 0.0
        self._tree[self._m:self._m + self.n] = gate
        for i in range(self._m - 1, 0, -1):
            self._tree[i] = self._tree[2 * i] + self._tree[2 * i + 1]
        self._gate = gate
        self._t = t
        self.full_rebuilds += 1

    def _arc_candidates(self, lo: float, width: float) -> np.ndarray:
        """Original indices of clients with stagger in [lo, lo+width) mod 1."""
        lo = lo % 1.0
        hi = lo + width
        ss = self._sorted_stagger
        if hi <= 1.0:
            a, b = np.searchsorted(ss, lo), np.searchsorted(ss, hi)
            return self._sort_idx[a:b]
        a = np.searchsorted(ss, lo)
        b = np.searchsorted(ss, hi - 1.0)
        return np.concatenate([self._sort_idx[a:], self._sort_idx[:b]])

    def _advance_one(self, t: int) -> None:
        """Incremental gate update t-1 -> t: only flip candidates — the
        clients whose stagger sits near the two moving gate boundaries —
        are re-evaluated with the exact f32 formula (the arc search is a
        float64 over-approximation widened by a safety margin)."""
        c, f = self.churn_rate, self.online_frac
        # gate on  <=>  stagger in [-t*c, -t*c + f) (mod 1);  both
        # boundaries move by c per round, so flips live in two arcs of
        # width c around the previous boundary positions
        eps = 4.0 * np.finfo(np.float32).eps * (abs(t * c) + 1.0) + 1e-7
        width = min(1.0, c + 2.0 * eps)
        cand = np.concatenate([
            self._arc_candidates(-t * c - eps, width),
            self._arc_candidates(-t * c + f - eps, width)])
        if cand.size:
            cand = np.unique(cand)
            new = self._gate_exact(t, cand)
            flip = new != self._gate[cand]
            if flip.any():
                ci = cand[flip]
                self._gate[ci] = new[flip]
                self._set_leaves(ci, new[flip].astype(np.float64))
        self._t = t
        self.incremental_updates += 1

    def _advance_to(self, t: int) -> None:
        if self._t == t:
            return
        if (self._t is None or t < self._t
                or (t - self._t) * max(self.churn_rate, 1e-9) > 0.5
                or not self.gated):
            self._rebuild(t)
            return
        for step in range(self._t + 1, t + 1):
            self._advance_one(step)

    # -- diurnal ---------------------------------------------------------- #

    def _diurnal(self, t: int, idx) -> np.ndarray:
        """f32 diurnal availability factor in [1-amp, 1] (clamped >= 0)."""
        ph = self.phase[idx]
        w = (np.float32(1.0) - np.float32(self.amp)
             * (np.float32(0.5) + np.float32(0.5) * np.sin(
                 np.float32(2.0 * np.pi)
                 * (np.float32(t) / np.float32(self.period) + ph))))
        return np.maximum(w, np.float32(0.0))

    # -- drawing ---------------------------------------------------------- #

    def _descend(self, u: float) -> int:
        i = 1
        while i < self._m:
            left = self._tree[2 * i]
            if u < left:
                i = 2 * i
            else:
                u -= left
                i = 2 * i + 1
        return i - self._m

    def _draw_impl(self, rng: np.random.Generator, t: int, s: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
        removed: Dict[int, float] = {}

        def remove(i: int) -> None:
            removed[i] = self._tree[self._m + i]
            self._set_leaves(np.asarray([i]), np.zeros(1))

        selected: list[int] = []
        budget = _REJECTION_CAP_PER_PICK * (s + 4)
        while len(selected) < s and self._tree[1] >= 0.5:
            if budget <= 0:
                # pathological trough: finish the cohort with an exact
                # O(remaining) Gumbel-top-k over the still-gated clients
                self.fallback_draws += 1
                rem = np.flatnonzero(self._tree[self._m:self._m + self.n]
                                     >= 0.5)
                w = self._diurnal(t, rem).astype(np.float64)
                live = w > 0.0
                rem, w = rem[live], w[live]
                if rem.size:
                    scores = np.log(w) + rng.gumbel(size=rem.size)
                    take = min(s - len(selected), rem.size)
                    picks = rem[np.argsort(-scores)[:take]]
                    for i in picks:
                        remove(int(i))
                        selected.append(int(i))
                break
            budget -= 1
            i = self._descend(rng.random() * self._tree[1])
            w = float(self._diurnal(t, i))
            if w <= 0.0:
                # gated on but diurnally offline (amp == 1 trough): not
                # drawable this round — drop it from the envelope
                remove(i)
                continue
            if rng.random() < w:
                remove(i)
                selected.append(i)

        online_count = len(selected)
        if online_count < s:
            # fewer than s clients online: pad with the lowest-indexed
            # not-selected clients — lax.top_k's tie-break on the Gumbel
            # path's -inf scores
            need = s - online_count
            taken = np.zeros(self.n, bool)
            taken[selected] = True
            pad = np.flatnonzero(~taken)[:need]
            selected.extend(int(i) for i in pad)
        # restore the envelope (the draw is without replacement *within*
        # the cohort only; the tree must reflect the gate for round t+1)
        if removed:
            idx = np.fromiter(removed.keys(), np.int64, len(removed))
            vals = np.fromiter(removed.values(), np.float64, len(removed))
            self._set_leaves(idx, vals)
        clients = np.asarray(selected, np.int32)
        online = np.zeros(s, bool)
        online[:online_count] = True
        return clients, online

    def draw(self, key_data, t, s: int) -> Tuple[np.ndarray, np.ndarray]:
        """The (s,) cohort and its online mask at round ``t``.

        ``key_data`` is the raw uint32 key bits (any shape); results are
        memoised on ``(key bits, t, s)`` so the engine's prefetch planner
        and the in-graph callback share one draw.
        """
        kd = np.ascontiguousarray(np.asarray(key_data, np.uint32))
        t = int(t)
        ck = (kd.tobytes(), t, int(s))
        with self._lock:
            hit = self._cache.get(ck)
            if hit is not None:
                self._cache.move_to_end(ck)
                return hit
            t0 = time.perf_counter()
            self._advance_to(t)
            seq = np.random.SeedSequence([int(x) for x in kd.ravel()]
                                         + [t & 0x7FFFFFFF])
            rng = np.random.Generator(np.random.Philox(seq))
            out = self._draw_impl(rng, t, int(s))
            self._cache[ck] = out
            while len(self._cache) > _CACHE_SIZE:
                self._cache.popitem(last=False)
            self.sample_seconds += time.perf_counter() - t0
            return out
