"""Compression operators (paper §3.1).

All compressors operate on pytrees of arrays. Semantics follow the paper:

* ``TopK`` (Definition 3.1) — keep the K largest-magnitude entries, zero the
  rest.  The paper parameterises by the *density ratio* (fraction of nonzeros
  kept), so we expose ``density`` in (0, 1].  Biased compressor.
* ``QuantQr`` (Definition 3.2) — QSGD-style binary quantization with ``r``
  bits: x -> ||x||_2 * sgn(x_i) * xi_i where xi_i stochastically rounds
  |x_i|/||x||_2 onto the uniform 2^r-level grid.  Unbiased.
* ``Compose`` (Appendix B.3) — TopK followed by quantization of the
  survivors ("double compression").
* ``Identity`` — no-op; FedComLoc with Identity is exactly Scaffnew.

Each compressor reports the number of bits needed to transmit its output
(``bits(tree)``), which drives the paper's communicated-bits x-axes.

Two granularities are supported:

* ``scope="tensor"`` (default) — TopK / norm computed per leaf tensor. This is
  what practical FL systems (and FedLab-style implementations) do.
* ``scope="global"`` — the pytree is flattened into one vector first, matching
  the mathematical Definition 3.1 over x in R^d exactly.

The hot inner ops are routed through :mod:`repro.kernels.ops` which dispatches
to Pallas TPU kernels on TPU and to the jnp reference elsewhere.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

PyTree = Any

FLOAT_BITS = 32  # uncompressed scalar payload, as accounted in the paper
INDEX_BITS = 32  # index payload for sparse (value, index) encoding


def _tree_size(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


class Compressor:
    """Base class. Subclasses implement ``compress`` and ``bits``."""

    #: True if E[C(x)] = x.
    unbiased: bool = False

    def compress(self, tree: PyTree, rng: Optional[jax.Array] = None) -> PyTree:
        raise NotImplementedError

    def bits(self, tree: PyTree) -> float:
        """Bits to transmit C(tree) (values + any indices / norms)."""
        raise NotImplementedError

    def __call__(self, tree: PyTree, rng: Optional[jax.Array] = None) -> PyTree:
        return self.compress(tree, rng)


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    unbiased = True

    def compress(self, tree: PyTree, rng=None) -> PyTree:
        return tree

    def bits(self, tree: PyTree) -> float:
        return float(_tree_size(tree)) * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Keep the ``density`` fraction of largest-|.| entries (Def. 3.1)."""

    density: float = 0.1
    scope: str = "tensor"  # "tensor" | "global"

    def __post_init__(self):
        if not (0.0 < self.density <= 1.0):
            raise ValueError(f"density must be in (0, 1], got {self.density}")
        if self.scope not in ("tensor", "global"):
            raise ValueError(f"unknown scope {self.scope!r}")

    def _k(self, size: int) -> int:
        return max(1, min(size, int(round(self.density * size))))

    def compress(self, tree: PyTree, rng=None) -> PyTree:
        if self.density >= 1.0:
            return tree
        if self.scope == "global":
            leaves, treedef = jax.tree_util.tree_flatten(tree)
            flat = jnp.concatenate([l.reshape(-1) for l in leaves])
            out = kops.topk_mask(flat, self._k(flat.size))
            parts, off = [], 0
            for l in leaves:
                parts.append(out[off:off + l.size].reshape(l.shape).astype(l.dtype))
                off += l.size
            return jax.tree_util.tree_unflatten(treedef, parts)
        return jax.tree_util.tree_map(
            lambda x: kops.topk_mask(x.reshape(-1), self._k(x.size))
            .reshape(x.shape).astype(x.dtype),
            tree,
        )

    def bits(self, tree: PyTree) -> float:
        # (value, index) pairs for the kept coordinates.
        if self.scope == "global":
            k = self._k(_tree_size(tree))
            return float(k) * (FLOAT_BITS + INDEX_BITS)
        total = 0.0
        for x in jax.tree_util.tree_leaves(tree):
            total += self._k(x.size) * (FLOAT_BITS + INDEX_BITS)
        return total


@dataclasses.dataclass(frozen=True)
class QuantQr(Compressor):
    """QSGD binary quantization with ``r`` bits (Def. 3.2). Unbiased."""

    r: int = 8
    scope: str = "tensor"

    unbiased = True

    def __post_init__(self):
        if self.r <= 0:
            raise ValueError("r must be positive")

    def compress(self, tree: PyTree, rng: Optional[jax.Array] = None) -> PyTree:
        if rng is None:
            raise ValueError("QuantQr requires an rng key (stochastic rounding)")
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(rng, len(leaves))
        if self.scope == "global":
            flat = jnp.concatenate([l.reshape(-1) for l in leaves])
            out = kops.quantize_qr(flat, self.r, keys[0])
            parts, off = [], 0
            for l in leaves:
                parts.append(out[off:off + l.size].reshape(l.shape).astype(l.dtype))
                off += l.size
            return jax.tree_util.tree_unflatten(treedef, parts)
        new = [
            kops.quantize_qr(l.reshape(-1), self.r, k).reshape(l.shape).astype(l.dtype)
            for l, k in zip(leaves, keys)
        ]
        return jax.tree_util.tree_unflatten(treedef, new)

    def bits(self, tree: PyTree) -> float:
        # sign + r-bit level per scalar, + one fp32 norm per tensor (or global).
        n_tensors = 1 if self.scope == "global" else len(jax.tree_util.tree_leaves(tree))
        return float(_tree_size(tree)) * (1 + self.r) + n_tensors * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class Compose(Compressor):
    """Apply ``first`` then ``second`` (paper Appendix B.3: TopK -> Q_r)."""

    first: Compressor = dataclasses.field(default_factory=lambda: TopK(0.25))
    second: Compressor = dataclasses.field(default_factory=lambda: QuantQr(4))

    def compress(self, tree: PyTree, rng: Optional[jax.Array] = None) -> PyTree:
        if rng is not None:
            k1, k2 = jax.random.split(rng)
        else:
            k1 = k2 = None
        return self.second.compress(self.first.compress(tree, k1), k2)

    def bits(self, tree: PyTree) -> float:
        # TopK -> Q_r: transmit k indices + k quantized values.
        if isinstance(self.first, TopK) and isinstance(self.second, QuantQr):
            if self.first.scope == "global":
                k = self.first._k(_tree_size(tree))
                return float(k) * (INDEX_BITS + 1 + self.second.r) + FLOAT_BITS
            total = 0.0
            for x in jax.tree_util.tree_leaves(tree):
                k = self.first._k(x.size)
                total += k * (INDEX_BITS + 1 + self.second.r) + FLOAT_BITS
            return total
        return min(self.first.bits(tree), self.second.bits(tree))


_REGISTRY: dict[str, Callable[..., Compressor]] = {
    "identity": Identity,
    "none": Identity,
    "topk": TopK,
    "quant": QuantQr,
    "qr": QuantQr,
    "topk+quant": Compose,
}


def make_compressor(name: str, **kwargs) -> Compressor:
    """Factory: ``make_compressor("topk", density=0.3)``."""
    try:
        ctor = _REGISTRY[name.lower()]
    except KeyError:
        raise ValueError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return ctor(**kwargs)
