"""LoCoDL (PAPERS.md, arXiv 2403.04348) — local training with bidirectional
compression, the fifth algorithm on the shared ``_round_impl`` contract.

LoCoDL keeps Scaffnew's local phases and per-client control variates but
compresses BOTH links: clients uplink ``u_i = C_up(x^_i - y^)`` (their local
result against the shared reference model) and the server downlinks
``m = C_dn(v)`` where ``v`` aggregates the cohort's messages — every
transmitted quantity is a *difference* the control-variate structure drives
to zero, which is what yields the doubly accelerated communication
complexity.  Round structure (communication probability ``p``, stepsize
``gamma``, communication stepsize ``lam``):

* local phase — Geometric(p) (or fixed round(1/p)) Scaffnew steps on each
  sampled client's OWN iterate: ``x_i <- x_i - gamma (grad f_i(x_i) - h_i)``;
* reference step — the server model carries no loss term (g = 0), so its
  phase collapses to ``y^ = y + gamma hy``;
* uplink — ``u_i = C_up(x^_i - y^)``, aggregated to ``v`` under the bound
  §7 policy (sync mean / semi-sync masked mean / async staleness-weighted);
* downlink — ``m`` from ``v`` through the §10 downlink seam: ``"dense"``
  broadcasts ``v`` raw, ``"account"``/``"packed"`` run the downlink
  compressor (packed moves the real broadcast payload and reconciles
  measured bytes against accounted bits in-graph);
* updates — ``x_i <- x^_i - lam (u_i - m)``, ``y <- y^ + lam m``,
  ``h_i += (p/gamma)(x_i' - x^_i)``, ``hy += (p/gamma) lam m``.

With ``C_up = C_dn = Identity`` and ``lam = 1`` under the sync policy the
update collapses to ``x_i = y = mean_i(x^_i)`` — exactly Scaffnew's
communication round — which is the consistency anchor the golden traces
pin.  Two cohort adaptations vs the full-participation paper setting
(DESIGN.md §10): sampled-only rounds (non-sampled clients keep ``x_i`` and
``h_i``, exactly like FedComLoc's control variates), and policy-excluded
stragglers revert to their pre-round iterate (they neither transmitted
``u_i`` nor received ``m``, so applying either side's update would desync
them from the reference).

State layout: per-client iterates ``xs`` and control variates ``h`` are
stacked over ``n_clients`` (gathered/scattered for the sampled cohort);
the shared reference ``y`` is the evaluable server model and lives in the
``x`` slot every driver/eval hook reads.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.compress import Compressor, Identity, dense_bits
from repro.core import aggregation, comm
from repro.core.clients import (
    NULL_CTX, ClientAxisCtx, ClientSchedule, apply_downlink, keep_where,
    masked_mean, mean_over_active, payload_metrics, per_client, tree_where,
    validate_schedule, vmap_compress)
from repro.core.engine import RoundEngine
from repro.core.fed_data import FederatedData

PyTree = Any
LossFn = Callable[[PyTree, jax.Array, jax.Array], jax.Array]


class LoCoDLState(NamedTuple):
    x: PyTree          # shared reference model y (the evaluable one)
    xs: PyTree         # per-client iterates, stacked (n_clients, ...)
    h: PyTree          # per-client control variates, stacked
    hy: PyTree         # reference-model control variate
    round: jax.Array   # communication rounds completed


@dataclasses.dataclass(frozen=True)
class LoCoDLConfig:
    gamma: float = 0.1                 # local stepsize
    p: float = 0.1                     # communication probability
    lam: float = 0.5                   # communication stepsize (lambda)
    n_clients: int = 100
    clients_per_round: int = 10
    batch_size: int = 32
    local_steps: str = "fixed"         # fixed | geometric
    max_local_steps: Optional[int] = None  # cap (geometric); default 4/p

    def __post_init__(self):
        if not (0 < self.p <= 1):
            raise ValueError("p must be in (0, 1]")
        if not (0 < self.lam <= 1):
            raise ValueError("lam must be in (0, 1]")
        if self.n_clients <= 0:
            raise ValueError("n_clients must be positive")
        if not (0 < self.clients_per_round <= self.n_clients):
            raise ValueError(
                f"clients_per_round must be in [1, n_clients]: got "
                f"{self.clients_per_round} with n_clients={self.n_clients}")
        if self.local_steps not in ("fixed", "geometric"):
            raise ValueError('local_steps must be "fixed" or "geometric"')

    @property
    def steps_cap(self) -> int:
        if self.max_local_steps is not None:
            return self.max_local_steps
        if self.local_steps == "fixed":
            return max(1, round(1.0 / self.p))
        return max(1, round(4.0 / self.p))


class LoCoDL(RoundEngine):
    """Bidirectionally compressed Scaffnew on the shared engine contract."""

    def __init__(self, loss_fn: LossFn, data: FederatedData,
                 config: LoCoDLConfig,
                 compressor: Compressor | None = None,
                 schedule: ClientSchedule | None = None,
                 policy: aggregation.AggregationPolicy | None = None,
                 wire: str = "account",
                 downlink: str = "dense",
                 downlink_compressor: Compressor | None = None,
                 store=None,
                 meter_mode: str = "host"):
        self.loss_fn = loss_fn
        self.data = data
        self.cfg = config
        self.policy = policy
        self.wire = wire
        self.downlink = downlink
        self.down_comp = downlink_compressor
        self.store = store
        self.comp = compressor if compressor is not None else Identity()
        self.sched = validate_schedule(
            schedule if schedule is not None
            else ClientSchedule.homogeneous(config.n_clients),
            config.n_clients, self.comp)
        self.meter = comm.CommMeter(mode=meter_mode)
        self._setup_engine()

    # ------------------------------------------------------------------ #

    def init(self, params0: PyTree) -> LoCoDLState:
        n = self.cfg.n_clients
        # §11 store slots: every client's iterate starts at the broadcast
        # model ("broadcast" init — the host backend serves it from ONE
        # fill row, never materialising n copies), variates at zero
        return LoCoDLState(
            x=params0,
            xs=self.store.init_slot("xs", params0, n, init="broadcast"),
            h=self.store.init_slot("h", params0, n),
            hy=jax.tree_util.tree_map(jnp.zeros_like, params0),
            round=jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------------ #

    def _num_local_steps(self, key: jax.Array) -> jax.Array:
        cap = self.cfg.steps_cap
        if self.cfg.local_steps == "fixed":
            return jnp.asarray(cap, jnp.int32)
        u = jax.random.uniform(key)
        g = jnp.floor(jnp.log1p(-u)
                      / jnp.log1p(-self.cfg.p)).astype(jnp.int32) + 1
        return jnp.clip(g, 1, cap)

    # one 5-way split for every mode — see _round_impl (§12 planner)
    _round_key_fanout = 5

    def _round_impl(self, state: LoCoDLState, key: jax.Array,
                    ctx: ClientAxisCtx = NULL_CTX):
        cfg, sched = self.cfg, self.sched
        # a single 5-way split for every mode: LoCoDL always carries a
        # downlink leg, so dense/account/packed share one key chain (the
        # dense mode simply never consumes k_dl)
        k_sample, k_steps, k_local, k_up, k_dl = jax.random.split(key, 5)
        s = cfg.clients_per_round
        s_loc = ctx.local_count(s)
        clients_full, avail_full = sched.sample_cohort(
            k_sample, s, state.round)
        num_steps = self._num_local_steps(k_steps)
        plan = sched.plan(clients_full, num_steps, available=avail_full)
        plan_l = ctx.shard_tree(plan)
        clients = ctx.shard(clients_full)
        partf_plan_full = plan.participating.astype(jnp.float32)

        h_s = self.store.gather("h", state.h, clients)
        # clients resume their OWN iterates — there is no model broadcast;
        # the only downlink traffic is the compressed difference m
        x0 = self.store.gather("xs", state.xs, clients)

        def local_step(carry, inp):
            x_i, loss_acc = carry
            step_idx, k_step = inp
            active = step_idx < plan_l.steps      # (s_loc,) per-client mask

            def one_client(x_c, h_c, client, kc):
                xb, yb = self.data.sample_batch(kc, client, cfg.batch_size)
                loss, g = jax.value_and_grad(self.loss_fn)(x_c, xb, yb)
                x_new = jax.tree_util.tree_map(
                    lambda xc, gc, hc: xc - cfg.gamma * (gc - hc),
                    x_c, g, h_c)
                return x_new, loss

            # full (s,) key chain then slice: device-count invariant
            keys = ctx.shard(jax.random.split(k_step, s))
            x_new, losses = jax.vmap(one_client)(x_i, h_s, clients, keys)
            x_i = jax.tree_util.tree_map(
                lambda new, old: jnp.where(per_client(active, new), new, old),
                x_new, x_i)
            loss_acc = loss_acc + mean_over_active(losses, active, ctx)
            return (x_i, loss_acc), None

        cap = cfg.steps_cap
        step_keys = jax.random.split(k_local, cap)
        (x_hat, loss_sum), _ = jax.lax.scan(
            local_step, (x0, jnp.zeros(())),
            (jnp.arange(cap), step_keys))

        # reference phase: the server objective is g = 0, so its local
        # phase is the closed-form drift along its control variate
        y_hat = jax.tree_util.tree_map(
            lambda y, hy: y + cfg.gamma * hy, state.x, state.hy)

        # --- uplink: u_i = C_up(x^_i - y^) ------------------------------- #
        diff = jax.tree_util.tree_map(
            lambda xh, yh: xh - yh[None], x_hat, y_hat)
        wire_on = self.wire == "packed"
        up_keys = ctx.shard(jax.random.split(k_up, s))
        payload = u = u_full = None
        if wire_on:
            payload, up_rep = ctx.encode_payload(
                self.comp, plan_l, diff, up_keys)
        else:
            u, up_rep = vmap_compress(self.comp, plan_l, diff, up_keys)

        pol = aggregation.resolve_policy(
            self.policy, sched, plan,
            ctx.all_clients(up_rep.total_bits) * partf_plan_full, ctx)
        out, part, may_exclude = pol.out, pol.part, pol.may_exclude
        client_up = pol.client_up             # excluded clients send nothing

        if wire_on:
            # §8: masked packed-payload gather, ONE server-side decode;
            # the client rows the x/h updates need are sliced back out
            u_full = ctx.gather_decoded_payload(payload, out.partf)
            u = ctx.shard_tree(u_full)

        # --- aggregate v under the §7 policy ----------------------------- #
        if aggregation.uses_delta_combine(self.policy):
            v = (aggregation.async_weighted_sum(out, u_full, NULL_CTX)
                 if wire_on
                 else aggregation.async_weighted_sum(out, u, ctx))
        elif may_exclude:
            # all-excluded rounds broadcast m from v = 0: y drifts only by
            # its control variate, exactly as if the coin never landed
            v = tree_where(
                out.n_selected > 0,
                (masked_mean(u_full, out.weight, NULL_CTX,
                             weight_sum=out.n_selected) if wire_on
                 else masked_mean(u, pol.weight, ctx,
                                  weight_sum=out.n_selected)),
                jax.tree_util.tree_map(jnp.zeros_like, y_hat))
        else:
            v = (jax.tree_util.tree_map(lambda t: t.mean(axis=0), u_full)
                 if wire_on else ctx.mean_clients(u))

        # --- downlink: m from v through the §10 seam --------------------- #
        # LoCoDL's broadcast quantity is ALREADY the difference v, so the
        # seam's delta-coding runs against a zero reference: m = dec(C(v)).
        dl_on = self.downlink != "dense"
        dl_extras = {}
        if dl_on:
            m, down_bits, dl_extras = apply_downlink(
                self.downlink, self.down_comp, ctx,
                jax.tree_util.tree_map(jnp.zeros_like, v), v, k_dl, s)
        else:
            m = v
            down_bits = jnp.asarray(s * dense_bits(state.x))

        # --- updates ------------------------------------------------------ #
        xs_rows = jax.tree_util.tree_map(
            lambda xh, ui, mm: xh - cfg.lam * (ui - mm[None]),
            x_hat, u, m)
        h_rows = jax.tree_util.tree_map(
            lambda h, xn, xh: h + (cfg.p / cfg.gamma) * (xn - xh),
            h_s, xs_rows, x_hat)
        if may_exclude:
            # an excluded straggler neither transmitted u_i nor received m:
            # revert to the pre-round iterate, keep the control variate
            xs_rows = keep_where(part, xs_rows, x0)
            h_rows = keep_where(part, h_rows, h_s)
        xs_new = self.store.scatter("xs", state.xs, clients, xs_rows, ctx)
        h_new = self.store.scatter("h", state.h, clients, h_rows, ctx)
        y_new = jax.tree_util.tree_map(
            lambda yh, mm: yh + cfg.lam * mm, y_hat, m)
        hy_new = jax.tree_util.tree_map(
            lambda hy, mm: hy + (cfg.p / cfg.gamma) * cfg.lam * mm,
            state.hy, m)

        metrics = {
            "train_loss": loss_sum / jnp.maximum(plan.steps.max(), 1),
            "num_local_steps": num_steps,
            "uplink_bits": client_up.sum(),
            "downlink_bits": down_bits,
            "client_steps": plan.steps,
            "client_uplink_bits": client_up,
            "client_finish": out.finish,
            "sim_time": out.sim_time,
            **aggregation.policy_metrics(out),
        }
        if wire_on:
            metrics.update(payload_metrics(payload, out.partf))
        metrics.update(dl_extras)
        return (LoCoDLState(x=y_new, xs=xs_new, h=h_new, hy=hy_new,
                            round=state.round + 1), metrics)
