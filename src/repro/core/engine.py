"""Round drivers shared by every FL algorithm (DESIGN.md §3.4).

Algorithms define one jit-able ``_round_impl(state, key) -> (state, metrics)``
where ``metrics`` is a flat dict of jnp values — scalars plus fixed-shape
per-client vectors (``client_steps`` / ``client_uplink_bits``, DESIGN.md
§5) — that **includes** ``uplink_bits`` / ``downlink_bits`` computed
in-graph from the payloads actually produced that round, and ``sim_time``
(the straggler-aware simulated round wall-clock).  :class:`RoundEngine`
then provides the two execution modes:

* ``round(state, key)`` — one jitted call per round, metrics pulled to host
  each round (interactive / debugging path);
* ``run_rounds(state, key, num_rounds)`` — the fused engine: ``lax.scan``
  over whole communication rounds inside ONE jit, in-graph bit/metric
  accumulation, a single host round-trip per chunk.  Bit-identical to
  calling ``round`` R times: the key chain inside the scan is exactly the
  host loop's ``key, sub = jax.random.split(key)``.

Both record into ``self.meter`` (a :class:`repro.core.comm.CommMeter`), so
histories and bits-axes are identical whichever driver ran.

``set_policy`` binds one of the three aggregation policies (DESIGN.md §7:
``sync`` / ``semi_sync(K)`` / ``async_buffered``); the round
implementations read ``self.policy`` at trace time, so both drivers — and
the ``shard_map`` mesh path — run the same policy-resolved graph.
``set_wire`` binds the §8 wire mode the same way: ``"account"`` moves
dense trees and only the ``BitsReport`` ledger claims compression;
``"packed"`` makes the uplink move real packed payloads
(``repro.compress.wire``) and adds measured ``uplink_payload_bytes`` /
``client_payload_bytes`` metrics that must reconcile with the accounted
bits in-graph.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

WIRE_MODES = ("account", "packed")

DOWNLINK_MODES = ("dense", "account", "packed")


def validate_downlink(downlink: Optional[str], compressor) -> str:
    """Resolve + check a downlink mode (DESIGN.md §10) at construction time.

    ``"dense"`` (default) keeps today's semantics: the broadcast is the
    raw fp32 model and ``downlink_bits`` accounts it at full width.
    ``"account"`` and ``"packed"`` both delta-code the broadcast against
    the clients' last-received reference through a *downlink* compressor:
    account mode applies the transform (dense buffers move, the
    ``BitsReport`` ledger claims the compression), packed mode moves the
    real packed broadcast payload (``repro.compress.wire``) and must
    reconcile measured bytes against accounted bits in-graph.  Packed
    needs a wire-codec-supported compressor; both need *a* compressor
    (pass ``Identity()`` for an explicit dense-codec downlink).
    """
    downlink = "dense" if downlink is None else downlink
    if downlink not in DOWNLINK_MODES:
        raise ValueError(
            f"downlink must be one of {DOWNLINK_MODES}, got {downlink!r}")
    if downlink != "dense":
        if compressor is None:
            raise ValueError(
                f'downlink="{downlink}" needs a downlink compressor '
                "(downlink_compressor=...; Identity() for the dense codec)")
        if downlink == "packed":
            from repro.compress import wire as wire_mod
            wire_mod.check_supported(compressor)
    return downlink


def validate_wire(wire: Optional[str], compressor, schedule) -> str:
    """Resolve + check a wire mode (DESIGN.md §8) at construction time.

    ``"account"`` (default) keeps today's semantics: dense trees move,
    only the ``BitsReport`` ledger claims compression.  ``"packed"``
    requires a compressor the wire layer can pack
    (``repro.compress.wire.check_supported``) and a schedule without
    per-client compressor overrides — overrides change payload *shapes*,
    which static packed buffers cannot carry.
    """
    wire = "account" if wire is None else wire
    if wire not in WIRE_MODES:
        raise ValueError(f"wire must be one of {WIRE_MODES}, got {wire!r}")
    if wire == "packed":
        from repro.compress import wire as wire_mod
        wire_mod.check_supported(compressor)
        if schedule is not None and schedule.profile.comp_params:
            raise ValueError(
                "packed wire mode cannot carry per-client compressor "
                f"overrides {sorted(schedule.profile.comp_params)} (static "
                "payload capacity); run per-client overrides in account "
                "mode")
    return wire


class RoundEngine:
    """Mixin: host-stepped ``round`` + fused ``run_rounds`` over _round_impl."""

    def _setup_engine(self) -> None:
        from repro.core import aggregation, client_store
        self.policy = aggregation.validate_policy(
            getattr(self, "policy", None), self.cfg.clients_per_round)
        self.store = client_store.resolve_store(getattr(self, "store", None))
        self.wire = validate_wire(getattr(self, "wire", None),
                                  getattr(self, "comp", None),
                                  getattr(self, "sched", None))
        self.down_comp = getattr(self, "down_comp", None)
        self.downlink = validate_downlink(getattr(self, "downlink", None),
                                          self.down_comp)
        self._validate_downlink_combo()
        self._mesh = None
        self._mesh_axis = "clients"
        self._fused_cache: Dict[int, Any] = {}
        self._rebind_impl()

    # ------------------------------------------------------------------ #

    def _rebind_impl(self) -> None:
        """(Re)derive ``self._impl`` and clear the jit caches.

        Always wraps the round in a *fresh* function object: pjit's trace
        cache keys on the wrapped callable, and the ``self._round_impl``
        bound method compares equal across accesses — re-jitting it
        directly after a ``set_policy``/``set_wire`` rebind can silently
        reuse a graph traced under the previous binding.
        """
        from repro.core import distributed
        if self._mesh is None:
            impl = lambda state, key: self._round_impl(state, key)
        else:
            impl = distributed.shard_round(
                self._round_impl, self._mesh, self.cfg.clients_per_round,
                self._mesh_axis)
        self._impl = impl
        self._round = jax.jit(impl)
        self._fused_cache = {}

    # ------------------------------------------------------------------ #

    def set_wire(self, wire: str) -> "RoundEngine":
        """Bind a wire mode (DESIGN.md §8) — ``"account"`` | ``"packed"``.

        ``_round_impl`` reads ``self.wire`` at trace time, so switching
        modes clears the jit caches (like ``set_policy``); rebinding the
        mode already bound is a no-op.  Returns ``self``.
        """
        wire = validate_wire(wire, getattr(self, "comp", None),
                             getattr(self, "sched", None))
        if wire == self.wire:
            return self
        self.wire = wire
        self._rebind_impl()
        return self

    # ------------------------------------------------------------------ #

    def _validate_downlink_combo(self) -> None:
        """Algorithm-specific downlink compatibility hook (no-op here);
        overridden where a mode combination is ill-defined (e.g. FedComLoc
        variant="global" already compresses the broadcast its own way)."""

    def set_downlink(self, downlink: str,
                     compressor=None) -> "RoundEngine":
        """Bind a downlink mode (DESIGN.md §10) —
        ``"dense"`` | ``"account"`` | ``"packed"``.

        ``compressor`` replaces the bound downlink compressor when given
        (required if none was bound at construction and the mode needs
        one).  The downlink reference state ``y`` lives in the algorithm
        state, so this must be called **before** ``init`` — states built
        under a different mode have a different structure.  Returns
        ``self``.
        """
        comp = compressor if compressor is not None else self.down_comp
        downlink = validate_downlink(downlink, comp)
        if downlink == self.downlink and comp is self.down_comp:
            return self
        self.downlink = downlink
        self.down_comp = comp
        self._validate_downlink_combo()
        self._rebind_impl()
        return self

    # ------------------------------------------------------------------ #

    def set_policy(self, policy) -> "RoundEngine":
        """Bind an aggregation policy (DESIGN.md §7) — ``None`` = sync.

        ``_round_impl`` reads ``self.policy`` at trace time, so rebinding
        to a *different* policy clears the jit caches (like ``use_mesh``);
        rebinding the policy already bound is a no-op.  Returns ``self``.
        """
        from repro.core import aggregation
        policy = aggregation.validate_policy(
            policy, self.cfg.clients_per_round)
        if policy == self.policy:
            return self
        self.policy = policy
        self._rebind_impl()
        return self

    # ------------------------------------------------------------------ #

    def use_mesh(self, mesh: Optional["jax.sharding.Mesh"],
                 axis: str = "clients"):
        """Bind (or, with ``None``, unbind) a client-axis mesh.

        With a mesh bound, both drivers run ``_round_impl`` under
        ``shard_map`` with the sampled-client axis split across the mesh's
        ``axis`` devices (DESIGN.md §6) — same trajectory contract as the
        fused engine: metric scalars bit-identical, params allclose.
        Rebinding to a *different* mesh clears the jit caches; rebinding
        the mesh already bound is a no-op (so drivers may pass ``mesh=``
        on every call without triggering recompiles).  Returns ``self``
        for chaining.
        """
        if (mesh is self._mesh
                or (mesh is not None and self._mesh is not None
                    and mesh == self._mesh)):
            return self
        if mesh is not None and self.store.host_side:
            # an ordered host callback cannot run inside the shard_map
            # round body — the §11 HostStore is a single-process backend
            raise ValueError(
                "host-side client stores (HostStore) cannot run under a "
                "client-axis mesh; use the in-memory store with meshes, or "
                "drop the mesh for out-of-core populations")
        if (mesh is not None and getattr(getattr(self, "sched", None),
                                         "uses_host_sampler", False)):
            # same restriction for the §12 host-side cohort sampler
            raise ValueError(
                "host-side cohort sampling (sampler='tree') cannot run "
                "under a client-axis mesh; use sampler='gumbel' with "
                "meshes")
        self._mesh = mesh
        self._mesh_axis = axis
        self._rebind_impl()
        return self

    # ------------------------------------------------------------------ #

    #: per-round key fanout: ``_round_impl`` draws its sampling key as
    #: ``jax.random.split(key, fanout)[0]``.  Algorithms override this
    #: (it depends on the bound downlink mode) so the §12 cohort planner
    #: can replay the key chain host-side; ``None`` disables planning.
    _round_key_fanout: Optional[int] = None

    def _plan_cohorts(self, state, key: jax.Array, num_rounds: int,
                      stepped: bool = False):
        """Replay the upcoming rounds' sampling-key chain host-side and
        hand the cohort schedule to a prefetching :class:`HostStore`.

        The fused scan derives round r's key as r applications of
        ``key, sub = jax.random.split(key)`` and its sampling key as
        ``split(sub, fanout)[0]`` — all deterministic before the scan
        launches.  Tree-sampler schedules draw each cohort in O(s log n)
        (memoised, so the in-graph callback reuses the exact arrays);
        neutral schedules replay the uniform ``jax.random.choice``
        eagerly.  Gumbel schedules are not replayed (that would be the
        O(n) work §12 removes) — the store then runs write-behind only.
        The plan is a performance hint: a misprediction costs a prefetch
        miss, never a wrong row (see ``client_store`` hazard rules).
        """
        store, sched = self.store, getattr(self, "sched", None)
        if (not getattr(store, "prefetch", False) or self._mesh is not None
                or sched is None or self._round_key_fanout is None):
            return
        if sched.availability is not None and not sched.uses_host_sampler:
            return
        s = self.cfg.clients_per_round
        t0 = int(state.round)
        cohorts = []
        for r in range(num_rounds):
            if stepped:
                sub = key           # round() receives the round key itself
            else:
                key, sub = jax.random.split(key)
            k_sample = jax.random.split(sub, self._round_key_fanout)[0]
            if sched.uses_host_sampler:
                clients, _ = sched.plan_cohort_host(k_sample, s, t0 + r)
            else:
                clients = np.asarray(jax.random.choice(
                    k_sample, sched.n_clients, (s,), replace=False))
            cohorts.append(clients)
        store.submit_cohort_plan(cohorts)

    # ------------------------------------------------------------------ #

    def round(self, state, key: jax.Array) -> Tuple[Any, Dict[str, Any]]:
        """Run one communication round; returns (state, metrics dict).

        Scalar metrics come back as python floats; per-client vector
        metrics (e.g. ``client_uplink_bits``, DESIGN.md §5) as numpy
        arrays.
        """
        self._plan_cohorts(state, key, 1, stepped=True)
        state, metrics = self._round(state, key)
        out = {k: (np.asarray(v) if getattr(v, "ndim", 0) else float(v))
               for k, v in metrics.items()}
        self.meter.record_round(
            uplink_bits=out.get("uplink_bits", 0.0),
            downlink_bits=out.get("downlink_bits", 0.0))
        return state, out

    # ------------------------------------------------------------------ #

    def _fused(self, num_rounds: int):
        fn = self._fused_cache.get(num_rounds)
        if fn is None:
            def run(state, key):
                def body(carry, _):
                    state, key = carry
                    key, sub = jax.random.split(key)
                    state, metrics = self._impl(state, sub)
                    return (state, key), metrics

                (state, _), metrics = jax.lax.scan(
                    body, (state, key), None, length=num_rounds)
                return state, metrics

            fn = jax.jit(run)
            self._fused_cache[num_rounds] = fn
        return fn

    def run_rounds(self, state, key: jax.Array, num_rounds: int
                   ) -> Tuple[Any, Dict[str, np.ndarray]]:
        """Run ``num_rounds`` communication rounds in ONE jit call.

        Returns ``(state, metrics)`` with each metric stacked over a leading
        ``(num_rounds,)`` axis (per-round values; ``uplink_bits`` /
        ``downlink_bits`` are the exact per-round wire costs, per-client
        vector metrics stack to ``(num_rounds, s)``).  The caller's
        key-advance convention is
        the host loop's: after this call, advance your key by
        ``num_rounds`` ``jax.random.split`` steps to stay on the same chain.
        """
        num_rounds = int(num_rounds)
        if num_rounds <= 0:
            raise ValueError("num_rounds must be positive")
        self._plan_cohorts(state, key, num_rounds)
        state, metrics = self._fused(num_rounds)(state, key)
        self.meter.record_rounds(
            uplink_bits=metrics.get("uplink_bits"),
            downlink_bits=metrics.get("downlink_bits"),
            num_rounds=num_rounds)
        return state, {k: np.asarray(v) for k, v in metrics.items()}
