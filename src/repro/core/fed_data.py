"""Federated dataset container used by all FL algorithms.

Holds the global arrays plus per-client index tables (ragged sizes padded to
the max; batch sampling draws uniformly in [0, size_i) so padding never
biases).  Produced by :mod:`repro.data.dirichlet`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FederatedData:
    x: jax.Array               # (N, ...) global inputs
    y: jax.Array               # (N,) global labels
    client_indices: jax.Array  # (n_clients, max_size) int32, padded
    client_sizes: jax.Array    # (n_clients,) int32

    @property
    def n_clients(self) -> int:
        return self.client_indices.shape[0]

    def sample_batch(self, key: jax.Array, client: jax.Array, batch: int):
        """Uniform-with-replacement minibatch from one client's shard."""
        size = self.client_sizes[client]
        pos = jax.random.randint(key, (batch,), 0, jnp.maximum(size, 1))
        idx = self.client_indices[client, pos]
        return self.x[idx], self.y[idx]


@dataclasses.dataclass(frozen=True)
class SyntheticFederatedData:
    """Procedural federated regression data — O(dim) memory for any n_clients.

    Million-client populations (DESIGN.md §11) cannot hold per-client index
    tables: this container stores only the ``(dim,)`` ground-truth weight
    vector and derives each client's local optimum on the fly from its id,
    so memory is independent of ``n_clients``.  Client ``c`` draws batches
    from ``y = x @ (w0 + hetero * n_c) + noise * eps`` with
    ``n_c ~ N(0, I)`` seeded by ``fold_in(root, c)`` — deterministic per
    client, heterogeneity dialled by ``hetero``.
    """

    w0: jax.Array              # (dim,) ground-truth global weights
    n_clients: int
    hetero: float = 0.1        # per-client optimum spread
    noise: float = 0.0         # observation noise stddev
    seed: int = 0              # root for the per-client heterogeneity draws

    @classmethod
    def create(cls, n_clients: int, dim: int, *, hetero: float = 0.1,
               noise: float = 0.0, seed: int = 0) -> "SyntheticFederatedData":
        w0 = jax.random.normal(jax.random.PRNGKey(seed), (dim,))
        return cls(w0=w0, n_clients=n_clients, hetero=hetero, noise=noise,
                   seed=seed)

    @property
    def dim(self) -> int:
        return self.w0.shape[0]

    def client_weights(self, client: jax.Array) -> jax.Array:
        kc = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), client)
        return self.w0 + self.hetero * jax.random.normal(kc, self.w0.shape)

    def sample_batch(self, key: jax.Array, client: jax.Array, batch: int):
        """Fresh linear-regression minibatch from client ``client``'s law."""
        kx, ke = jax.random.split(key)
        w_c = self.client_weights(client)
        x = jax.random.normal(kx, (batch, self.dim))
        y = x @ w_c
        if self.noise:
            y = y + self.noise * jax.random.normal(ke, (batch,))
        return x, y


def from_numpy_partition(x: np.ndarray, y: np.ndarray,
                         parts: list[np.ndarray]) -> FederatedData:
    """parts[i] = global indices owned by client i (ragged)."""
    n = len(parts)
    max_sz = max(max(len(p) for p in parts), 1)
    idx = np.zeros((n, max_sz), dtype=np.int32)
    sizes = np.zeros((n,), dtype=np.int32)
    for i, p in enumerate(parts):
        sizes[i] = len(p)
        if len(p):
            idx[i, :len(p)] = p
    return FederatedData(
        x=jnp.asarray(x), y=jnp.asarray(y),
        client_indices=jnp.asarray(idx), client_sizes=jnp.asarray(sizes))
