"""Federated dataset container used by all FL algorithms.

Holds the global arrays plus per-client index tables (ragged sizes padded to
the max; batch sampling draws uniformly in [0, size_i) so padding never
biases).  Produced by :mod:`repro.data.dirichlet`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FederatedData:
    x: jax.Array               # (N, ...) global inputs
    y: jax.Array               # (N,) global labels
    client_indices: jax.Array  # (n_clients, max_size) int32, padded
    client_sizes: jax.Array    # (n_clients,) int32

    @property
    def n_clients(self) -> int:
        return self.client_indices.shape[0]

    def sample_batch(self, key: jax.Array, client: jax.Array, batch: int):
        """Uniform-with-replacement minibatch from one client's shard."""
        size = self.client_sizes[client]
        pos = jax.random.randint(key, (batch,), 0, jnp.maximum(size, 1))
        idx = self.client_indices[client, pos]
        return self.x[idx], self.y[idx]


def from_numpy_partition(x: np.ndarray, y: np.ndarray,
                         parts: list[np.ndarray]) -> FederatedData:
    """parts[i] = global indices owned by client i (ragged)."""
    n = len(parts)
    max_sz = max(max(len(p) for p in parts), 1)
    idx = np.zeros((n, max_sz), dtype=np.int32)
    sizes = np.zeros((n,), dtype=np.int32)
    for i, p in enumerate(parts):
        sizes[i] = len(p)
        if len(p):
            idx[i, :len(p)] = p
    return FederatedData(
        x=jnp.asarray(x), y=jnp.asarray(y),
        client_indices=jnp.asarray(idx), client_sizes=jnp.asarray(sizes))
