"""Communication accounting (the paper's "communicated bits" x-axes).

The FL simulator does dense arithmetic (compression zeroes / quantizes
values in place); the *bits actually on the wire* are what the paper plots.
They are computed **in-graph from the actual payloads** by
:mod:`repro.compress` (``BitsReport``) and accumulated here:

* uncompressed tensor: 32 bits / scalar;
* TopK: (32 + 32) bits per coordinate of the actual support (nnz from the
  mask — not the nominal k);
* Q_r: (1 + r) bits per scalar (sign + level) + 32 bits per-tensor norm;
* TopK + Q_r: (32 + 1 + r) per kept coordinate + norm.

Uplink (client -> server) and downlink (server -> client) are tracked
separately — FedComLoc-Com compresses only uplink, FedComLoc-Global only
downlink, FedComLoc-Local neither.

Two accumulator modes:

* ``mode="host"`` (default) — every ``record_round`` coerces to python
  floats (forces a device sync; fine for the per-round driver which syncs
  for metrics anyway);
* ``mode="jnp"`` — sums stay jax scalars; adds are lazy device ops and
  nothing blocks until a property / ``snapshot()`` is read.  This is the
  mode for the fused ``run_rounds`` engine, where R rounds produce one
  ``(R,)`` bits array and the meter should not force a round-trip.
"""

from __future__ import annotations

from typing import Any, Union

Scalar = Union[float, Any]  # float or jax scalar in "jnp" mode


class CommMeter:
    def __init__(self, mode: str = "host"):
        if mode not in ("host", "jnp"):
            raise ValueError(f"unknown CommMeter mode {mode!r}")
        self.mode = mode
        self._uplink: Scalar = 0.0
        self._downlink: Scalar = 0.0
        self.rounds: int = 0

    # -- recording ------------------------------------------------------- #

    def record_round(self, *, uplink_bits: Scalar,
                     downlink_bits: Scalar) -> None:
        if self.mode == "host":
            uplink_bits = float(uplink_bits)
            downlink_bits = float(downlink_bits)
        self._uplink = self._uplink + uplink_bits
        self._downlink = self._downlink + downlink_bits
        self.rounds += 1

    def record_rounds(self, *, uplink_bits, downlink_bits,
                      num_rounds: int) -> None:
        """Batched recording from the fused engine.

        ``uplink_bits`` / ``downlink_bits`` are per-round arrays (summed
        here), scalars (taken as chunk totals), or None (nothing tracked).
        """
        def total(v):
            if v is None:
                return 0.0
            v = v.sum() if hasattr(v, "sum") else v
            return float(v) if self.mode == "host" else v

        self._uplink = self._uplink + total(uplink_bits)
        self._downlink = self._downlink + total(downlink_bits)
        self.rounds += int(num_rounds)

    # -- reading (host-side; forces sync in "jnp" mode) ------------------ #

    @property
    def uplink_bits(self) -> float:
        return float(self._uplink)

    @property
    def downlink_bits(self) -> float:
        return float(self._downlink)

    @property
    def total_bits(self) -> float:
        return self.uplink_bits + self.downlink_bits

    def snapshot(self) -> dict:
        return {
            "rounds": self.rounds,
            "uplink_bits": self.uplink_bits,
            "downlink_bits": self.downlink_bits,
            "total_bits": self.total_bits,
        }
