"""Communication accounting (the paper's "communicated bits" x-axes).

The FL simulator does dense arithmetic (compression zeroes / quantizes
values in place); the *bits actually on the wire* are what the paper plots,
so we account them exactly:

* uncompressed tensor: 32 bits / scalar;
* TopK: (32 + 32) bits per kept coordinate (value + index);
* Q_r: (1 + r) bits per scalar (sign + level) + 32 bits per-tensor norm;
* TopK + Q_r: (32 + 1 + r) per kept coordinate + norm.

Uplink (client -> server) and downlink (server -> client) are tracked
separately — FedComLoc-Com compresses only uplink, FedComLoc-Global only
downlink, FedComLoc-Local neither.
"""

from __future__ import annotations

import dataclasses
from typing import Any

PyTree = Any


@dataclasses.dataclass
class CommMeter:
    uplink_bits: float = 0.0
    downlink_bits: float = 0.0
    rounds: int = 0

    @property
    def total_bits(self) -> float:
        return self.uplink_bits + self.downlink_bits

    def record_round(self, *, uplink_bits: float, downlink_bits: float) -> None:
        self.uplink_bits += uplink_bits
        self.downlink_bits += downlink_bits
        self.rounds += 1

    def snapshot(self) -> dict:
        return {
            "rounds": self.rounds,
            "uplink_bits": self.uplink_bits,
            "downlink_bits": self.downlink_bits,
            "total_bits": self.total_bits,
        }
