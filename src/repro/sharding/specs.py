"""Parameter/activation sharding rules for the production meshes.

Logical scheme (GSPMD / pjit):

* ``model``  — tensor parallelism: attention heads, d_ff, vocab, experts;
* ``data``   — batch parallelism AND FSDP-style weight sharding (weights'
  d_model-sized dims shard over ``data``; XLA inserts per-layer
  all-gathers);
* ``pod``    — multi-pod axis.  For plain training it joins ``data`` for
  batch/FSDP; for the federated runtime it is the *client* axis
  (launch/fed_train.py) and carries only the per-round compressed sync.

Rules match on the parameter path (joined dict keys).  MoE expert tensors
shard experts over ``model`` when divisible, else fall back to d_ff over
``model``.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def _fsdp_axis(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _fsdp_size(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def batch_axis(mesh: Mesh, dim_size: int):
    """fsdp axis for a batch dim, or None when it doesn't divide (e.g. the
    batch-1 long-context decode)."""
    return _fsdp_axis(mesh) if dim_size % _fsdp_size(mesh) == 0 else None


def _path_str(path) -> str:
    def one(p):
        if hasattr(p, "key"):          # DictKey
            return str(p.key)
        if hasattr(p, "name"):         # GetAttrKey (NamedTuple fields)
            return str(p.name)
        return str(p).strip(".[]'\"")
    return "/".join(one(p) for p in path)


def param_spec(path: str, shape: tuple, mesh: Mesh,
               expert_over_model: bool) -> P:
    """PartitionSpec for one parameter, by path pattern + rank."""
    fsdp = _fsdp_axis(mesh)
    ndim = len(shape)

    # ---- MoE expert tensors (E, D, F) / (E, F, D) ----------------------- #
    if re.search(r"moe/(wi|wg)/kernel$", path):
        return P("model", fsdp, None) if expert_over_model \
            else P(None, fsdp, "model")
    if re.search(r"moe/wo/kernel$", path):
        return P("model", None, fsdp) if expert_over_model \
            else P(None, "model", fsdp)
    if re.search(r"moe/router/kernel$", path):
        return P(fsdp, None)

    # ---- embeddings ------------------------------------------------------ #
    if path.endswith("embed/embedding"):
        return P("model", fsdp)
    if re.search(r"unembed/kernel$", path):
        return P(fsdp, "model")

    # ---- attention ------------------------------------------------------- #
    if re.search(r"(^|/)(q|k|v|self_attn/q|self_attn/k|self_attn/v"
                 r"|cross_attn/q|cross_attn/k|cross_attn/v)/kernel$", path):
        return P(fsdp, "model")
    if re.search(r"(^|/)(o|self_attn/o|cross_attn/o)/kernel$", path):
        return P("model", fsdp)
    if re.search(r"(^|/)(q|k|v)/bias$", path):
        return P("model")

    # ---- dense / shared MLP ---------------------------------------------- #
    if re.search(r"(mlp|shared_mlp)/(wi|wg)/kernel$", path):
        return P(fsdp, "model")
    if re.search(r"(mlp|shared_mlp)/wo/kernel$", path):
        return P("model", fsdp)

    # ---- RG-LRU ------------------------------------------------------------ #
    if re.search(r"rglru/(wx|wy)/kernel$", path):
        return P(fsdp, "model")
    if re.search(r"rglru/wo/kernel$", path):
        return P("model", fsdp)
    if re.search(r"rglru/(gate_a|gate_x)/kernel$", path):
        return P(fsdp, "model")
    if re.search(r"rglru/(gate_a|gate_x)/bias$", path) or \
            path.endswith("rglru/lam"):
        return P("model")
    if re.search(r"rglru/conv/kernel$", path):
        return P(None, "model")

    # ---- RWKV6 -------------------------------------------------------------- #
    if re.search(r"rwkv/(wr|wk|wv|wg|cm_r|cm_k)/kernel$", path):
        return P(fsdp, "model")
    if re.search(r"rwkv/(wo|cm_v)/kernel$", path):
        return P("model", fsdp)
    if re.search(r"rwkv/wa/kernel$", path):
        return P(fsdp, None)
    if re.search(r"rwkv/wb/kernel$", path):
        return P(None, "model")
    if path.endswith("rwkv/w0"):
        return P("model")
    if path.endswith("rwkv/mu") or path.endswith("rwkv/cm_mu"):
        return P(None, "model")

    # ---- everything else (norms, scalars, small) -> replicated ----------- #
    return P(*([None] * ndim))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _sanitize(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop axis assignments whose dim doesn't divide the axis size (pjit
    rejects uneven explicit shardings, e.g. seamless' 256206 vocab / 16)."""
    out = []
    for dim, entry in enumerate(spec):
        if entry is not None and shape[dim] % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out)


def model_dim_index(path, shape: tuple, model_shards: int, *,
                    expert_over_model: bool = False) -> Optional[int]:
    """Index of the dimension :func:`param_spec` puts on ``model``, or None.

    The federated sharded wire path (compress/wire.py §9) needs, per leaf,
    *which* dimension is model-sharded — independent of any concrete mesh.
    ``path`` is a ``tree_map_with_path`` key path or an already-joined
    string.  Returns None for replicated leaves AND for leaves whose model
    dim does not divide ``model_shards`` (the same condition under which
    ``_sanitize`` strips the axis from the real sharding, so wire layout
    and placement agree).
    """
    p = path if isinstance(path, str) else _path_str(path)
    spec = param_spec(p, shape, _RULE_MESH, expert_over_model)
    for dim, entry in enumerate(spec):
        if entry == "model":
            return dim if shape[dim] % int(model_shards) == 0 else None
    return None


class _RuleMesh:
    """Mesh stand-in for :func:`param_spec`, which only reads
    ``mesh.axis_names`` (for the pod check) — lets path->spec rules run
    without a device mesh in scope."""

    axis_names = ("data", "model")


_RULE_MESH = _RuleMesh()


def param_shardings(params_shape: PyTree, mesh: Mesh, *,
                    n_experts: Optional[int] = None,
                    seq_parallel: bool = False) -> PyTree:
    """Tree of NamedShardings matching ``params_shape`` (shapes or arrays).

    ``seq_parallel``: drop the ``model`` axis from attention/MLP weights
    (keeping FSDP + embeddings + MoE experts) — the prefill scheme where
    model parallelism comes from the T-sharded activations instead.
    Per-device FLOPs are identical (T/16 x full-F vs full-T x F/16), but the
    activation all-reduces that GSPMD inserts to reconcile T-sharded inputs
    with F-sharded weights disappear (§Perf H2: 316 GB/step of f32 MLP
    all-reduces on gemma2-9b prefill_32k).
    """
    model_size = mesh.shape["model"]
    expert_over_model = bool(n_experts) and n_experts % model_size == 0

    def one(path, leaf):
        p = _path_str(path)
        spec = param_spec(p, leaf.shape, mesh, expert_over_model)
        if seq_parallel and "moe/" not in p and "embed" not in p:
            # 2-D kernels: shard the contracting (input) dim over model and
            # nothing over data — the activations carry (B->data, T->model),
            # so any weight dim on `data` makes GSPMD all-reduce the full
            # activation instead of gathering the (tiny) weight.
            if len(leaf.shape) == 2:
                spec = P("model", None)
            else:
                spec = P(*[None if e == "model" else e for e in spec])
        return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_spec(mesh: Mesh) -> P:
    """Tokens / labels: batch over (pod, data)."""
    return P(_fsdp_axis(mesh))


def cache_spec(mesh: Mesh, kv_heads: int, cache_len: int) -> P:
    """KV caches: batch over (pod, data), sequence over model.

    Sharding the cache length over ``model`` is what keeps 32k x 128-batch
    caches on-chip; XLA inserts the softmax all-reduces.
    """
    return P(_fsdp_axis(mesh), None, "model", None)


def state_sharding(state_shape: PyTree, mesh: Mesh) -> PyTree:
    """Decode-state tree: KV caches + recurrent states."""

    def one(path, leaf):
        p = _path_str(path)
        nd = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
        if p.endswith("length") or nd == 0:
            return NamedSharding(mesh, P())
        b = batch_axis(mesh, leaf.shape[0])
        if nd == 4 and (p.endswith("/k") or p.endswith("/v")):
            # KV cache (B, H, S, Dh): shard S over model when long
            if (leaf.shape[2] >= 4 * mesh.shape["model"]
                    and leaf.shape[2] % mesh.shape["model"] == 0):
                return NamedSharding(mesh, P(b, None, "model", None))
            return NamedSharding(mesh, P(b, None, None, None))
        if nd == 4 and p.endswith("/s"):   # rwkv state (B,H,K,V)
            return NamedSharding(mesh, P(b, None, None, None))
        # recurrent / shift states: batch-shard the leading dim
        return NamedSharding(mesh, P(*([b] + [None] * (nd - 1))))

    return jax.tree_util.tree_map_with_path(one, state_shape)
