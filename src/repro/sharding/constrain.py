"""Activation sharding constraints (mesh-context aware, no-op without one).

GSPMD propagates parameter shardings to most activations, but two places
need explicit pins at framework level:

* the flash-attention scan bodies (per-chunk f32 logits) — batch over
  (pod, data), query-time over ``model``;
* the residual stream at layer boundaries — keeps propagation conflicts
  (attention wants T/model, matmuls want F/model) from dropping the batch
  sharding, which replicates every MLP activation across ``data``
  (measured: ~4 GiB/device/layer at train_4k).

All helpers silently no-op when there is no mesh context, when axis names
don't exist, or when dims don't divide — so the same model code runs in
plain CPU tests, the FL simulator, and the production meshes.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _current_mesh():
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return am
    except Exception:
        pass
    try:  # legacy `with mesh:` context
        env = jax._src.mesh.thread_resources.env  # noqa: SLF001
        if env.physical_mesh.axis_names:
            return env.physical_mesh
    except Exception:
        pass
    return None


def constrain(x: jax.Array, axes: dict[int, str]) -> jax.Array:
    """Pin dims of ``x``: {dim: "batch"|"seq"|"model"}; fail-soft."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    if "model" not in names:
        return x
    db = [a for a in ("pod", "data") if a in names]
    spec = [None] * x.ndim
    try:
        for dim, role in axes.items():
            if role == "batch" and db:
                dsz = 1
                for a in db:
                    dsz *= mesh.shape[a]
                if x.shape[dim] % dsz == 0 and x.shape[dim] > 0:
                    spec[dim] = tuple(db) if len(db) > 1 else db[0]
            elif role in ("seq", "model"):
                if x.shape[dim] % mesh.shape["model"] == 0:
                    spec[dim] = "model"
        if not any(s is not None for s in spec):
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def constrain_btd(x: jax.Array) -> jax.Array:
    """Residual stream (B, T, D): batch over (pod, data), T over model."""
    if x.ndim != 3:
        return x
    return constrain(x, {0: "batch", 1: "seq"})


def constrain_moe(x: jax.Array, batch_dim: int, expert_dim: int,
                  inner_dim: int | None = None) -> jax.Array:
    """MoE activations: tokens/groups over (pod, data); experts over
    ``model`` when the expert count divides, else the inner (d_ff) dim."""
    mesh = _current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    axes = {batch_dim: "batch"}
    if x.shape[expert_dim] % mesh.shape["model"] == 0:
        axes[expert_dim] = "model"
    elif inner_dim is not None:
        axes[inner_dim] = "model"
    return constrain(x, axes)
