"""Shared building blocks for the architecture zoo.

Functional style throughout: ``init_*(key, ...) -> params`` (nested dicts of
arrays) and pure apply functions.  Parameter *names* are load-bearing: the
sharding rules in :mod:`repro.sharding.specs` match on dict paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------- #
# initialisers
# --------------------------------------------------------------------------- #

def dense_init(key, n_in, n_out, dtype=jnp.float32, bias=False, scale=None):
    if scale is None:
        scale = (1.0 / n_in) ** 0.5
    p = {"kernel": (scale * jax.random.normal(key, (n_in, n_out))).astype(dtype)}
    if bias:
        p["bias"] = jnp.zeros((n_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["kernel"]
    if "bias" in p:
        y = y + p["bias"]
    return y


def embed_init(key, vocab, d, dtype=jnp.float32):
    return {"embedding": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p, tokens):
    return p["embedding"][tokens]


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------- #
# RoPE (standard + multimodal M-RoPE)
# --------------------------------------------------------------------------- #

def rope_freqs(dh: int, theta: float = 10_000.0):
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """x: (B, H, T, Dh); positions: (B, T) absolute positions."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,T,dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                sections=(16, 24, 24), theta: float = 10_000.0) -> jax.Array:
    """Qwen2-VL multimodal RoPE (arXiv:2409.12191).

    x: (B, H, T, Dh); positions3: (B, 3, T) — temporal / height / width
    position ids.  ``sections`` partitions the dh/2 rotary frequencies among
    the three axes (t, h, w); text tokens carry identical t/h/w ids, reducing
    M-RoPE to standard RoPE for them.
    """
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(dh, theta)                       # (half,)
    # per-frequency axis selector: 0,1,2 over the sections
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)       # (half,)
    pos = positions3.astype(jnp.float32)[:, sec_id, :]  # (B, half, T)
    ang = pos.transpose(0, 2, 1)[:, None] * freqs       # (B,1,T,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Feed-forward blocks
# --------------------------------------------------------------------------- #

def mlp_init(key, d, d_ff, dtype=jnp.float32, gated=True,
             act: str = "silu"):
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d, d_ff, dtype),
         "wo": dense_init(ks[1], d_ff, d, dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], d, d_ff, dtype)
    return p


_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
         "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
         "sqrelu": lambda x: jnp.square(jax.nn.relu(x))}


def mlp(p, x, act: str = "silu"):
    a = _ACTS[act]
    h = dense(p["wi"], x)
    if "wg" in p:
        h = a(dense(p["wg"], x)) * h
    else:
        h = a(h)
    return dense(p["wo"], h)


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
