"""Unified decoder stack covering the assigned architecture families.

One configurable stack handles dense GQA (llama/qwen/gemma), MoE (mixtral /
llama4), hybrid recurrent (recurrentgemma), attention-free (rwkv6), VLM
backbones (qwen2-vl M-RoPE), plus an encoder-decoder wrapper (seamless).

Per-layer block types (``ModelConfig.block_pattern``, cycled over layers):

  "attn"   — full-attention transformer layer
  "swa"    — sliding-window attention layer (window = cfg.window)
  "rglru"  — RecurrentGemma recurrent layer
  "rwkv"   — RWKV6 layer (time-mix + channel-mix; replaces attn+FFN)

Execution modes:

  * ``loss(params, tokens, ...)``     — next-token CE (chunked over the
    sequence so the (tokens, vocab) logits are never materialised at once);
  * ``prefill(params, tokens, ...)``  — returns last-position logits + caches;
  * ``decode_step(params, token, state)`` — one token against the caches.

Parameter names are matched by :mod:`repro.sharding.specs` for pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import attention as attn
from repro.models import layers, moe, rglru, rwkv6
from repro.sharding.constrain import constrain, constrain_btd

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: Optional[int] = None
    d_ff: int = 512
    vocab: int = 1024
    block_pattern: tuple = ("attn",)
    window: Optional[int] = None           # for "swa" blocks
    softcap_attn: Optional[float] = None   # gemma2 attn logit cap
    softcap_final: Optional[float] = None  # gemma2 final logit cap
    qkv_bias: bool = False                 # qwen2
    qk_norm: bool = False                  # gemma3
    post_norm: bool = False                # gemma2 extra post-norms
    act: str = "silu"
    rope_theta: float = 10_000.0
    mrope_sections: Optional[tuple] = None  # qwen2-vl
    moe: Optional[moe.MoEConfig] = None
    moe_period: int = 1                    # every k-th layer is MoE
    n_shared_experts: int = 0              # llama4 shared expert
    embed_scale: bool = False              # gemma: x *= sqrt(d)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: Any = jnp.float32
    # long-context mode: cap "attn" layers to a sliding window (documented
    # deviation enabling long_500k for gemma2/gemma3/llama4)
    long_context_cap: Optional[int] = None
    # lax.scan over repeated layer-cycles (compile-time); False unrolls
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def block_type(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe_period
                                         == self.moe_period - 1)

    def layer_window(self, i: int) -> Optional[int]:
        bt = self.block_type(i)
        if bt == "swa":
            return self.window
        if bt == "attn":
            return self.long_context_cap
        return None

    def num_params(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for i in range(self.n_layers):
            bt = self.block_type(i)
            if bt in ("attn", "swa"):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * hd * d
            elif bt == "rglru":
                total += 2 * d * d + 3 * d * d + d  # in/gates/out approx
            elif bt == "rwkv":
                total += 4 * d * d + d * 64 * 2 + d * d  # time-mix
                total += d * d + 2 * d * f               # channel-mix
            if bt != "rwkv":
                if self.is_moe_layer(i):
                    total += self.moe.n_experts * 3 * d * f + d * self.moe.n_experts
                    total += self.n_shared_experts * 3 * d * f
                else:
                    total += 3 * d * f
        return total

    def active_params(self) -> int:
        """Active (per-token) parameters — MoE counts topk experts."""
        if self.moe is None:
            return self.num_params()
        d, f = self.d_model, self.d_ff
        inactive = (self.moe.n_experts - self.moe.topk) * 3 * d * f
        n_moe = sum(self.is_moe_layer(i) for i in range(self.n_layers))
        return self.num_params() - n_moe * inactive


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #

def init_params(key: jax.Array, cfg: ModelConfig) -> PyTree:
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: dict = {
        "embed": layers.embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
        "layers": {},
    }
    if not cfg.tie_embeddings:
        params["unembed"] = layers.dense_init(
            keys[1], cfg.d_model, cfg.vocab, cfg.dtype)
    for i in range(cfg.n_layers):
        params["layers"][f"layer_{i}"] = _layer_init(keys[i + 2], cfg, i)
    return params


def _layer_init(key, cfg: ModelConfig, i: int) -> dict:
    bt = cfg.block_type(i)
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 8)
    p: dict = {}
    if bt in ("attn", "swa"):
        p["ln_attn"] = layers.rmsnorm_init(d, cfg.dtype)
        p["q"] = layers.dense_init(ks[0], d, cfg.n_heads * hd, cfg.dtype,
                                   bias=cfg.qkv_bias)
        p["k"] = layers.dense_init(ks[1], d, cfg.n_kv_heads * hd, cfg.dtype,
                                   bias=cfg.qkv_bias)
        p["v"] = layers.dense_init(ks[2], d, cfg.n_kv_heads * hd, cfg.dtype,
                                   bias=cfg.qkv_bias)
        p["o"] = layers.dense_init(ks[3], cfg.n_heads * hd, d, cfg.dtype)
        if cfg.qk_norm:
            p["q_norm"] = layers.rmsnorm_init(hd, cfg.dtype)
            p["k_norm"] = layers.rmsnorm_init(hd, cfg.dtype)
        if cfg.post_norm:
            p["ln_attn_post"] = layers.rmsnorm_init(d, cfg.dtype)
    elif bt == "rglru":
        p["ln_attn"] = layers.rmsnorm_init(d, cfg.dtype)
        p["rglru"] = rglru.rglru_init(ks[0], d, d, cfg.dtype)
    elif bt == "rwkv":
        p["ln_tm"] = layers.layernorm_init(d, cfg.dtype)
        p["ln_cm"] = layers.layernorm_init(d, cfg.dtype)
        p["rwkv"] = rwkv6.rwkv6_init(ks[0], d, cfg.d_ff, dtype=cfg.dtype)
        return p
    else:
        raise ValueError(f"unknown block type {bt!r}")

    p["ln_mlp"] = layers.rmsnorm_init(d, cfg.dtype)
    if cfg.is_moe_layer(i):
        p["moe"] = moe.moe_init(ks[4], d, cfg.d_ff, cfg.moe, cfg.dtype)
        if cfg.n_shared_experts:
            p["shared_mlp"] = layers.mlp_init(
                ks[5], d, cfg.n_shared_experts * cfg.d_ff, cfg.dtype)
    else:
        p["mlp"] = layers.mlp_init(ks[4], d, cfg.d_ff, cfg.dtype)
    if cfg.post_norm:
        p["ln_mlp_post"] = layers.rmsnorm_init(d, cfg.dtype)
    return p


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #

def _split_heads(x, n, hd):
    b, t, _ = x.shape
    return x.reshape(b, t, n, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * hd)


def _qkv(p, cfg: ModelConfig, x, positions, positions3=None):
    hd = cfg.hd
    q = _split_heads(layers.dense(p["q"], x), cfg.n_heads, hd)
    k = _split_heads(layers.dense(p["k"], x), cfg.n_kv_heads, hd)
    v = _split_heads(layers.dense(p["v"], x), cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = layers.rmsnorm(p["q_norm"], q)
        k = layers.rmsnorm(p["k_norm"], k)
    if cfg.mrope_sections is not None and positions3 is not None:
        q = layers.apply_mrope(q, positions3, cfg.mrope_sections,
                               cfg.rope_theta)
        k = layers.apply_mrope(k, positions3, cfg.mrope_sections,
                               cfg.rope_theta)
    else:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(p, cfg: ModelConfig, i: int, x):
    """Feed-forward (dense or MoE); returns (out, aux_loss)."""
    if cfg.is_moe_layer(i):
        out, aux = moe.moe_apply(p["moe"], x, cfg.moe, cfg.act)
        if cfg.n_shared_experts:
            out = out + layers.mlp(p["shared_mlp"], x, cfg.act)
        return out, aux
    return layers.mlp(p["mlp"], x, cfg.act), jnp.zeros((), jnp.float32)


def _layer_fwd(p, cfg: ModelConfig, i: int, x, positions, positions3,
               causal: bool = True):
    """Full-sequence layer forward (train / prefill). Returns (x, aux)."""
    bt = cfg.block_type(i)
    x = constrain_btd(x)
    if bt == "rwkv":
        x = x + rwkv6.time_mix(p["rwkv"], layers.layernorm(p["ln_tm"], x))
        x = constrain_btd(x)
        x = x + rwkv6.channel_mix(p["rwkv"], layers.layernorm(p["ln_cm"], x))
        return constrain_btd(x), jnp.zeros((), jnp.float32)

    h = layers.rmsnorm(p["ln_attn"], x)
    if bt == "rglru":
        y = rglru.rglru_block(p["rglru"], h)
    else:
        q, k, v = _qkv(p, cfg, h, positions, positions3)
        y = attn.chunked_attention(
            q, k, v, causal=causal, window=cfg.layer_window(i),
            softcap=cfg.softcap_attn)
        y = layers.dense(p["o"], _merge_heads(y))
    if cfg.post_norm:
        y = layers.rmsnorm(p["ln_attn_post"], y)
    x = constrain_btd(x + y)

    h = layers.rmsnorm(p["ln_mlp"], x)
    y, aux = _ffn(p, cfg, i, h)
    if cfg.post_norm:
        y = layers.rmsnorm(p["ln_mlp_post"], y)
    return constrain_btd(x + y), aux


def _embed_in(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    x = layers.embed(params["embed"], tokens).astype(cfg.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    return x


def _unembed(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        logits = h.astype(jnp.float32) @ params["embed"][
            "embedding"].T.astype(jnp.float32)
    else:
        logits = layers.dense(params["unembed"], h).astype(jnp.float32)
    return layers.softcap(logits, cfg.softcap_final)


def _effective_cycle(cfg: ModelConfig) -> int:
    """Layer-cycle length after which the layer *function* repeats exactly
    (lcm of the block pattern and the MoE period)."""
    import math
    cyc = len(cfg.block_pattern)
    if cfg.moe is not None:
        cyc = math.lcm(cyc, cfg.moe_period)
    return cyc


def forward_hidden(params, cfg: ModelConfig, tokens, *,
                   prefix_embeds=None, positions3=None, causal=True,
                   remat: bool = True, scan_layers: bool = True):
    """Token ids (+ optional prefix embeddings) -> final hidden states.

    ``scan_layers``: stack the parameters of repeated layer-cycles and run
    them under ``lax.scan`` — the layer body is compiled ONCE per cycle
    position instead of once per layer (MaxText-style; ~n_layers/cycle x
    faster XLA compiles for the deep stacks).  Numerics are identical to the
    unrolled loop (tested).  Remat is per cycle under scan, per layer when
    unrolled.
    """
    x = _embed_in(params, cfg, tokens, prefix_embeds)
    t = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t), (x.shape[0], t))
    if positions3 is None and cfg.mrope_sections is not None:
        # text-only default: t/h/w ids all equal the linear position
        positions3 = jnp.broadcast_to(positions[:, None], (x.shape[0], 3, t))
    aux_total = jnp.zeros((), jnp.float32)

    cyc = _effective_cycle(cfg)
    n_rep = cfg.n_layers // cyc
    first_unstacked = n_rep * cyc
    use_scan = scan_layers and cfg.scan_layers and n_rep >= 2

    if use_scan:
        # stack each cycle position's params across repeats: (n_rep, ...)
        stacked = tuple(
            jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls),
                *(params["layers"][f"layer_{r * cyc + pos}"]
                  for r in range(n_rep)))
            for pos in range(cyc))

        def cycle_body(x_, cycle_params):
            aux_c = jnp.zeros((), jnp.float32)
            for pos in range(cyc):
                x_, aux = _layer_fwd(cycle_params[pos], cfg, pos, x_,
                                     positions, positions3, causal)
                aux_c = aux_c + aux
            return x_, aux_c

        body = jax.checkpoint(cycle_body) if remat else cycle_body

        def scan_fn(carry, cycle_params):
            x_, aux_t = carry
            x_, aux = body(x_, cycle_params)
            return (x_, aux_t + aux), None

        (x, aux_total), _ = jax.lax.scan(scan_fn, (x, aux_total), stacked)

    for i in range(first_unstacked if use_scan else 0, cfg.n_layers):
        p = params["layers"][f"layer_{i}"]
        fwd = lambda p_, x_, i_=i: _layer_fwd(
            p_, cfg, i_, x_, positions, positions3, causal)
        if remat:
            fwd = jax.checkpoint(fwd)
        x, aux = fwd(p, x)
        aux_total = aux_total + aux
    return layers.rmsnorm(params["final_norm"], x), aux_total


def loss(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
         positions3=None, loss_chunk: int = 1024, aux_weight: float = 0.01,
         remat: bool = True):
    """Next-token chunked cross-entropy over the token positions."""
    h, aux = forward_hidden(params, cfg, tokens, prefix_embeds=prefix_embeds,
                            positions3=positions3, remat=remat)
    npre = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    h = h[:, npre:]
    b, t, d = h.shape
    inputs = h[:, :-1]
    targets = tokens[:, 1:]
    tm1 = t - 1
    chunk = min(loss_chunk, tm1)
    nchunk = -(-tm1 // chunk)
    pad = nchunk * chunk - tm1
    inputs = jnp.pad(inputs, ((0, 0), (0, pad), (0, 0)))
    targets = jnp.pad(targets, ((0, 0), (0, pad)))
    wmask = jnp.pad(jnp.ones((b, tm1), jnp.float32), ((0, 0), (0, pad)))

    # remat per chunk: the (B, chunk, vocab) logits are recomputed in the
    # backward pass instead of being stored as scan residuals.
    @jax.checkpoint
    def _chunk_nll(hs, ys, ws):
        logits = constrain(_unembed(params, cfg, hs),
                           {0: "batch", 1: "seq"})
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, ys[..., None], axis=-1)[..., 0]
        return (nll * ws).sum()

    def chunk_loss(carry, idx):
        hs = jax.lax.dynamic_slice_in_dim(inputs, idx * chunk, chunk, axis=1)
        ys = jax.lax.dynamic_slice_in_dim(targets, idx * chunk, chunk, axis=1)
        ws = jax.lax.dynamic_slice_in_dim(wmask, idx * chunk, chunk, axis=1)
        return carry + _chunk_nll(hs, ys, ws), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros(()), jnp.arange(nchunk))
    return total / (b * tm1) + aux_weight * aux


# --------------------------------------------------------------------------- #
# inference: prefill + decode
# --------------------------------------------------------------------------- #

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """Per-layer cache pytree sized for ``max_len`` context."""
    state = {}
    for i in range(cfg.n_layers):
        bt = cfg.block_type(i)
        if bt in ("attn", "swa"):
            w = cfg.layer_window(i)
            size = min(max_len, w) if w is not None else max_len
            state[f"layer_{i}"] = attn.init_cache(
                batch, cfg.n_kv_heads, size, cfg.hd, dtype)
        elif bt == "rglru":
            state[f"layer_{i}"] = rglru.rglru_init_state(
                batch, cfg.d_model, dtype)
        elif bt == "rwkv":
            state[f"layer_{i}"] = rwkv6.rwkv_init_state(
                batch, cfg.d_model, dtype=dtype)
    return state


def decode_step(params, cfg: ModelConfig, token, state: dict,
                positions3=None):
    """One-token step.  token: (B,) int32.  Returns (logits, new_state)."""
    b = token.shape[0]
    x = _embed_in(params, cfg, token[:, None])
    new_state = {}
    # absolute position: every layer state tracks the same length; use the
    # first layer's counter.
    first = state[f"layer_{_first_attn_layer(cfg)}"] \
        if _first_attn_layer(cfg) is not None else None
    pos_scalar = (first.length if isinstance(first, attn.KVCache)
                  else jnp.zeros((), jnp.int32))
    positions = jnp.broadcast_to(pos_scalar, (b, 1))
    if positions3 is None and cfg.mrope_sections is not None:
        positions3 = jnp.broadcast_to(positions[:, None], (b, 3, 1))
    for i in range(cfg.n_layers):
        p = params["layers"][f"layer_{i}"]
        bt = cfg.block_type(i)
        st = state[f"layer_{i}"]
        if bt == "rwkv":
            h = layers.layernorm(p["ln_tm"], x)
            y, shift_tm, s_new = rwkv6.time_mix_decode(
                p["rwkv"], h, st.shift_tm, st.s)
            x = x + y
            h = layers.layernorm(p["ln_cm"], x)
            x = x + rwkv6.channel_mix(p["rwkv"], h, prev=st.shift_cm)
            new_state[f"layer_{i}"] = rwkv6.RWKVState(
                shift_tm=shift_tm, shift_cm=h[:, -1], s=s_new)
            continue
        h = layers.rmsnorm(p["ln_attn"], x)
        if bt == "rglru":
            y, st_new = rglru.rglru_block_decode(p["rglru"], h, st)
        else:
            q, k, v = _qkv(p, cfg, h, positions, positions3)
            w = cfg.layer_window(i)
            ring = w is not None and st.k.shape[2] == w
            if ring:
                st_new = attn.update_ring_cache(st, k, v)
                y = attn.ring_decode_attention(q, st_new,
                                               softcap=cfg.softcap_attn)
            else:
                st_new = attn.update_cache(st, k, v)
                y = attn.decode_attention(q, st_new, window=w,
                                          softcap=cfg.softcap_attn)
            y = layers.dense(p["o"], _merge_heads(y))
        if cfg.post_norm:
            y = layers.rmsnorm(p["ln_attn_post"], y)
        x = x + y
        h = layers.rmsnorm(p["ln_mlp"], x)
        y, _ = _ffn(p, cfg, i, h)
        if cfg.post_norm:
            y = layers.rmsnorm(p["ln_mlp_post"], y)
        x = x + y
        new_state[f"layer_{i}"] = st_new
    h = layers.rmsnorm(params["final_norm"], x)
    return _unembed(params, cfg, h)[:, 0], new_state


def _first_attn_layer(cfg: ModelConfig):
    for i in range(cfg.n_layers):
        if cfg.block_type(i) in ("attn", "swa"):
            return i
    return None


def prefill(params, cfg: ModelConfig, tokens, max_len: int, *,
            prefix_embeds=None, positions3=None, dtype=jnp.bfloat16):
    """Process a prompt; returns (last-position logits, decode state).

    Caches are produced by the full-sequence forward (recomputing k/v per
    layer), sized for ``max_len``.
    """
    b, t = tokens.shape
    x = _embed_in(params, cfg, tokens, prefix_embeds)
    ttot = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(ttot), (b, ttot))
    if positions3 is None and cfg.mrope_sections is not None:
        positions3 = jnp.broadcast_to(positions[:, None], (b, 3, ttot))
    state = init_decode_state(cfg, b, max_len, dtype)
    new_state = {}
    for i in range(cfg.n_layers):
        p = params["layers"][f"layer_{i}"]
        bt = cfg.block_type(i)
        if bt == "rwkv":
            h = layers.layernorm(p["ln_tm"], x)
            r, k, v, g, w = rwkv6._tm_inputs(p["rwkv"], h)
            hd = 64
            y, s_fin = kops.wkv6_scan(
                rwkv6._heads(r, hd), rwkv6._heads(k, hd),
                rwkv6._heads(v, hd), rwkv6._heads(w, hd), p["rwkv"]["u"])
            x = x + rwkv6._gn_gate(p["rwkv"], rwkv6._unheads(y).astype(x.dtype), g)
            hcm = layers.layernorm(p["ln_cm"], x)
            x = x + rwkv6.channel_mix(p["rwkv"], hcm)
            new_state[f"layer_{i}"] = rwkv6.RWKVState(
                shift_tm=h[:, -1], shift_cm=hcm[:, -1], s=s_fin)
            continue
        h = layers.rmsnorm(p["ln_attn"], x)
        if bt == "rglru":
            gate = jax.nn.gelu(layers.dense(p["rglru"]["wy"], h))
            xr = layers.dense(p["rglru"]["wx"], h)
            xc, conv_st = rglru._causal_depthwise_conv(
                p["rglru"]["conv"]["kernel"], xr)
            a, gi = rglru._rglru_gates(p["rglru"], xc)
            ys, h_fin = kops.rglru_scan(gi * xc.astype(jnp.float32), a)
            y = layers.dense(p["rglru"]["wo"], ys.astype(x.dtype) * gate)
            new_state[f"layer_{i}"] = rglru.RGLRUState(
                conv=conv_st.astype(dtype), h=h_fin)
        else:
            q, k, v = _qkv(p, cfg, h, positions, positions3)
            y = attn.chunked_attention(q, k, v, causal=True,
                                       window=cfg.layer_window(i),
                                       softcap=cfg.softcap_attn)
            y = layers.dense(p["o"], _merge_heads(y))
            st = state[f"layer_{i}"]
            size = st.k.shape[2]
            if size < ttot:
                # ring cache: keep the last `size` positions, rotated so that
                # slot s holds the token with absolute position p, p % size = s
                # (matches update_ring_cache's slot = length % window).
                ks_ = jnp.roll(k[:, :, -size:], ttot % size, axis=2)
                vs_ = jnp.roll(v[:, :, -size:], ttot % size, axis=2)
                st_new = attn.KVCache(
                    k=ks_.astype(st.k.dtype), v=vs_.astype(st.v.dtype),
                    length=jnp.asarray(ttot, jnp.int32))
            else:
                st_new = attn.update_cache(st, k, v)
            new_state[f"layer_{i}"] = st_new
        if cfg.post_norm:
            y = layers.rmsnorm(p["ln_attn_post"], y)
        x = x + y
        h = layers.rmsnorm(p["ln_mlp"], x)
        y, _ = _ffn(p, cfg, i, h)
        if cfg.post_norm:
            y = layers.rmsnorm(p["ln_mlp_post"], y)
        x = x + y
    h = layers.rmsnorm(params["final_norm"], x)
    logits = _unembed(params, cfg, h[:, -1:])[:, 0]
    return logits, new_state
