"""Mixture-of-Experts feed-forward (Mixtral 8x7B top-2, Llama-4 128e top-1).

GShard-style capacity-based token-choice routing, chosen for SPMD
shardability: every einsum has static shapes, experts shard over the
``model`` mesh axis (expert parallelism), tokens over ``data``; the combine
contraction over the expert axis is what XLA turns into the expert
all-to-all / reduce pattern.

Tokens are processed in groups of ``group_size`` (default 256); per group
each expert has capacity C = ceil(group_size * topk * capacity_factor /
n_experts).  Overflowing tokens are dropped (standard dropped-token MoE);
the router carries an auxiliary load-balance loss (Switch/Mixtral style).

The dispatch tensors cost O(tokens * group_size * topk) memory/FLOPs —
kept ~0.1% of model FLOPs by the small group size (see EXPERIMENTS.md
§Roofline "useful-FLOPs ratio").
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.sharding.constrain import constrain, constrain_moe


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    topk: int = 2
    group_size: int = 256
    capacity_factor: float = 1.25

    def capacity(self) -> int:
        c = self.group_size * self.topk * self.capacity_factor / self.n_experts
        return max(4, int(-(-c // 1)))  # ceil, floor of 4


def moe_init(key, d: int, d_ff: int, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    scale = (1.0 / d) ** 0.5
    e = cfg.n_experts
    return {
        "router": layers.dense_init(ks[0], d, e, jnp.float32),
        "wi": {"kernel": (scale * jax.random.normal(
            ks[1], (e, d, d_ff))).astype(dtype)},
        "wg": {"kernel": (scale * jax.random.normal(
            ks[2], (e, d, d_ff))).astype(dtype)},
        "wo": {"kernel": ((1.0 / d_ff) ** 0.5 * jax.random.normal(
            ks[3], (e, d_ff, d))).astype(dtype)},
    }


def moe_apply(params, x: jax.Array, cfg: MoEConfig, act: str = "silu"):
    """x: (B, T, D) -> (out (B, T, D), aux_loss scalar)."""
    b, t, d = x.shape
    tokens = x.reshape(-1, d)
    n_tok = tokens.shape[0]
    gs = min(cfg.group_size, n_tok)
    pad = (-n_tok) % gs
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    g = tokens.shape[0] // gs
    xt = constrain(tokens.reshape(g, gs, d), {0: "batch", 1: "seq"})
    e, cap = cfg.n_experts, cfg.capacity()

    # router matmul in model dtype (the f32 upcast of the full token tensor
    # dominated HLO temps); softmax/top-k stay in f32.
    logits = (xt @ params["router"]["kernel"].astype(x.dtype)
              ).astype(jnp.float32)                      # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- top-k selection ------------------------------------------------- #
    topw, topi = jax.lax.top_k(probs, cfg.topk)          # (G,S,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # --- capacity assignment (position of each token in its expert queue) - #
    sel = jax.nn.one_hot(topi, e, dtype=jnp.float32)     # (G,S,k,E)
    # priority: earlier tokens first; rank within expert across (S, k).
    # These (G, S*k, E) rank tensors are the largest routing intermediates —
    # pin groups to (pod, data) and the slot dim to model.
    selk = constrain(sel.reshape(g, gs * cfg.topk, e),
                     {0: "batch", 1: "seq"})
    pos_in_expert = constrain(jnp.cumsum(selk, axis=1) - selk,
                              {0: "batch", 1: "seq"})   # (G,S*k,E)
    pos = (pos_in_expert * selk).sum(-1).reshape(g, gs, cfg.topk)
    keep = pos < cap
    topw = topw * keep

    # --- dispatch / combine one-hots -------------------------------------- #
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    # (G,S,k,E) x (G,S,k,C) -> (G,S,E,C)
    combine = constrain_moe(
        jnp.einsum("gske,gskc,gsk->gsec", sel, pos_oh, topw), 0, 2)
    dispatch = constrain_moe(
        jnp.einsum("gske,gskc,gsk->gsec", sel, pos_oh,
                   keep.astype(jnp.float32)), 0, 2)

    # --- expert compute (groups over data, experts/d_ff over model) ------- #
    # (capacity-dim sharding was tried and REGRESSED: resharding between the
    # C-sharded dispatch and F-sharded FFN einsums materialises replicated
    # copies — see EXPERIMENTS.md §Perf iteration log)
    xe = constrain_moe(
        jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xt), 0, 1)
    a = layers._ACTS[act]
    hi = constrain_moe(
        jnp.einsum("gecd,edf->gecf", xe, params["wi"]["kernel"]), 0, 1, 3)
    hg = constrain_moe(
        jnp.einsum("gecd,edf->gecf", xe, params["wg"]["kernel"]), 0, 1, 3)
    h = a(hg) * hi
    ye = constrain_moe(
        jnp.einsum("gecf,efd->gecd", h, params["wo"]["kernel"]), 0, 1)
    yt = constrain(
        jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye),
        {0: "batch"})

    out = yt.reshape(-1, d)[:n_tok].reshape(b, t, d)

    # --- Switch-style load-balance auxiliary loss -------------------------- #
    frac_tokens = sel.sum(2).mean(axis=1)                # (G,E) fraction routed
    frac_probs = probs.mean(axis=1)                      # (G,E)
    aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    return out, aux
