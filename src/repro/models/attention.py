"""Memory-safe attention for training/prefill/decode.

Three execution paths, one semantics (oracle: kernels/ref.py):

* ``chunked_attention`` — differentiable blockwise online-softmax written
  with ``jax.lax`` control flow.  Never materialises the (Tq, Tk) matrix, so
  32k-token prefill lowers with bounded memory on any backend.  This is what
  the model stacks call; on TPU the same math is served by the Pallas flash
  kernel (kernels/flash_attention.py) via kernels.ops dispatch for inference.
* ``decode_attention`` — one-token query against a KV cache (serve_step).
* sliding-window / chunked-local masking for the long-context archs.

GQA is handled without materialising repeated KV: queries are folded to
(B, Hkv, G, T, Dh) and einsums contract against the shared KV heads.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# custom-VJP flash attention (training path)
#
# lax.scan autodiff stores the per-chunk probability tensors as residuals —
# at (B, H, Tq, chunk) x nchunks that is the full quadratic attention matrix
# (measured: 39.5 GiB/device for one qwen2-0.5b layer at train_4k).  The
# custom VJP saves only (q, k, v, out, logsumexp) and recomputes each chunk's
# probabilities in the backward scan — O(B*H*T*Dh) residency, the standard
# FlashAttention-2 strategy.
# --------------------------------------------------------------------------- #

def _constrain_tq(x: jax.Array, tq_axis: int) -> jax.Array:
    """Shard the query-time dim over ``model`` + batch over (pod, data).

    The flash scans' per-chunk f32 intermediates are the dominant training
    temps; without this constraint GSPMD only shards them over ``data``
    (batch), replicating across ``model``.  No-op outside a mesh context or
    when dims don't divide.
    """
    from repro.sharding.constrain import constrain
    return constrain(x, {0: "batch", tq_axis: "seq"})


def _mask_for(qpos, kpos, causal, window):
    mask = None
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        wmask = kpos[None, :] > qpos[:, None] - window
        mask = wmask if mask is None else (mask & wmask)
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_offset, softcap, chunk):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, softcap,
                             chunk)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_offset, softcap, chunk):
    b, hq, tq, dh = q.shape
    _, hkv, tk, _ = k.shape
    nchunks = tk // chunk
    scale = 1.0 / (dh ** 0.5)
    g = hq // hkv
    # keep q/k/v in model dtype; accumulate in f32 via preferred_element_type
    # (MXU-native on TPU, and keeps the cross-`model` KV gathers in bf16 —
    # the f32 upcast otherwise gets hoisted above the gather, doubling it)
    qf = _constrain_tq(_fold_gqa(q, hkv) * jnp.asarray(scale, q.dtype), 3)
    kc = k.reshape(b, hkv, nchunks, chunk, dh)
    vc = v.reshape(b, hkv, nchunks, chunk, dh)
    qpos = q_offset + jnp.arange(tq)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp
        kpos = ci * chunk + jnp.arange(chunk)
        s = _constrain_tq(
            jnp.einsum("bngqd,bnkd->bngqk", qf, kb,
                       preferred_element_type=jnp.float32), 3)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = _mask_for(qpos, kpos, causal, window)
        if mask is not None:
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bngqk,bnkd->bngqd",
                                      p.astype(v.dtype), vb,
                                      preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, tq, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, tq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, tq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(nchunks)))
    # logsumexp; +inf sentinel for fully-masked rows so bwd p = exp(s-lse) = 0
    lse = jnp.where(l > 0, m + jnp.log(jnp.where(l > 0, l, 1.0)),
                    jnp.asarray(1e30, jnp.float32))
    out = (acc / jnp.where(l > 0, l, 1.0))
    return out.reshape(b, hq, tq, dh).astype(q.dtype), lse


def _flash_fwd(q, k, v, causal, window, q_offset, softcap, chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, softcap,
                               chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, softcap, chunk, res, dout):
    q, k, v, out, lse = res
    b, hq, tq, dh = q.shape
    _, hkv, tk, _ = k.shape
    nchunks = tk // chunk
    scale = 1.0 / (dh ** 0.5)
    g = hq // hkv
    qf = _constrain_tq(_fold_gqa(q, hkv).astype(jnp.float32), 3)
    of = _constrain_tq(_fold_gqa(out, hkv).astype(jnp.float32), 3)
    dof = _constrain_tq(_fold_gqa(dout, hkv).astype(jnp.float32), 3)
    kc = k.reshape(b, hkv, nchunks, chunk, dh)
    vc = v.reshape(b, hkv, nchunks, chunk, dh)
    qpos = q_offset + jnp.arange(tq)
    delta = jnp.sum(of * dof, axis=-1, keepdims=True)   # (B,Hkv,G,Tq,1)

    def body(dq, inp):
        kb, vb, ci = inp
        kpos = ci * chunk + jnp.arange(chunk)
        s_raw = _constrain_tq(
            jnp.einsum("bngqd,bnkd->bngqk", qf,
                       kb.astype(jnp.float32)), 3) * scale
        if softcap is not None:
            th = jnp.tanh(s_raw / softcap)
            s = softcap * th
        else:
            s = s_raw
        mask = _mask_for(qpos, kpos, causal, window)
        if mask is not None:
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
        p = jnp.exp(s - lse)                            # (B,Hkv,G,Tq,ck)
        dv = jnp.einsum("bngqk,bngqd->bnkd", p, dof)
        dp = jnp.einsum("bngqd,bnkd->bngqk", dof, vb.astype(jnp.float32))
        ds = p * (dp - delta)
        if softcap is not None:
            ds = ds * (1.0 - th * th)
        if mask is not None:
            ds = jnp.where(mask[None, None, None], ds, 0.0)
        dq = dq + jnp.einsum("bngqk,bnkd->bngqd", ds,
                             kb.astype(jnp.float32)) * scale
        dk = jnp.einsum("bngqk,bngqd->bnkd", ds, qf) * scale
        return dq, (dk, dv)

    dq0 = jnp.zeros((b, hkv, g, tq, dh), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(
        body, dq0,
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(nchunks)))
    dk = dk.transpose(1, 2, 0, 3, 4).reshape(b, hkv, tk, dh)
    dv = dv.transpose(1, 2, 0, 3, 4).reshape(b, hkv, tk, dh)
    return (dq.reshape(b, hq, tq, dh).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


class KVCache(NamedTuple):
    k: jax.Array          # (B, Hkv, S, Dh)
    v: jax.Array          # (B, Hkv, S, Dh)
    length: jax.Array     # () int32 — tokens currently valid


def init_cache(batch: int, n_kv: int, max_len: int, dh: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, n_kv, max_len, dh), dtype),
        v=jnp.zeros((batch, n_kv, max_len, dh), dtype),
        length=jnp.zeros((), jnp.int32))


def _fold_gqa(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, Hq, T, Dh) -> (B, Hkv, G, T, Dh)."""
    b, hq, t, dh = q.shape
    return q.reshape(b, n_kv, hq // n_kv, t, dh)


def chunked_attention(
    q: jax.Array,                 # (B, Hq, Tq, Dh)
    k: jax.Array,                 # (B, Hkv, Tk, Dh)
    v: jax.Array,                 # (B, Hkv, Tk, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    softcap: Optional[float] = None,
    kv_length: Optional[jax.Array] = None,   # valid prefix of k/v
    chunk: int = 512,
) -> jax.Array:
    """Blockwise online-softmax attention via lax.scan over KV chunks.

    When ``kv_length`` is None the call routes through the custom-VJP flash
    implementation (O(B*H*T*Dh) backward residency); the explicit-length
    variant (decode against partially-filled caches) keeps the plain scan.
    """
    b, hq, tq, dh = q.shape
    _, hkv, tk, _ = k.shape
    chunk = min(chunk, tk)
    pad = (-tk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    tk_p = tk + pad
    if kv_length is None and (not pad or causal):
        # causal masking already hides end-padding (kpos > max qpos)
        return _flash(q, k, v, causal, window, q_offset, softcap, chunk)
    nchunks = tk_p // chunk
    scale = 1.0 / (dh ** 0.5)

    qf = _fold_gqa(q, hkv).astype(jnp.float32) * scale   # (B,Hkv,G,Tq,Dh)
    kc = k.reshape(b, hkv, nchunks, chunk, dh)
    vc = v.reshape(b, hkv, nchunks, chunk, dh)
    qpos = q_offset + jnp.arange(tq)                     # (Tq,)
    limit = jnp.asarray(tk if kv_length is None else kv_length, jnp.int32)

    def body(carry, inp):
        m, l, acc = carry                                # (B,Hkv,G,Tq,1), ..., (...,Dh)
        kb, vb, ci = inp                                 # (B,Hkv,chunk,Dh) x2, ()
        kpos = ci * chunk + jnp.arange(chunk)            # (chunk,)
        s = jnp.einsum("bngqd,bnkd->bngqk", qf, kb.astype(jnp.float32))
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos[None, :] < limit
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bngqk,bnkd->bngqd",
                                      p.astype(v.dtype), vb,
                                      preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    g = hq // hkv
    m0 = jnp.full((b, hkv, g, tq, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, tq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, tq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(nchunks)))
    out = acc / jnp.where(l > 0, l, 1.0)
    return out.reshape(b, hq, tq, dh).astype(q.dtype)


def decode_attention(
    q: jax.Array,                 # (B, Hq, 1, Dh)
    cache: KVCache,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    chunk: int = 2048,
) -> jax.Array:
    """Single-token decode against the cache (cache already updated)."""
    return _decode_impl(q, cache, window=window, softcap=softcap, chunk=chunk)


def _decode_impl(q, cache, *, window, softcap, chunk):
    b, hq, _, dh = q.shape
    hkv = cache.k.shape[1]
    s_len = cache.k.shape[2]
    qf = _fold_gqa(q, hkv).astype(jnp.float32) / (dh ** 0.5)  # (B,Hkv,G,1,Dh)
    qpos = cache.length - 1
    kpos = jnp.arange(s_len)
    mask = (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.einsum("bngqd,bnkd->bngqk", qf, cache.k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[None, None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngqk,bnkd->bngqd", p, cache.v.astype(jnp.float32))
    return out.reshape(b, hq, 1, dh).astype(q.dtype)


def update_cache(cache: KVCache, k_new: jax.Array, v_new: jax.Array) -> KVCache:
    """Append k/v (B, Hkv, T_new, Dh) at the current length."""
    t_new = k_new.shape[2]
    k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), cache.length, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), cache.length, axis=2)
    return KVCache(k=k, v=v, length=cache.length + t_new)


# --------------------------------------------------------------------------- #
# Ring (sliding-window) cache — the sub-quadratic memory story for long_500k:
# windowed layers keep only `window` KV entries regardless of context length.
# --------------------------------------------------------------------------- #

def init_ring_cache(batch: int, n_kv: int, window: int, dh: int,
                    dtype=jnp.bfloat16) -> KVCache:
    return init_cache(batch, n_kv, window, dh, dtype)


def update_ring_cache(cache: KVCache, k_new: jax.Array,
                      v_new: jax.Array) -> KVCache:
    """Single-token ring-buffer append (decode path)."""
    assert k_new.shape[2] == 1, "ring cache append is one token at a time"
    window = cache.k.shape[2]
    slot = jnp.mod(cache.length, window)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), slot, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), slot, axis=2)
    return KVCache(k=k, v=v, length=cache.length + 1)


def ring_decode_attention(q: jax.Array, cache: KVCache, *,
                          softcap: Optional[float] = None) -> jax.Array:
    """Decode against a ring cache: every stored entry within the window is
    valid; entries beyond ``length`` (cold start) are masked."""
    b, hq, _, dh = q.shape
    hkv = cache.k.shape[1]
    window = cache.k.shape[2]
    qf = _fold_gqa(q, hkv).astype(jnp.float32) / (dh ** 0.5)
    valid = jnp.arange(window) < jnp.minimum(cache.length, window)
    s = jnp.einsum("bngqd,bnkd->bngqk", qf, cache.k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[None, None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngqk,bnkd->bngqd", p, cache.v.astype(jnp.float32))
    return out.reshape(b, hq, 1, dh).astype(q.dtype)
