"""RWKV6 "Finch" block (arXiv:2404.05892) — attention-free SSM.

Time-mix with *data-dependent decay* (the defining RWKV6 feature):

    xx_t   = x_{t-1} - x_t                       (token shift)
    z_q    = x_t + xx_t * mu_q,   q in {r, k, v, w, g}
    w_t    = exp(-exp(w0 + tanh(z_w A_w) B_w))   (low-rank data-dep decay)
    y_t    = WKV6(r, k, v, w, u)                 (kernels.ops.wkv6_scan)
    out    = W_o (groupnorm(y) * silu(g))

Channel-mix (replaces the FFN):

    r = sigmoid(W_r z_r);  k = relu(W_k z_k)^2;  out = r * (W_v k)

Decode state per block: (shift_tm, shift_cm (B, D), S (B, H, K, V)) —
O(1) in context length, which is why rwkv6 runs the 500k decode shape.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers


class RWKVState(NamedTuple):
    shift_tm: jax.Array   # (B, D)  last input to time-mix
    shift_cm: jax.Array   # (B, D)  last input to channel-mix
    s: jax.Array          # (B, H, K, V) wkv state


def rwkv6_init(key, d: int, d_ff: int, head_dim: int = 64,
               decay_rank: int = 64, dtype=jnp.float32):
    h = d // head_dim
    ks = jax.random.split(key, 12)
    mu = lambda k_: (jax.random.uniform(k_, (5, d)) * 0.5).astype(dtype)
    return {
        "mu": mu(ks[0]),                                   # r,k,v,w,g shifts
        "wr": layers.dense_init(ks[1], d, d, dtype),
        "wk": layers.dense_init(ks[2], d, d, dtype),
        "wv": layers.dense_init(ks[3], d, d, dtype),
        "wg": layers.dense_init(ks[4], d, d, dtype),
        "w0": (jax.random.normal(ks[5], (d,)) * 0.5 - 6.0).astype(jnp.float32),
        "wa": layers.dense_init(ks[6], d, decay_rank, dtype),
        "wb": layers.dense_init(ks[7], decay_rank, d, dtype),
        "u": (jax.random.normal(ks[8], (h, head_dim)) * 0.1).astype(jnp.float32),
        "gn": layers.layernorm_init(d, dtype),             # per-head groupnorm
        "wo": layers.dense_init(ks[9], d, d, dtype),
        # channel mix
        "cm_mu": (jax.random.uniform(ks[10], (2, d)) * 0.5).astype(dtype),
        "cm_r": layers.dense_init(ks[11], d, d, dtype),
        "cm_k": layers.dense_init(jax.random.fold_in(key, 101), d, d_ff, dtype),
        "cm_v": layers.dense_init(jax.random.fold_in(key, 102), d_ff, d, dtype),
    }


def _shift(x, prev=None):
    """x_{t-1} with zero (or carried) initial token. x: (B, T, D)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def _tm_inputs(params, x, prev=None):
    xx = _shift(x, prev) - x
    mu = params["mu"]
    zr, zk, zv, zw, zg = (x + xx * mu[i] for i in range(5))
    r = layers.dense(params["wr"], zr)
    k = layers.dense(params["wk"], zk)
    v = layers.dense(params["wv"], zv)
    g = layers.dense(params["wg"], zg)
    dd = layers.dense(params["wb"], jnp.tanh(layers.dense(params["wa"], zw)))
    w = jnp.exp(-jnp.exp(params["w0"] + dd.astype(jnp.float32)))  # in (0,1)
    return r, k, v, g, w


def _heads(x, head_dim):
    b, t, d = x.shape
    return x.reshape(b, t, d // head_dim, head_dim).transpose(0, 2, 1, 3)


def _unheads(x):
    b, h, t, k = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * k)


def _gn_gate(params, y, g):
    y = layers.layernorm(params["gn"], y)
    return layers.dense(params["wo"], y * jax.nn.silu(g))


def time_mix(params, x: jax.Array, head_dim: int = 64):
    """x: (B, T, D) -> (B, T, D)."""
    r, k, v, g, w = _tm_inputs(params, x)
    rh, kh, vh, wh = (_heads(z, head_dim) for z in (r, k, v, w))
    y, _ = kops.wkv6_scan(rh, kh, vh, wh, params["u"])
    return _gn_gate(params, _unheads(y).astype(x.dtype), g)


def time_mix_decode(params, x: jax.Array, shift_prev, s_prev,
                    head_dim: int = 64):
    """x: (B, 1, D); one recurrence step."""
    r, k, v, g, w = _tm_inputs(params, x, prev=shift_prev)
    b, _, d = x.shape
    h = d // head_dim
    rh = r.reshape(b, h, head_dim).astype(jnp.float32)
    kh = k.reshape(b, h, head_dim).astype(jnp.float32)
    vh = v.reshape(b, h, head_dim).astype(jnp.float32)
    wh = w.reshape(b, h, head_dim)
    u = params["u"]
    kv = kh[..., :, None] * vh[..., None, :]                  # (B,H,K,V)
    y = jnp.einsum("bhk,bhkv->bhv", rh,
                   s_prev + u[None, :, :, None] * kv)
    s_new = wh[..., :, None] * s_prev + kv
    out = _gn_gate(params, y.reshape(b, 1, d).astype(x.dtype), g)
    return out, x[:, -1], s_new


def channel_mix(params, x: jax.Array, prev=None):
    xx = _shift(x, prev) - x
    mu = params["cm_mu"]
    zr, zk = x + xx * mu[0], x + xx * mu[1]
    r = jax.nn.sigmoid(layers.dense(params["cm_r"], zr))
    k = jnp.square(jax.nn.relu(layers.dense(params["cm_k"], zk)))
    return r * layers.dense(params["cm_v"], k)


def rwkv_init_state(batch: int, d: int, head_dim: int = 64,
                    dtype=jnp.bfloat16) -> RWKVState:
    h = d // head_dim
    return RWKVState(
        shift_tm=jnp.zeros((batch, d), dtype),
        shift_cm=jnp.zeros((batch, d), dtype),
        s=jnp.zeros((batch, h, head_dim, head_dim), jnp.float32))
