"""RecurrentGemma / Griffin recurrent block (arXiv:2402.19427).

Block: two parallel linear branches — a GeLU gate branch and a
conv1d(width 4, causal, depthwise) -> RG-LRU branch — multiplied and
projected back.  The RG-LRU recurrence:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The time scan is the TPU hot-spot -> kernels.ops.rglru_scan
(Pallas kernel on TPU, lax.scan oracle elsewhere).  Decode carries
(conv buffer (B, 3, D_rnn), h (B, D_rnn)) — O(1) in context length.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers

_C = 8.0
_CONV_W = 4


class RGLRUState(NamedTuple):
    conv: jax.Array   # (B, CONV_W-1, D_rnn) last inputs
    h: jax.Array      # (B, D_rnn)


def rglru_init(key, d: int, d_rnn: int, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    # Lambda init so that a in (0.9, 0.999) at r = 1 (Griffin appendix)
    lam = jax.random.uniform(ks[0], (d_rnn,), minval=jnp.log(
        jnp.expm1(-jnp.log(0.999) / _C)), maxval=jnp.log(
        jnp.expm1(-jnp.log(0.9) / _C)))
    return {
        "wx": layers.dense_init(ks[1], d, d_rnn, dtype),     # rnn branch in
        "wy": layers.dense_init(ks[2], d, d_rnn, dtype),     # gate branch in
        "conv": {"kernel": (jax.random.normal(ks[3], (_CONV_W, d_rnn))
                            * (1.0 / _CONV_W) ** 0.5).astype(dtype)},
        "gate_a": layers.dense_init(ks[4], d_rnn, d_rnn, dtype, bias=True),
        "gate_x": layers.dense_init(ks[5], d_rnn, d_rnn, dtype, bias=True),
        "lam": lam.astype(jnp.float32),
        "wo": layers.dense_init(ks[6], d_rnn, d, dtype),
    }


def _causal_depthwise_conv(kernel, x, state=None):
    """x: (B, T, D); kernel (W, D); causal depthwise conv.

    Kept as shifted-slice-and-add: a grouped lax.conv was tried for H1 and
    REGRESSED the HLO byte count on the CPU cost model (64.5 vs 45.1
    GB/device for the block gradient — EXPERIMENTS.md §Perf H1); on TPU the
    Pallas rglru path fuses the conv anyway.

    state: (B, W-1, D) previous inputs for decode; returns (y, new_state).
    """
    w = kernel.shape[0]
    if state is None:
        hist = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    else:
        hist = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(hist[:, i:i + x.shape[1]] * kernel[i] for i in range(w))
    new_state = hist[:, -(w - 1):]
    return y, new_state


def _rglru_gates(params, xc):
    r = jax.nn.sigmoid(layers.dense(params["gate_a"], xc).astype(jnp.float32))
    i = jax.nn.sigmoid(layers.dense(params["gate_x"], xc).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    return a, i


def rglru_block(params, x: jax.Array):
    """Training/prefill forward. x: (B, T, D) -> (B, T, D)."""
    gate = jax.nn.gelu(layers.dense(params["wy"], x))
    xr = layers.dense(params["wx"], x)
    xc, _ = _causal_depthwise_conv(params["conv"]["kernel"], xr)
    a, i = _rglru_gates(params, xc)
    ys, _ = kops.rglru_scan(i * xc.astype(jnp.float32), a)
    out = ys.astype(x.dtype) * gate
    return layers.dense(params["wo"], out)


def rglru_block_decode(params, x: jax.Array, state: RGLRUState):
    """One-token step. x: (B, 1, D) -> ((B, 1, D), new state)."""
    gate = jax.nn.gelu(layers.dense(params["wy"], x))
    xr = layers.dense(params["wx"], x)
    xc, conv_state = _causal_depthwise_conv(
        params["conv"]["kernel"], xr, state.conv)
    a, i = _rglru_gates(params, xc)            # (B, 1, D_rnn)
    gx = jnp.sqrt(jnp.maximum(1.0 - a ** 2, 0.0)) * (
        i * xc.astype(jnp.float32))
    h = a[:, 0] * state.h + gx[:, 0]           # (B, D_rnn)
    out = h[:, None].astype(x.dtype) * gate
    return layers.dense(params["wo"], out), RGLRUState(conv=conv_state, h=h)


def rglru_init_state(batch: int, d_rnn: int, dtype=jnp.bfloat16) -> RGLRUState:
    return RGLRUState(
        conv=jnp.zeros((batch, _CONV_W - 1, d_rnn), dtype),
        h=jnp.zeros((batch, d_rnn), jnp.float32))
