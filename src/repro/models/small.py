"""Paper-faithful small models (Appendix A.1).

* ``MLP`` — three fully-connected layers with ReLU (FedMNIST model).
* ``CNN`` — two conv layers + three FC layers (FedCIFAR10 model, FedLab
  architecture: LeNet-style 5x5 convs with max-pooling).

Pure-jax functional modules: ``init(key) -> params``, ``apply(params, x)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _dense_init(key, n_in, n_out):
    k1, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / n_in)
    return {"w": scale * jax.random.normal(k1, (n_in, n_out), jnp.float32),
            "b": jnp.zeros((n_out,), jnp.float32)}


def _dense(p, x):
    return x @ p["w"] + p["b"]


class MLP:
    """784 -> hidden -> hidden -> 10, ReLU (paper's FedMNIST model)."""

    def __init__(self, in_dim: int = 784, hidden: int = 128,
                 n_classes: int = 10):
        self.dims = (in_dim, hidden, hidden, n_classes)

    def init(self, key: jax.Array):
        keys = jax.random.split(key, 3)
        d = self.dims
        return {f"fc{i}": _dense_init(keys[i], d[i], d[i + 1])
                for i in range(3)}

    def apply(self, params, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(_dense(params["fc0"], x))
        x = jax.nn.relu(_dense(params["fc1"], x))
        return _dense(params["fc2"], x)


def _conv_init(key, h, w, cin, cout):
    scale = jnp.sqrt(2.0 / (h * w * cin))
    return {"w": scale * jax.random.normal(key, (h, w, cin, cout),
                                           jnp.float32),
            "b": jnp.zeros((cout,), jnp.float32)}


def _conv(p, x):  # NHWC, VALID
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


class CNN:
    """LeNet-style: conv5x5(6) -> pool -> conv5x5(16) -> pool -> 120-84-C.

    Matches the FedLab CIFAR10 CNN (2 conv + 3 FC) the paper uses.
    """

    def __init__(self, in_channels: int = 3, n_classes: int = 10,
                 image_hw: int = 32):
        self.cin = in_channels
        self.n_classes = n_classes
        hw = (image_hw - 4) // 2      # after conv1+pool
        hw = (hw - 4) // 2            # after conv2+pool
        self.flat = hw * hw * 16

    def init(self, key: jax.Array):
        ks = jax.random.split(key, 5)
        return {
            "conv0": _conv_init(ks[0], 5, 5, self.cin, 6),
            "conv1": _conv_init(ks[1], 5, 5, 6, 16),
            "fc0": _dense_init(ks[2], self.flat, 120),
            "fc1": _dense_init(ks[3], 120, 84),
            "fc2": _dense_init(ks[4], 84, self.n_classes),
        }

    def apply(self, params, x):
        x = _maxpool2(jax.nn.relu(_conv(params["conv0"], x)))
        x = _maxpool2(jax.nn.relu(_conv(params["conv1"], x)))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(_dense(params["fc0"], x))
        x = jax.nn.relu(_dense(params["fc1"], x))
        return _dense(params["fc2"], x)


def cross_entropy_loss(apply_fn):
    """Build loss_fn(params, xb, yb) for the FL algorithms."""

    def loss_fn(params, xb, yb):
        logits = apply_fn(params, xb)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, yb[:, None], axis=1).mean()

    return loss_fn
