"""Encoder-decoder backbone (SeamlessM4T-v2 text/speech backbone,
arXiv:2308.11596).

Per the assignment carve-out, the modality frontend (mel-spectrogram +
conv feature extractor) is a stub: the encoder consumes precomputed frame
embeddings (B, T_src, d_model).  The backbone is a standard pre-norm
transformer encoder (bidirectional) + decoder (causal self-attention +
cross-attention), GQA per config (seamless-large uses MHA, kv = heads).

Decode state: per decoder layer a self-attention KVCache plus the
precomputed cross-attention K/V of the encoder output.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers

PyTree = Any


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str = "encdec"
    n_enc_layers: int = 12
    n_dec_layers: int = 12
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    head_dim: Optional[int] = None
    d_ff: int = 8192
    vocab: int = 256206
    act: str = "relu"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: Any = jnp.float32
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return self.n_enc_layers + self.n_dec_layers

    def num_params(self) -> int:
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        att = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        enc = self.n_enc_layers * (att + 2 * d * f)
        dec = self.n_dec_layers * (2 * att + 2 * d * f)
        return v * d + enc + dec

    def active_params(self) -> int:
        return self.num_params()


def _attn_init(key, cfg: EncDecConfig):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "q": layers.dense_init(ks[0], d, cfg.n_heads * hd, cfg.dtype),
        "k": layers.dense_init(ks[1], d, cfg.n_kv_heads * hd, cfg.dtype),
        "v": layers.dense_init(ks[2], d, cfg.n_kv_heads * hd, cfg.dtype),
        "o": layers.dense_init(ks[3], cfg.n_heads * hd, d, cfg.dtype),
    }


def init_params(key: jax.Array, cfg: EncDecConfig) -> PyTree:
    keys = jax.random.split(key, cfg.n_enc_layers + cfg.n_dec_layers + 2)
    d = cfg.d_model
    params = {
        "embed": layers.embed_init(keys[0], cfg.vocab, d, cfg.dtype),
        "enc_final_norm": layers.rmsnorm_init(d, cfg.dtype),
        "dec_final_norm": layers.rmsnorm_init(d, cfg.dtype),
        "encoder": {}, "decoder": {},
    }
    for i in range(cfg.n_enc_layers):
        ks = jax.random.split(keys[i + 1], 2)
        params["encoder"][f"layer_{i}"] = {
            "ln_attn": layers.rmsnorm_init(d, cfg.dtype),
            "attn": _attn_init(ks[0], cfg),
            "ln_mlp": layers.rmsnorm_init(d, cfg.dtype),
            "mlp": layers.mlp_init(ks[1], d, cfg.d_ff, cfg.dtype, gated=False),
        }
    off = cfg.n_enc_layers + 1
    for i in range(cfg.n_dec_layers):
        ks = jax.random.split(keys[off + i], 3)
        params["decoder"][f"layer_{i}"] = {
            "ln_self": layers.rmsnorm_init(d, cfg.dtype),
            "self_attn": _attn_init(ks[0], cfg),
            "ln_cross": layers.rmsnorm_init(d, cfg.dtype),
            "cross_attn": _attn_init(ks[1], cfg),
            "ln_mlp": layers.rmsnorm_init(d, cfg.dtype),
            "mlp": layers.mlp_init(ks[2], d, cfg.d_ff, cfg.dtype, gated=False),
        }
    return params


def _mha(p, cfg: EncDecConfig, xq, xkv, *, causal, positions_q, positions_kv,
         rope: bool = True):
    hd = cfg.hd
    q = xq @ p["q"]["kernel"]
    k = xkv @ p["k"]["kernel"]
    v = xkv @ p["v"]["kernel"]
    q = q.reshape(*q.shape[:2], cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(*k.shape[:2], cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(*v.shape[:2], cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if rope:
        q = layers.apply_rope(q, positions_q, cfg.rope_theta)
        k = layers.apply_rope(k, positions_kv, cfg.rope_theta)
    y = attn.chunked_attention(q, k, v, causal=causal)
    b, h, t, _ = y.shape
    y = y.transpose(0, 2, 1, 3).reshape(b, t, h * hd)
    return y @ p["o"]["kernel"], (k, v)


def _stack_layers(layer_dict: dict, n: int):
    return jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls),
        *(layer_dict[f"layer_{i}"] for i in range(n)))


def _scan_stack(layer_fn, layer_dict: dict, n: int, x, remat: bool,
                scan: bool = True):
    """Uniform layers -> lax.scan over stacked params (one compile)."""
    if n < 2 or not scan:
        for i in range(n):
            f = jax.checkpoint(layer_fn) if remat else layer_fn
            x = f(layer_dict[f"layer_{i}"], x)
        return x
    stacked = _stack_layers(layer_dict, n)
    body = jax.checkpoint(layer_fn) if remat else layer_fn

    def step(x_, p):
        return body(p, x_), None

    x, _ = jax.lax.scan(step, x, stacked)
    return x


def encode(params, cfg: EncDecConfig, src_embeds: jax.Array,
           remat: bool = True) -> jax.Array:
    """src_embeds: (B, T_src, d) from the (stubbed) modality frontend."""
    x = src_embeds.astype(cfg.dtype)
    b, t, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))

    def layer(p, x_):
        h = layers.rmsnorm(p["ln_attn"], x_)
        y, _ = _mha(p["attn"], cfg, h, h, causal=False,
                    positions_q=pos, positions_kv=pos)
        x_ = x_ + y
        h = layers.rmsnorm(p["ln_mlp"], x_)
        return x_ + layers.mlp(p["mlp"], h, cfg.act)

    x = _scan_stack(layer, params["encoder"], cfg.n_enc_layers, x, remat,
                    cfg.scan_layers)
    return layers.rmsnorm(params["enc_final_norm"], x)


def decode_train(params, cfg: EncDecConfig, enc_out: jax.Array,
                 tgt_tokens: jax.Array, remat: bool = True) -> jax.Array:
    x = layers.embed(params["embed"], tgt_tokens).astype(cfg.dtype)
    b, t, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    pos_src = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                               (b, enc_out.shape[1]))

    def layer(p, x_):
        h = layers.rmsnorm(p["ln_self"], x_)
        y, _ = _mha(p["self_attn"], cfg, h, h, causal=True,
                    positions_q=pos, positions_kv=pos)
        x_ = x_ + y
        h = layers.rmsnorm(p["ln_cross"], x_)
        y, _ = _mha(p["cross_attn"], cfg, h, enc_out, causal=False,
                    positions_q=pos, positions_kv=pos_src, rope=False)
        x_ = x_ + y
        h = layers.rmsnorm(p["ln_mlp"], x_)
        return x_ + layers.mlp(p["mlp"], h, cfg.act)

    x = _scan_stack(layer, params["decoder"], cfg.n_dec_layers, x, remat,
                    cfg.scan_layers)
    return layers.rmsnorm(params["dec_final_norm"], x)


def loss(params, cfg: EncDecConfig, src_embeds, tgt_tokens, *,
         loss_chunk: int = 1024, remat: bool = True):
    enc_out = encode(params, cfg, src_embeds, remat)
    h = decode_train(params, cfg, enc_out, tgt_tokens, remat)
    b, t, d = h.shape
    inputs, targets = h[:, :-1], tgt_tokens[:, 1:]
    tm1 = t - 1
    chunk = min(loss_chunk, tm1)
    nchunk = -(-tm1 // chunk)
    pad = nchunk * chunk - tm1
    inputs = jnp.pad(inputs, ((0, 0), (0, pad), (0, 0)))
    targets = jnp.pad(targets, ((0, 0), (0, pad)))
    wmask = jnp.pad(jnp.ones((b, tm1), jnp.float32), ((0, 0), (0, pad)))
    emb = params["embed"]["embedding"]

    @jax.checkpoint
    def _chunk_nll(hs, ys, ws):
        logits = hs.astype(jnp.float32) @ emb.T.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, ys[..., None], axis=-1)[..., 0]
        return (nll * ws).sum()

    def chunk_loss(carry, idx):
        hs = jax.lax.dynamic_slice_in_dim(inputs, idx * chunk, chunk, axis=1)
        ys = jax.lax.dynamic_slice_in_dim(targets, idx * chunk, chunk, axis=1)
        ws = jax.lax.dynamic_slice_in_dim(wmask, idx * chunk, chunk, axis=1)
        return carry + _chunk_nll(hs, ys, ws), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros(()), jnp.arange(nchunk))
    return total / (b * tm1)


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #

class EncDecState(NamedTuple):
    self_caches: dict          # layer -> KVCache
    cross_kv: dict             # layer -> (k, v) of encoder output
    enc_len: jax.Array


def prefill(params, cfg: EncDecConfig, src_embeds, tgt_tokens, max_len: int,
            dtype=jnp.bfloat16):
    """Encode source + consume target prefix; return (logits, state)."""
    enc_out = encode(params, cfg, src_embeds, remat=False)
    b = enc_out.shape[0]
    pos_src = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                               (b, enc_out.shape[1]))
    x = layers.embed(params["embed"], tgt_tokens).astype(cfg.dtype)
    t = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    self_caches, cross_kv = {}, {}
    for i in range(cfg.n_dec_layers):
        p = params["decoder"][f"layer_{i}"]
        h = layers.rmsnorm(p["ln_self"], x)
        y, (k, v) = _mha(p["self_attn"], cfg, h, h, causal=True,
                         positions_q=pos, positions_kv=pos)
        cache = attn.init_cache(b, cfg.n_kv_heads, max_len, cfg.hd, dtype)
        self_caches[f"layer_{i}"] = attn.update_cache(cache, k, v)
        x = x + y
        h = layers.rmsnorm(p["ln_cross"], x)
        y, (ck, cv) = _mha(p["cross_attn"], cfg, h, enc_out, causal=False,
                           positions_q=pos, positions_kv=pos_src, rope=False)
        cross_kv[f"layer_{i}"] = (ck.astype(dtype), cv.astype(dtype))
        x = x + y
        h = layers.rmsnorm(p["ln_mlp"], x)
        x = x + layers.mlp(p["mlp"], h, cfg.act)
    h = layers.rmsnorm(params["dec_final_norm"], x)
    logits = (h[:, -1].astype(jnp.float32)
              @ params["embed"]["embedding"].T.astype(jnp.float32))
    state = EncDecState(self_caches=self_caches, cross_kv=cross_kv,
                        enc_len=jnp.asarray(enc_out.shape[1], jnp.int32))
    return logits, state


def decode_step(params, cfg: EncDecConfig, token, state: EncDecState):
    b = token.shape[0]
    x = layers.embed(params["embed"], token[:, None]).astype(cfg.dtype)
    first = state.self_caches["layer_0"]
    pos = jnp.broadcast_to(first.length, (b, 1))
    new_caches = {}
    hd = cfg.hd
    for i in range(cfg.n_dec_layers):
        p = params["decoder"][f"layer_{i}"]
        cache = state.self_caches[f"layer_{i}"]
        h = layers.rmsnorm(p["ln_self"], x)
        q = (h @ p["self_attn"]["q"]["kernel"]).reshape(
            b, 1, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        k = (h @ p["self_attn"]["k"]["kernel"]).reshape(
            b, 1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        v = (h @ p["self_attn"]["v"]["kernel"]).reshape(
            b, 1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k = layers.apply_rope(k, pos, cfg.rope_theta)
        cache = attn.update_cache(cache, k, v)
        new_caches[f"layer_{i}"] = cache
        y = attn.decode_attention(q, cache)
        y = y.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * hd)
        x = x + y @ p["self_attn"]["o"]["kernel"]
        # cross attention against precomputed encoder K/V
        h = layers.rmsnorm(p["ln_cross"], x)
        ck, cv = state.cross_kv[f"layer_{i}"]
        q = (h @ p["cross_attn"]["q"]["kernel"]).reshape(
            b, 1, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        cross_cache = attn.KVCache(k=ck, v=cv, length=state.enc_len)
        y = attn.decode_attention(q, cross_cache)
        y = y.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * hd)
        x = x + y @ p["cross_attn"]["o"]["kernel"]
        h = layers.rmsnorm(p["ln_mlp"], x)
        x = x + layers.mlp(p["mlp"], h, cfg.act)
    h = layers.rmsnorm(params["dec_final_norm"], x)
    logits = (h[:, 0].astype(jnp.float32)
              @ params["embed"]["embedding"].T.astype(jnp.float32))
    return logits, EncDecState(self_caches=new_caches,
                               cross_kv=state.cross_kv,
                               enc_len=state.enc_len)
