"""Architecture spec plumbing: full configs + reduced smoke variants.

Every assigned architecture gets a module defining ``SPEC`` (exact published
dimensions, cited) — selectable via ``--arch <id>`` in the launchers.
``reduced()`` derives the family-preserving small variant used by the CPU
smoke tests (<= 2 layers, d_model <= 512, <= 4 experts).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax.numpy as jnp

from repro.models.encdec import EncDecConfig

ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    citation: str
    model: Any                     # ModelConfig | EncDecConfig
    modality: str = "text"         # text | audio | vlm
    skip_shapes: Tuple[str, ...] = ()
    skip_reason: str = ""
    n_prefix_tokens: int = 0       # vision/audio stub tokens prepended

    @property
    def is_encdec(self) -> bool:
        return isinstance(self.model, EncDecConfig)

    def runs(self, shape: str) -> bool:
        return shape not in self.skip_shapes


def reduced(spec: ArchSpec) -> ArchSpec:
    """Family-preserving smoke-test variant (2 layers, d<=512, <=4 experts)."""
    m = spec.model
    if isinstance(m, EncDecConfig):
        small = dataclasses.replace(
            m, n_enc_layers=1, n_dec_layers=1, d_model=128, n_heads=4,
            n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
            dtype=jnp.float32)
    else:
        # keep pattern + feature flags, shrink dims; head_dim kept modest
        moe_cfg = None
        if m.moe is not None:
            moe_cfg = dataclasses.replace(
                m.moe, n_experts=min(4, m.moe.n_experts),
                topk=min(m.moe.topk, 2), group_size=64,
                capacity_factor=2.0)
        n_layers = max(2, min(len(m.block_pattern), 4)) \
            if len(m.block_pattern) > 1 else 2
        d_model = 256 if m.block_type(0) != "rwkv" else 128
        small = dataclasses.replace(
            m, n_layers=n_layers, d_model=d_model, n_heads=4,
            n_kv_heads=max(1, min(m.n_kv_heads, 2)),
            head_dim=64, d_ff=512, vocab=512,
            window=(16 if m.window else None),
            long_context_cap=(16 if m.long_context_cap else None),
            moe=moe_cfg, dtype=jnp.float32)
        if m.mrope_sections is not None:
            small = dataclasses.replace(small, mrope_sections=(16, 8, 8))
    return dataclasses.replace(
        spec, model=small,
        n_prefix_tokens=min(16, spec.n_prefix_tokens))
