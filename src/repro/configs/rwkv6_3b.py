"""RWKV6-3B "Finch" [arXiv:2404.05892] — attention-free, data-dependent decay.

32 layers, d_model 2560 (40 heads of 64), d_ff 8960, vocab 65536.
Runs long_500k: recurrence state is O(1) in context length.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig

SPEC = ArchSpec(
    arch_id="rwkv6-3b",
    family="ssm",
    citation="arXiv:2404.05892",
    model=ModelConfig(
        name="rwkv6-3b",
        n_layers=32,
        d_model=2560,
        n_heads=40,            # informational; rwkv uses 64-dim heads
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab=65_536,
        block_pattern=("rwkv",),
        tie_embeddings=False,
        dtype=jnp.bfloat16,
    ),
)
