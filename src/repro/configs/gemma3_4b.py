"""Gemma-3 4B [hf:google/gemma-3-1b-pt family] — 5:1 local:global attention,
QK-norm, 128k context.

34 layers, d_model 2560, 8 heads (GQA kv=4, head_dim 256), d_ff 10240,
vocab 262144, local window 1024.  long_500k runs in long-context mode with
the global layers capped to an 8192 window (documented deviation).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig

SPEC = ArchSpec(
    arch_id="gemma3-4b",
    family="dense",
    citation="hf:google/gemma-3-1b-pt",
    model=ModelConfig(
        name="gemma3-4b",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10_240,
        vocab=262_144,
        block_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
        window=1024,
        long_context_cap=8192,
        qk_norm=True,
        act="gelu_tanh",
        embed_scale=True,
        tie_embeddings=True,
        rope_theta=1e6,
        dtype=jnp.bfloat16,
    ),
)
