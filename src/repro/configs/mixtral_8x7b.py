"""Mixtral-8x7B [arXiv:2401.04088] — 8-expert top-2 MoE with SWA.

32 layers, d_model 4096, 32 heads (GQA kv=8, head_dim 128), expert d_ff
14336, vocab 32000, sliding-window attention (4096) on all layers.
Runs long_500k: SWA bounds the KV cache to the window.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig

SPEC = ArchSpec(
    arch_id="mixtral-8x7b",
    family="moe",
    citation="arXiv:2401.04088",
    model=ModelConfig(
        name="mixtral-8x7b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14_336,
        vocab=32_000,
        block_pattern=("swa",),
        window=4096,
        moe=MoEConfig(n_experts=8, topk=2, group_size=256,
                      capacity_factor=1.25),
        moe_period=1,
        tie_embeddings=False,
        rope_theta=1e6,
        dtype=jnp.bfloat16,
    ),
)
