"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family] —
128-expert top-1 MoE with a shared expert, interleaved dense/MoE layers,
chunked local attention (3 local : 1 global, iRoPE-style).

48 layers, d_model 5120, 40 heads (GQA kv=8, head_dim 128), expert d_ff
8192, vocab 202048.  ~400B total / ~17B active parameters.
Runs long_500k with the global layers capped to an 8192 window
(long-context mode, documented deviation in DESIGN.md).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig

SPEC = ArchSpec(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    model=ModelConfig(
        name="llama4-maverick-400b-a17b",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=202_048,
        block_pattern=("swa", "swa", "swa", "attn"),
        window=8192,
        long_context_cap=8192,
        moe=MoEConfig(n_experts=128, topk=1, group_size=256,
                      capacity_factor=1.25),
        moe_period=2,              # interleaved dense/MoE (Maverick)
        n_shared_experts=1,
        tie_embeddings=False,
        rope_theta=5e5,
        dtype=jnp.bfloat16,
    ),
)
