"""Qwen2-7B [arXiv:2407.10671] — dense GQA with QKV bias.

28 layers, d_model 3584, 28 heads (GQA kv=4, head_dim 128), d_ff 18944,
vocab 152064.  Pure full attention -> long_500k skipped (DESIGN.md §4).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig

SPEC = ArchSpec(
    arch_id="qwen2-7b",
    family="dense",
    citation="arXiv:2407.10671",
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; no native sub-quadratic variant",
    model=ModelConfig(
        name="qwen2-7b",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18_944,
        vocab=152_064,
        qkv_bias=True,
        tie_embeddings=False,
        rope_theta=1e6,
        dtype=jnp.bfloat16,
    ),
)
