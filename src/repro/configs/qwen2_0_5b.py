"""Qwen2-0.5B [arXiv:2407.10671] — dense GQA with QKV bias.

24 layers, d_model 896, 14 heads (GQA kv=2, head_dim 64), d_ff 4864,
vocab 151936, tied embeddings.  Pure full attention -> long_500k skipped
(no sub-quadratic variant; DESIGN.md §4).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig

SPEC = ArchSpec(
    arch_id="qwen2-0.5b",
    family="dense",
    citation="arXiv:2407.10671",
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; no native sub-quadratic variant",
    model=ModelConfig(
        name="qwen2-0.5b",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab=151_936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1e6,
        dtype=jnp.bfloat16,
    ),
)
