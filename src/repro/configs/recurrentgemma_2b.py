"""RecurrentGemma-2B [arXiv:2402.19427] — hybrid RG-LRU + local attention.

26 layers, d_model 2560, 10 heads (MQA, kv=1, head_dim 256), d_ff 7680,
vocab 256000.  Griffin block pattern: two recurrent blocks per local
(window 2048) attention block.  Runs long_500k: RG-LRU state is O(1) and
the attention window bounds the KV cache.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig

SPEC = ArchSpec(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    citation="arXiv:2402.19427",
    model=ModelConfig(
        name="recurrentgemma-2b",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256_000,
        block_pattern=("rglru", "rglru", "swa"),
        window=2048,
        act="gelu_tanh",
        embed_scale=True,
        tie_embeddings=True,
        dtype=jnp.bfloat16,
    ),
)
