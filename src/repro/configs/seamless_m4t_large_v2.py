"""SeamlessM4T-large-v2 [arXiv:2308.11596] — multimodal encoder-decoder
backbone (12 encoder + 12 decoder layers = 24L total).

d_model 1024, 16 heads (MHA, kv=16, head_dim 64), d_ff 8192, vocab 256206.
The speech frontend (mel + conv feature extractor) is a stub per the
assignment carve-out: the encoder consumes precomputed frame embeddings.
Decode shapes exercise the decoder with a seq_len self-attention cache;
long_500k skipped (full-attention enc-dec; speech segments never reach
500k tokens; DESIGN.md §4).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.encdec import EncDecConfig

SPEC = ArchSpec(
    arch_id="seamless-m4t-large-v2",
    family="audio",
    modality="audio",
    citation="arXiv:2308.11596",
    skip_shapes=("long_500k",),
    skip_reason="full-attention encoder-decoder; 500k decode inapplicable",
    n_prefix_tokens=0,
    model=EncDecConfig(
        name="seamless-m4t-large-v2",
        n_enc_layers=12,
        n_dec_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab=256_206,
        act="relu",
        dtype=jnp.bfloat16,
    ),
)
