"""Qwen2-VL-7B [arXiv:2409.12191] — VLM backbone with M-RoPE.

Language decoder identical to Qwen2-7B (28L, d 3584, 28H GQA kv=4,
d_ff 18944, vocab 152064) plus multimodal rotary embeddings with
(temporal, height, width) = (16, 24, 24) frequency sections.  The ViT
vision encoder + projector are a stub per the carve-out: ``input_specs``
provides 256 precomputed patch embeddings prepended to the text tokens.
long_500k skipped (pure full attention; DESIGN.md §4).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig

SPEC = ArchSpec(
    arch_id="qwen2-vl-7b",
    family="vlm",
    modality="vlm",
    citation="arXiv:2409.12191",
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; no native sub-quadratic variant",
    n_prefix_tokens=256,
    model=ModelConfig(
        name="qwen2-vl-7b",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18_944,
        vocab=152_064,
        qkv_bias=True,
        tie_embeddings=False,
        rope_theta=1e6,
        mrope_sections=(16, 24, 24),
        dtype=jnp.bfloat16,
    ),
)
