"""Gemma-2 9B [arXiv:2408.00118] — alternating local/global attention with
logit soft-capping and sandwich (post) norms.

42 layers, d_model 3584, 16 heads (GQA kv=8, head_dim 256), d_ff 14336,
vocab 256000, local window 4096, attn softcap 50, final softcap 30.
long_500k runs in long-context mode: the global layers are capped to an
8192 sliding window (documented deviation in DESIGN.md §4).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig

SPEC = ArchSpec(
    arch_id="gemma2-9b",
    family="dense",
    citation="arXiv:2408.00118",
    model=ModelConfig(
        name="gemma2-9b",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14_336,
        vocab=256_000,
        block_pattern=("swa", "attn"),
        window=4096,
        long_context_cap=8192,
        softcap_attn=50.0,
        softcap_final=30.0,
        post_norm=True,
        act="gelu_tanh",
        embed_scale=True,
        tie_embeddings=True,
        dtype=jnp.bfloat16,
    ),
)
