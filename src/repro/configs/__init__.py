"""Architecture registry: ``get_spec("mixtral-8x7b")`` / ``--arch`` ids."""

from __future__ import annotations

import importlib

from repro.configs.base import ALL_SHAPES, SHAPES, ArchSpec, InputShape, reduced

__all__ = ["ALL_SHAPES", "SHAPES", "ArchSpec", "InputShape", "reduced",
           "ARCH_IDS", "get_spec", "all_specs"]

_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "rwkv6-3b": "rwkv6_3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "gemma2-9b": "gemma2_9b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen2-7b": "qwen2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "gemma3-4b": "gemma3_4b",
}

ARCH_IDS = tuple(_MODULES)


def get_spec(arch_id: str) -> ArchSpec:
    try:
        mod = _MODULES[arch_id]
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{mod}").SPEC


def all_specs() -> dict[str, ArchSpec]:
    return {a: get_spec(a) for a in ARCH_IDS}
